"""Online GNN inference serving (paper §5 — the production story).

The training side of this repo reproduces GraphTheta's flexible training
strategies; this package serves the trained model: a
:class:`~repro.serving.server.GNNServer` micro-batches incoming node-id
requests into size-bucketed compact views (the PR 6 machinery), runs a
compiled-once-per-bucket jitted infer step, and — the production latency
trick — keeps a host-side :class:`~repro.serving.cache.EmbeddingCache`
of historical layer-(K-1) embeddings so a cache-hit request recomputes
only its 1-hop top layer instead of the full K-hop cascade.
"""
from repro.serving.cache import EmbeddingCache
from repro.serving.server import (GNNServer, ServeStats,
                                  ServerClosedError,
                                  ServerOverloadedError)

__all__ = ["EmbeddingCache", "GNNServer", "ServeStats",
           "ServerClosedError", "ServerOverloadedError"]
