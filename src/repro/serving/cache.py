"""Historical-embedding cache for online GNN inference.

The serving latency trick from the sampling literature (see PAPERS.md,
"Scalable Graph Neural Network Training: The Case for Sampling"): keep
the layer-(K-1) hidden embeddings computed by previous requests in a
host-side table. A later request whose 1-hop ego-net is fully covered by
*fresh* cached rows skips the K-hop cascade entirely — it builds a
1-hop compact view, feeds the cached rows in as features, and runs only
the model's top layer plus the decoder.

Freshness is version-based: every entry records the global ``version``
it was written at, and a read is fresh iff ``version - entry_version <=
staleness``. ``advance()`` bumps the global version (call it when the
served params change — e.g. after an online fine-tune step), so
``staleness=0`` means "only embeddings computed under the current
params ever hit", which makes cache-hit outputs **bit-exact** with the
full recompute (the cached rows came out of the very same jitted
computation). ``invalidate(nodes)`` drops entries outright on feature
updates — a node whose raw features changed has a wrong cached
embedding at *any* version.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.graph.csr import Graph


class EmbeddingCache:
    """Host-side table of historical layer-(K-1) node embeddings.

    ``table`` is an ``(N, dim)`` float32 array updated in place (so a
    :class:`~repro.core.views.CompactBlockBuilder` holding it as its
    feature source always gathers current rows); ``entry_version[v]`` is
    the global version node v's row was written at, ``-1`` = never
    written. ``hits``/``misses`` count per-target admission decisions.
    """

    def __init__(self, g: Graph, dim: int, staleness: int = 0):
        if int(dim) <= 0:
            raise ValueError(f"EmbeddingCache dim must be positive, "
                             f"got {dim}")
        self.g = g
        self.dim = int(dim)
        self.staleness = int(staleness)
        self.table = np.zeros((g.num_nodes, self.dim), np.float32)
        self.entry_version = np.full(g.num_nodes, -1, np.int64)
        self.version = 0
        self.hits = 0
        self.misses = 0
        # every version/table access takes this lock: a param swap's
        # advance() racing a dispatch thread's coverage()/put() must not
        # interleave (coverage reads self.version twice — target rows
        # and neighbor rows — and a bump in between would admit a blend
        # of old and new embeddings). RLock: coverage() calls fresh().
        self._lock = threading.RLock()

    # -- writes ----------------------------------------------------------------

    def put(self, nodes: np.ndarray, values: np.ndarray) -> None:
        """Write embeddings for ``nodes`` at the current version."""
        nodes = np.asarray(nodes)
        values = np.asarray(values, np.float32)
        if values.shape != (len(nodes), self.dim):
            raise ValueError(
                f"EmbeddingCache.put: values shape {values.shape} != "
                f"({len(nodes)}, {self.dim})")
        with self._lock:
            self.table[nodes] = values
            self.entry_version[nodes] = self.version

    def advance(self) -> int:
        """Bump the global version (served params changed). Existing
        entries age by one; with ``staleness=0`` they all stop hitting
        until rewritten."""
        with self._lock:
            self.version += 1
            return self.version

    def invalidate(self, nodes: Optional[np.ndarray] = None) -> None:
        """Drop entries for ``nodes`` (all nodes if None) — the feature
        -update path: stale *inputs* can't be aged back in by any
        staleness bound."""
        with self._lock:
            if nodes is None:
                self.entry_version.fill(-1)
            else:
                self.entry_version[np.asarray(nodes)] = -1

    # -- reads -----------------------------------------------------------------

    def fresh(self, nodes: np.ndarray) -> np.ndarray:
        """Bool mask: which of ``nodes`` have a usable entry."""
        with self._lock:
            ver = self.entry_version[np.asarray(nodes)]
            return (ver >= 0) & ((self.version - ver) <= self.staleness)

    def coverage(self, targets: np.ndarray) -> np.ndarray:
        """Bool mask over ``targets``: target t is *covered* (can be
        served from cache) iff t and every in-neighbor of t are fresh —
        exactly the rows the top GNN layer reads on a 1-hop view.
        Vectorized over the CSC segments of the whole batch. Holds the
        lock across BOTH freshness reads: an ``advance()`` landing
        between the target check and the neighbor check would admit a
        mixed-version hit."""
        targets = np.asarray(targets)
        if len(targets) == 0:
            return np.zeros(0, bool)
        indptr, order = self.g.csc()
        starts, stops = indptr[targets], indptr[targets + 1]
        counts = (stops - starts).astype(np.int64)
        with self._lock:
            covered = self.fresh(targets)
            total = int(counts.sum())
            if total == 0:
                return covered
            # gather every target's in-edge ids in one flat sweep
            flat = np.repeat(starts, counts) + (
                np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                             counts))
            srcs = self.g.src[order[flat]]
            stale = ~self.fresh(srcs)
        # per-target stale count via segment sums (reduceat needs
        # non-empty segments; empty ones contribute zero by construction)
        seg = np.zeros(len(targets), np.int64)
        nz = counts > 0
        if nz.any():
            bounds = (np.cumsum(counts) - counts)[nz]
            seg[nz] = np.add.reduceat(stale.astype(np.int64), bounds)
        return covered & (seg == 0)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"hits": int(self.hits), "misses": int(self.misses),
                "hit_rate": (self.hits / total) if total else 0.0,
                "version": int(self.version),
                "entries": int((self.entry_version >= 0).sum()),
                "staleness": self.staleness}
