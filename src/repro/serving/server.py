"""Request-batched online GNN inference over bucketed compact views.

The serving pipeline (queue -> view -> device -> gather)::

    clients --> request(node_id) --> [batching queue]
                                         | deadline / size trigger
                                         v
                  coverage split: cache-hit targets | miss targets
                       |                                  |
                1-hop CompactView                  K-hop CompactView
              (features = cached h^{K-1})       (raw node features)
                       |                                  |
               top-layer infer step              full infer step
              (compiled once/bucket)           (compiled once/bucket,
                       |                        also emits h^{K-1})
                       |                                  |
                       +----------- gather rows ----------+--> responses
                                                          |
                                             cache.put (write-back)

Why the hit path is exact at ``staleness=0``: hop ordering makes the
"within 1 hop" node set a *prefix* of a K-hop view, and after K-1
layers the full step's hidden state is the true full-graph h^{K-1} for
exactly that prefix (the telescoping active-set guarantee the training
loss already relies on). The write-back stores those rows, so a later
hit feeds the top layer the *same numbers* the full cascade would — and
the 1-hop view's per-target edge lists are the same global edges in the
same CSC order, so the aggregation sums bitwise-identically.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.tgar import layer_forward_block
from repro.core.trainer import BucketedFn
from repro.core.views import BucketSpec, CompactBlockBuilder, ViewBuilder
from repro.graph.csr import Graph
from repro.serving.cache import EmbeddingCache


class ServerClosedError(RuntimeError):
    """The server was closed: the request was refused at the door, or it
    was still queued when ``close()`` failed the pending futures."""


class ServerOverloadedError(RuntimeError):
    """The bounded request queue is full — the server sheds load instead
    of buffering unboundedly (clients should back off and retry)."""


@dataclass
class ServeStats:
    """Per-stage timing + cache/batching counters; ``summary()`` folds in
    latency percentiles and trace certificates."""
    requests: int = 0
    batches: int = 0
    queue_wait_s: float = 0.0
    view_build_s: float = 0.0
    device_step_s: float = 0.0
    gather_s: float = 0.0
    latencies_s: list = field(default_factory=list)

    def record_batch(self, n: int, queue_wait: float = 0.0) -> None:
        """Count one served batch (stage times accumulate separately as
        the batch flows through the pipeline)."""
        self.requests += n
        self.batches += 1
        self.queue_wait_s += queue_wait

    @staticmethod
    def _pct(xs, q):
        if not xs:
            return 0.0
        return float(np.percentile(np.asarray(xs), q))

    def summary(self) -> dict:
        lat = self.latencies_s
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_batch": (self.requests / self.batches
                           if self.batches else 0.0),
            "stage_s": {"queue_wait": self.queue_wait_s,
                        "view_build": self.view_build_s,
                        "device_step": self.device_step_s,
                        "gather": self.gather_s},
            "latency_ms": {"p50": 1e3 * self._pct(lat, 50),
                           "p99": 1e3 * self._pct(lat, 99),
                           "mean": (1e3 * float(np.mean(lat))
                                    if lat else 0.0)},
        }


class _Pending:
    """One queued request: a node id, its enqueue time, and a completion
    event the client blocks on."""

    __slots__ = ("node", "t_in", "done", "result", "error")

    def __init__(self, node: int):
        self.node = int(node)
        self.t_in = time.perf_counter()
        self.done = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None


class GNNServer:
    """Online inference over a trained MPGNN: micro-batches node-id
    requests into size-bucketed compact views and answers with per-node
    logits.

    Two device paths, both :class:`~repro.core.trainer.BucketedFn`
    (compiled once per touched bucket, certified by
    :meth:`assert_compiled_per_bucket`):

    - **miss** — K-hop compact view over raw features; the jitted step
      also returns the layer-(K-1) hidden rows, which are written back
      to the :class:`EmbeddingCache` (nodes within 1 hop — a prefix
      under hop ordering).
    - **hit** — 1-hop compact view whose ``x`` rows are gathered from
      the cache table; only the top layer + decoder run. Admission is
      per target: the target and *all* its in-neighbors must be fresh
      within ``staleness`` versions.

    ``request()`` is the concurrent client API (deadline/size-triggered
    batching via a dispatcher thread, see :meth:`start`); ``submit()``
    serves one batch synchronously (the load-test / bench inner loop).
    """

    def __init__(self, model, params, g: Graph,
                 buckets: Optional[BucketSpec] = None,
                 cache: object = True, staleness: int = 0,
                 max_batch: int = 32, max_wait_ms: float = 2.0,
                 gcn_norm: bool = True, slots: int = 2,
                 max_queue: Optional[int] = None):
        self.model = model
        self.params = params
        self.g = g
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max(0.0, float(max_wait_ms)) / 1e3
        # bounded admission: a stalled device path must shed load with a
        # typed error, not buffer requests (and their client threads)
        # without limit
        self.max_queue = (8 * self.max_batch if max_queue is None
                          else max(1, int(max_queue)))
        backend = getattr(model, "aggregate_backend", "reference")
        csc = backend == "csc"
        self.buckets = buckets or BucketSpec.for_graph(g)
        self._builder = ViewBuilder(g, model.K, compact=True)
        self._stager = CompactBlockBuilder(
            g, model.K, buckets=self.buckets, slots=slots,
            gcn_norm=gcn_norm, csc_plan=csc)
        # the historical-embedding fast path needs a layer below the top
        # one to cache — K=1 models always take the full (1-hop) path
        if cache is True and model.K >= 2:
            cache = EmbeddingCache(g, dim=model.layers[-2].out_dim,
                                   staleness=staleness)
        elif cache is True:
            cache = None
        self.cache: Optional[EmbeddingCache] = cache or None
        if self.cache is not None:
            self._hit_builder = ViewBuilder(g, 1, compact=True)
            self._hit_stager = CompactBlockBuilder(
                g, 1, buckets=self.buckets, slots=slots,
                gcn_norm=gcn_norm, csc_plan=csc,
                features=self.cache.table)
        else:
            self._hit_builder = self._hit_stager = None
        self.stats = ServeStats()
        # one batch in flight at a time: staging mutates per-bucket ring
        # buffers and the cache write-back must be ordered
        self._serve_lock = threading.Lock()

        K = model.K

        def full_fn(params, block):
            h = block.x
            n = block.num_nodes_padded
            penult = h
            for k, layer in enumerate(model.layers):
                if k == K - 1:
                    penult = h     # the layer-(K-1) rows the cache stores
                h = layer_forward_block(layer, params["layers"][k], h,
                                        block, k, n, backend=backend)
            return model.decode(params, h), penult

        def hit_fn(params, block):
            h = layer_forward_block(model.layers[-1],
                                    params["layers"][-1], block.x, block,
                                    0, block.num_nodes_padded,
                                    backend=backend)
            return model.decode(params, h)

        self._full_step = BucketedFn(full_fn, name="serve_full")
        self._hit_step = BucketedFn(hit_fn, name="serve_hit")

        # batching queue state (armed by start())
        self._queue: list = []
        self._cv = threading.Condition()
        self._dispatcher: Optional[threading.Thread] = None
        self._running = False
        self._closed = False

    # -- the device paths ------------------------------------------------------

    def _infer_full(self, targets: np.ndarray) -> np.ndarray:
        """K-hop path for (sorted unique) targets; writes back h^{K-1}."""
        t0 = time.perf_counter()
        view = self._builder.khop_compact(targets)
        block = jax.tree_util.tree_map(np.array, self._stager.stage(view))
        t1 = time.perf_counter()
        logits, penult = self._full_step(self.params, block)
        logits = np.asarray(logits)
        t2 = time.perf_counter()
        if self.cache is not None:
            m = int(view.hop_offsets[1])     # nodes within 1 hop: a prefix
            self.cache.put(view.nodes[:m], np.asarray(penult)[:m])
        self.stats.view_build_s += t1 - t0
        self.stats.device_step_s += t2 - t1
        return logits[:len(targets)]

    def _infer_hit(self, targets: np.ndarray) -> np.ndarray:
        """1-hop top-layer path over cached h^{K-1} rows."""
        t0 = time.perf_counter()
        view = self._hit_builder.khop_compact(targets)
        block = jax.tree_util.tree_map(np.array,
                                       self._hit_stager.stage(view))
        t1 = time.perf_counter()
        logits = np.asarray(self._hit_step(self.params, block))
        t2 = time.perf_counter()
        self.stats.view_build_s += t1 - t0
        self.stats.device_step_s += t2 - t1
        return logits[:len(targets)]

    def submit(self, node_ids: Sequence[int]) -> np.ndarray:
        """Serve one batch synchronously: returns ``(len(node_ids),
        num_classes)`` logits, one row per requested node (duplicates
        allowed)."""
        if self._closed:
            raise ServerClosedError("GNNServer is closed")
        nodes = np.asarray(node_ids, np.int64)
        if nodes.ndim != 1 or len(nodes) == 0:
            raise ValueError("submit() expects a non-empty 1-D sequence "
                             "of node ids")
        if nodes.min() < 0 or nodes.max() >= self.g.num_nodes:
            raise ValueError(
                f"node ids must lie in [0, {self.g.num_nodes})")
        t0 = time.perf_counter()
        with self._serve_lock:
            out = self._serve_locked(nodes)
        lat = time.perf_counter() - t0
        self.stats.latencies_s.extend([lat] * len(nodes))
        self.stats.record_batch(len(nodes))
        return out

    def _serve_locked(self, nodes: np.ndarray) -> np.ndarray:
        targets = np.unique(nodes)           # sorted — hop-0 view order
        if self.cache is not None:
            hit_mask = self.cache.coverage(targets)
            self.cache.hits += int(hit_mask.sum())
            self.cache.misses += int((~hit_mask).sum())
        else:
            hit_mask = np.zeros(len(targets), bool)
        out = np.empty((len(targets), self.model.num_classes), np.float32)
        miss = targets[~hit_mask]
        if len(miss):
            out[~hit_mask] = self._infer_full(miss)
        hit = targets[hit_mask]
        if len(hit):
            out[hit_mask] = self._infer_hit(hit)
        t0 = time.perf_counter()
        rows = np.searchsorted(targets, nodes)
        result = out[rows]
        self.stats.gather_s += time.perf_counter() - t0
        return result

    # -- the batching queue (concurrent clients) -------------------------------

    def start(self) -> "GNNServer":
        """Arm the dispatcher thread; clients then call :meth:`request`
        concurrently. A batch fires when ``max_batch`` requests are
        queued or the oldest has waited ``max_wait_ms``."""
        with self._cv:
            if self._closed:
                raise ServerClosedError(
                    "GNNServer is closed — build a new server")
            if self._running:
                return self
            self._running = True
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            name="gnn-serve-dispatch",
                                            daemon=True)
        self._dispatcher.start()
        return self

    def stop(self) -> None:
        """Retire the dispatcher after *draining*: every already-queued
        request is still served. (:meth:`close` is the hard variant —
        queued requests are failed, not served.)"""
        with self._cv:
            self._running = False
            self._cv.notify_all()
        if self._dispatcher is not None:
            self._dispatcher.join()
            self._dispatcher = None

    def close(self) -> None:
        """Shut down with drain semantics: stop accepting new requests
        (they get :class:`ServerClosedError`), let the batch already
        being served flush its responses, fail every still-queued
        request's future with :class:`ServerClosedError`, and retire the
        dispatcher. Idempotent; the server cannot be restarted."""
        with self._cv:
            self._closed = True
            self._running = False
            pending, self._queue = self._queue, []
            self._cv.notify_all()
        err = ServerClosedError(
            "GNNServer closed while the request was queued")
        for p in pending:
            p.error = err
            p.done.set()
        if self._dispatcher is not None:
            self._dispatcher.join()     # flushes the in-flight batch
            self._dispatcher = None

    def request(self, node_id: int,
                timeout: Optional[float] = 30.0) -> np.ndarray:
        """Enqueue one node-id request and block until its logits are
        ready (the concurrent client API; requires :meth:`start`).
        Raises :class:`ServerOverloadedError` when the bounded queue is
        full and :class:`ServerClosedError` after :meth:`close`."""
        with self._cv:
            if self._closed:
                raise ServerClosedError("GNNServer is closed")
            if not self._running:
                raise RuntimeError("GNNServer.request() needs start() — "
                                   "or use submit() for synchronous "
                                   "batches")
            if len(self._queue) >= self.max_queue:
                raise ServerOverloadedError(
                    f"request queue full ({self.max_queue} pending) — "
                    "back off and retry")
            p = _Pending(node_id)
            self._queue.append(p)
            self._cv.notify_all()
        if not p.done.wait(timeout):
            raise TimeoutError(f"request for node {node_id} timed out")
        if p.error is not None:
            raise p.error
        return p.result

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while self._running and not self._queue:
                    self._cv.wait(0.1)
                if not self._running and not self._queue:
                    return
                # deadline/size trigger: wait for more work until the
                # oldest request's deadline, then take up to max_batch
                deadline = self._queue[0].t_in + self.max_wait_s
                while (self._running
                       and len(self._queue) < self.max_batch):
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        break
                    self._cv.wait(left)
                batch = self._queue[:self.max_batch]
                del self._queue[:self.max_batch]
            self._serve_pending(batch)

    def _serve_pending(self, batch: list) -> None:
        t_go = time.perf_counter()
        waited = sum(t_go - p.t_in for p in batch)
        nodes = np.asarray([p.node for p in batch], np.int64)
        try:
            with self._serve_lock:
                out = self._serve_locked(nodes)
        except BaseException as e:      # deliver, don't kill the loop
            for p in batch:
                p.error = e
                p.done.set()
            return
        t_end = time.perf_counter()
        for i, p in enumerate(batch):
            p.result = out[i]
            self.stats.latencies_s.append(t_end - p.t_in)
            p.done.set()
        self.stats.record_batch(len(batch), waited)

    # -- contracts / observability ---------------------------------------------

    def assert_compiled_per_bucket(self) -> None:
        """The serving analogue of the CompactTrainer contract: each
        device path traced exactly once per touched bucket across the
        whole request trace."""
        self._full_step.assert_compiled_per_bucket()
        if self._hit_step.buckets_touched:
            self._hit_step.assert_compiled_per_bucket()

    def server_stats(self) -> dict:
        s = self.stats.summary()
        s["cache"] = (self.cache.stats() if self.cache is not None
                      else {"enabled": False})
        s["trace"] = {
            "full": {"traces": self._full_step.traces,
                     "buckets": sorted(self._full_step.buckets_touched)},
            "hit": {"traces": self._hit_step.traces,
                    "buckets": sorted(self._hit_step.buckets_touched)},
        }
        return s

    # -- lifecycle -------------------------------------------------------------

    def update_params(self, params) -> None:
        """Swap the served params (an online fine-tune step landed). The
        cache ages one version: with ``staleness=0`` every pre-update
        embedding stops hitting immediately.

        Holds the serve lock so the swap+advance pair is atomic with
        respect to a batch being served: every response is computed
        entirely under one ``(params, cache version)`` — never a blend
        of old cached rows with a new top layer."""
        with self._serve_lock:
            self.params = params
            if self.cache is not None:
                self.cache.advance()

    def update_features(self, nodes: np.ndarray,
                        values: np.ndarray) -> None:
        """In-place node-feature update + cache invalidation: the updated
        nodes' cached embeddings are wrong at any staleness, and so are
        their out-neighbors' (their h^{K-1} aggregates the updated
        features within K-1 hops — conservatively, every node whose
        1..(K-1)-hop in-neighborhood touches ``nodes``; for the common
        K=2 serving setup that is exactly the out-neighbors).

        Holds the serve lock — a batch mid-flight must not see half the
        feature write or a feature/invalidation mismatch."""
        nodes = np.asarray(nodes, np.int64)
        with self._serve_lock:
            self.g.node_features[nodes] = values
            # the graph's cached strategy-invariant base blocks hold a
            # COPY of the features (GraphView.as_block / offline infer)
            self.g._base_blocks.clear()
            if self.cache is None:
                return
            stale = [nodes]
            frontier = nodes
            for _ in range(self.model.K - 1):
                # out-neighbors of the frontier: edges whose src is stale
                sel = np.isin(self.g.src, frontier)
                frontier = np.unique(self.g.dst[sel])
                stale.append(frontier)
            self.cache.invalidate(np.unique(np.concatenate(stale)))
