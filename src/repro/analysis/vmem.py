"""Static VMEM-budget analyzer for Pallas launches.

The block geometry of the CSC kernels is *documented* in
``kernels/segment_sum.py`` ("Block geometry & VMEM budget") and
``kernels/backward.py`` — this module checks it. Walking a traced jaxpr,
every ``pallas_call`` equation carries its full launch geometry in
params: ``grid_mapping`` holds the grid and one BlockMapping per tensor
operand/output (block shape + the backing array's dtype), and the kernel
body rides along as a sub-jaxpr. Per-launch residency is reconstructed
as:

- **block residency** — Σ over BlockMappings of ``prod(block_shape) ·
  itemsize``: what the pipeline keeps in VMEM per grid step (the
  constant-index-map resident blocks — e.g. the whole ``(E, D)`` message
  array — price in at full size, exactly as documented);
- **peak temporary** — max over kernel-body equations of that equation's
  summed output-aval bytes: a lower-bound proxy for the largest
  intermediate the body materializes (the max kernel's ``(BE, BN, BD)``
  candidate tensor is caught here);
- **SMEM residency** — the scalar-prefetch operands
  (``grid_mapping.num_index_operands`` leading invars), reported but not
  budgeted (plan indices are KiB-scale).

A kernel whose ``block + peak-temp`` bytes exceed the configurable
budget (default 16 MiB — one TPU core's VMEM) yields a ``vmem.budget``
finding, so geometry regressions die in CI instead of OOMing on a TPU.
The model is deliberately conservative-simple: double-buffering overhead
and compiler scratch aren't modeled, which is why the default budget is
the full core rather than the documented half-core design point.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.analysis.jaxpr import (Finding, JaxprContext, jaxpr_eqns,
                                  pallas_src, rule)

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024      # one TPU core, bytes


def _aval_bytes(aval) -> int:
    shape = tuple(getattr(aval, "shape", ()))
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    return int(math.prod(shape)) * np.dtype(str(dtype)).itemsize


@dataclass
class KernelStats:
    """Reconstructed per-launch residency of one ``pallas_call``."""
    name: str                   # kernel fn + source location
    grid: tuple
    block_bytes: int            # Σ block residency over tensor operands
    peak_temp_bytes: int        # largest kernel-body intermediate
    smem_bytes: int             # scalar-prefetch operands
    blocks: List[dict] = field(default_factory=list)

    @property
    def vmem_bytes(self) -> int:
        return self.block_bytes + self.peak_temp_bytes

    def to_json(self) -> dict:
        return {"name": self.name, "grid": list(self.grid),
                "block_bytes": self.block_bytes,
                "peak_temp_bytes": self.peak_temp_bytes,
                "vmem_bytes": self.vmem_bytes,
                "smem_bytes": self.smem_bytes,
                "blocks": self.blocks}


def analyze_pallas_eqn(eqn) -> Optional[KernelStats]:
    """KernelStats for one ``pallas_call`` equation (None if the params
    don't carry a grid mapping — foreign/legacy lowering)."""
    gm = eqn.params.get("grid_mapping")
    if gm is None:
        return None
    block_bytes = 0
    blocks = []
    for bm in gm.block_mappings:
        shape = tuple(int(d) if isinstance(d, (int, np.integer)) else 1
                      for d in bm.block_shape)
        dtype = np.dtype(str(bm.array_shape_dtype.dtype))
        nbytes = int(math.prod(shape)) * dtype.itemsize
        block_bytes += nbytes
        blocks.append({"block_shape": list(shape), "dtype": str(dtype),
                       "bytes": nbytes})
    # scalar-prefetch operands are the leading invars, excluded from
    # block_mappings; they live in SMEM
    n_idx = int(getattr(gm, "num_index_operands", 0))
    smem = sum(_aval_bytes(v.aval) for v in eqn.invars[:n_idx])
    # peak body intermediate: the largest single equation's outputs
    body = eqn.params.get("jaxpr")
    peak = 0
    if body is not None:
        for beqn in jaxpr_eqns(body):
            peak = max(peak, sum(_aval_bytes(v.aval)
                                 for v in beqn.outvars))
    return KernelStats(name=pallas_src(eqn),
                       grid=tuple(int(g) for g in gm.grid),
                       block_bytes=block_bytes, peak_temp_bytes=peak,
                       smem_bytes=smem, blocks=blocks)


def iter_kernel_stats(closed_jaxpr) -> List[KernelStats]:
    """Stats for every ``pallas_call`` reachable from the traced jaxpr
    (including those spliced into VJP sub-jaxprs by value_and_grad)."""
    out = []
    for eqn in jaxpr_eqns(closed_jaxpr):
        if eqn.primitive.name == "pallas_call":
            stats = analyze_pallas_eqn(eqn)
            if stats is not None:
                out.append(stats)
    return out


def check_vmem(closed_jaxpr, budget: int = DEFAULT_VMEM_BUDGET,
               label: str = "") -> List[Finding]:
    """``vmem.budget`` findings for every launch exceeding ``budget``."""
    findings = []
    for stats in iter_kernel_stats(closed_jaxpr):
        if stats.vmem_bytes > budget:
            findings.append(Finding(
                "vmem.budget",
                f"per-launch VMEM residency {stats.vmem_bytes / 2**20:.1f}"
                f" MiB (blocks {stats.block_bytes / 2**20:.1f} MiB + peak "
                f"temp {stats.peak_temp_bytes / 2**20:.1f} MiB) exceeds "
                f"the {budget / 2**20:.1f} MiB budget; grid={stats.grid}",
                label=label, location=stats.name))
    return findings


@rule("vmem.budget",
      "every pallas_call launch's reconstructed VMEM residency (blocks "
      "+ peak body temporary) fits the configured budget")
def _check_vmem_rule(ctx: JaxprContext) -> List[Finding]:
    return check_vmem(ctx.closed_jaxpr, budget=ctx.vmem_budget,
                      label=ctx.label)
