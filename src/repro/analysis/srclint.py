"""AST lint over ``src/repro`` — the bug classes this repo actually shipped.

Two families, run by :func:`lint_tree` (and the ``python -m
repro.analysis`` gate):

``src.bare-assert``
    A bare ``assert`` guarding inputs in library code vanishes under
    ``python -O`` and then crashes (or silently mis-computes) far from
    the call site — the PR 5 bug ``_require_rng`` documents. Library
    code raises ``ValueError``/``TypeError`` with a message instead;
    the lint enforces zero remaining.

``src.hot-membership-scan`` / ``src.hot-full-graph-alloc``
    Per-step work in the **hot view path** must stay O(view). The
    configured hot functions of ``core/views.py``/``core/subgraph.py``
    may not call the O(N)-membership numpy scans
    (``np.isin``/``np.union1d``/``np.setdiff1d``) nor allocate fresh
    full-graph-sized arrays (``np.zeros(g.num_nodes, ...)`` and
    friends, including via locals assigned from ``.num_nodes`` /
    ``.num_edges``). Parity oracles (``bfs_layers_loop``,
    ``cluster_view_recompute``) are deliberately outside the hot set.

``src.silent-except``
    An ``except`` whose body is only ``pass`` (or ``...``) swallows the
    error with no trace — in a fault-tolerant runtime every discarded
    exception is a recovery decision and must be visible (retry it,
    count it, log it, or re-raise). Deliberate best-effort cleanup
    paths carry a waiver comment explaining why discarding is correct.

``src.unjoined-process``
    A module that calls ``Process(...).start()`` without any
    ``.join()``/``.terminate()``/``.kill()`` call anywhere in the file
    has no supervised shutdown path — on error the child is orphaned
    (and under spawn it pins shared-memory segments). Fire-and-forget
    helpers that genuinely cannot leak carry a waiver.

Waiving a finding: append ``# lint: waive=<rule-id>`` to the flagged
line (comma-separate several ids; ``all`` waives every rule). Waivers
are for documented one-off fallback paths — e.g. the scratch-buffer
allocation a function performs only when the caller didn't supply one.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis.jaxpr import Finding

# hot view-path functions, keyed by path relative to the repro package;
# values are qualnames (Class.method for methods)
HOT_FUNCTIONS: Dict[str, Set[str]] = {
    "core/subgraph.py": {
        "bfs_layers", "bfs_layers_fresh", "stamped_in_edges",
        "_expand_frontier", "fill_khop_masks",
    },
    "core/views.py": {
        "ViewBuilder.khop_view", "ViewBuilder.cluster_view",
        "ViewBuilder.khop_compact", "ViewBuilder.cluster_compact",
        "ClusterViewCache.compose", "CompactBlockBuilder.stage",
        "_fill_compact_block",
    },
}

MEMBERSHIP_SCANS = {"isin", "union1d", "setdiff1d", "intersect1d"}
ALLOC_FUNCS = {"zeros", "ones", "full", "empty"}
SIZE_ATTRS = {"num_nodes", "num_edges"}

_WAIVE_RE = re.compile(r"#\s*lint:\s*waive=([\w.,\-]+)")


def _waivers(source: str) -> Dict[int, Set[str]]:
    """lineno -> waived rule ids, from ``# lint: waive=...`` comments."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVE_RE.search(line)
        if m:
            out[i] = {s.strip() for s in m.group(1).split(",")}
    return out


def _waived(waivers: Dict[int, Set[str]], lineno: int, rule_id: str) -> bool:
    ids = waivers.get(lineno, ())
    return "all" in ids or rule_id in ids or rule_id.split(".", 1)[-1] in ids


class _SizeNames(ast.NodeVisitor):
    """Collect local names assigned from ``<expr>.num_nodes``/``.num_edges``
    (simple and tuple assignments) within one function body."""

    def __init__(self):
        self.names: Set[str] = set()

    @staticmethod
    def _is_size_attr(node) -> bool:
        return isinstance(node, ast.Attribute) and node.attr in SIZE_ATTRS

    def visit_Assign(self, node: ast.Assign):
        targets = node.targets[0] if len(node.targets) == 1 else None
        if (isinstance(targets, ast.Tuple)
                and isinstance(node.value, ast.Tuple)
                and len(targets.elts) == len(node.value.elts)):
            pairs = zip(targets.elts, node.value.elts)
        else:
            pairs = [(t, node.value) for t in node.targets]
        for tgt, val in pairs:
            if isinstance(tgt, ast.Name) and self._is_size_attr(val):
                self.names.add(tgt.id)
        self.generic_visit(node)


def _references_graph_size(node, size_names: Set[str]) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in SIZE_ATTRS:
            return True
        if isinstance(sub, ast.Name) and sub.id in size_names:
            return True
    return False


def _np_call_name(node: ast.Call) -> Optional[str]:
    """'zeros' for ``np.zeros(...)``/``numpy.zeros(...)``, else None."""
    fn = node.func
    if (isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name)
            and fn.value.id in ("np", "numpy")):
        return fn.attr
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, waivers: Dict[int, Set[str]],
                 hot: Set[str]):
        self.rel = rel
        self.waivers = waivers
        self.hot = hot
        self.stack: List[str] = []          # qualname parts
        self.size_names: List[Set[str]] = []   # per enclosing hot fn
        self.findings: List[Finding] = []
        # src.unjoined-process bookkeeping (file-level: Process(...)
        # call sites vs. whether ANY join/terminate/kill path exists)
        self.process_calls: List[int] = []
        self.has_reaper = False

    # -- helpers ----------------------------------------------------------

    def _qualname(self, name: str) -> str:
        return ".".join(self.stack + [name])

    def _in_hot_function(self) -> bool:
        return bool(self.size_names)

    def _emit(self, rule_id: str, lineno: int, message: str):
        if not _waived(self.waivers, lineno, rule_id):
            self.findings.append(Finding(
                rule_id, message, label=self.rel,
                location=f"{self.rel}:{lineno}"))

    # -- scopes -----------------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _visit_function(self, node):
        qn = self._qualname(node.name)
        is_hot = qn in self.hot
        self.stack.append(node.name)
        if is_hot:
            collector = _SizeNames()
            collector.visit(node)
            self.size_names.append(collector.names)
        self.generic_visit(node)
        if is_hot:
            self.size_names.pop()
        self.stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- rules ------------------------------------------------------------

    def visit_Assert(self, node: ast.Assert):
        self._emit(
            "src.bare-assert", node.lineno,
            "bare assert in library code (vanishes under python -O) — "
            "raise ValueError/TypeError with a message instead")
        self.generic_visit(node)

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        body = [n for n in node.body
                if not (isinstance(n, ast.Expr)
                        and isinstance(n.value, ast.Constant)
                        and isinstance(n.value.value, str))]  # docstrings
        silent = all(
            isinstance(n, ast.Pass)
            or (isinstance(n, ast.Expr)
                and isinstance(n.value, ast.Constant)
                and n.value.value is Ellipsis)
            for n in body)
        if silent:
            # a waiver reads most naturally next to the ``pass`` itself,
            # so accept it on the handler line or any body line
            lines = [node.lineno] + [n.lineno for n in node.body]
            if not any(_waived(self.waivers, ln, "src.silent-except")
                       for ln in lines):
                what = (ast.unparse(node.type) if node.type is not None
                        else "everything")
                self._emit(
                    "src.silent-except", node.lineno,
                    f"except {what} with a pass-only body swallows the "
                    "error invisibly — handle it (retry/count/log/raise) "
                    "or waive with a comment saying why discarding is "
                    "correct")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        fn = node.func
        if ((isinstance(fn, ast.Name) and fn.id == "Process")
                or (isinstance(fn, ast.Attribute)
                    and fn.attr == "Process")):
            self.process_calls.append(node.lineno)
        elif (isinstance(fn, ast.Attribute)
                and fn.attr in ("join", "terminate", "kill")):
            self.has_reaper = True
        if self._in_hot_function():
            name = _np_call_name(node)
            if name in MEMBERSHIP_SCANS:
                self._emit(
                    "src.hot-membership-scan", node.lineno,
                    f"np.{name} in a hot view-path function — an O(N) "
                    "membership scan per step; use a stamp/visited "
                    "buffer (or move the call to an oracle function)")
            elif name in ALLOC_FUNCS and _references_graph_size(
                    node, self.size_names[-1]):
                self._emit(
                    "src.hot-full-graph-alloc", node.lineno,
                    f"np.{name} of a full-graph-sized array in a hot "
                    "view-path function — allocate once (builder "
                    "scratch) and reuse per step")
        self.generic_visit(node)


def lint_source(source: str, rel: str,
                hot: Optional[Set[str]] = None) -> List[Finding]:
    """Lint one module's source; ``rel`` keys the hot-function config."""
    if hot is None:
        hot = HOT_FUNCTIONS.get(rel, set())
    tree = ast.parse(source)
    linter = _Linter(rel, _waivers(source), hot)
    linter.visit(tree)
    if not linter.has_reaper:
        for lineno in linter.process_calls:
            linter._emit(
                "src.unjoined-process", lineno,
                "Process(...) spawned in a file with no join()/"
                "terminate()/kill() anywhere — no supervised shutdown "
                "path; children orphan on error (add a close() that "
                "joins with escalation, or waive if the process cannot "
                "outlive its work)")
    return linter.findings


def lint_file(path: Path, root: Path,
              hot: Optional[Set[str]] = None) -> List[Finding]:
    rel = path.relative_to(root).as_posix()
    return lint_source(path.read_text(), rel, hot=hot)


def lint_tree(root) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (the ``repro`` package dir)."""
    root = Path(root)
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        findings.extend(lint_file(path, root))
    return findings
