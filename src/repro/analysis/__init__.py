"""Static analysis over traced jaxprs, Pallas launch geometry, and source.

Three analyzers share one :class:`Rule` registry and :class:`Finding`
vocabulary:

- :mod:`repro.analysis.jaxpr` — contract rules over traced jaxprs
  (pre-gather / segment-scatter / backward-gather on the csc path,
  O(view) compact steps, f64 drift, host transfers, buffer donation);
- :mod:`repro.analysis.vmem` — per-launch VMEM residency reconstructed
  from every ``pallas_call``'s grid/BlockSpecs against a budget;
- :mod:`repro.analysis.srclint` — AST lint (bare asserts, per-step
  O(N) work in the hot view path).

``python -m repro.analysis --strict`` traces the model zoo across
strategies and backends, runs everything, and exits nonzero on any
finding — the CI gate.
"""
from repro.analysis.jaxpr import (ContractError, Finding, JaxprContext,
                                  Rule, RULES, check_or_raise,
                                  count_segment_scatters, jaxpr_avals,
                                  jaxpr_eqns, register, rule, run_rules)
from repro.analysis.srclint import lint_file, lint_source, lint_tree
from repro.analysis.vmem import (DEFAULT_VMEM_BUDGET, KernelStats,
                                 analyze_pallas_eqn, check_vmem,
                                 iter_kernel_stats)

__all__ = [
    "ContractError", "Finding", "JaxprContext", "Rule", "RULES",
    "check_or_raise", "count_segment_scatters", "jaxpr_avals",
    "jaxpr_eqns", "register", "rule", "run_rules",
    "lint_file", "lint_source", "lint_tree",
    "DEFAULT_VMEM_BUDGET", "KernelStats", "analyze_pallas_eqn",
    "check_vmem", "iter_kernel_stats",
]
