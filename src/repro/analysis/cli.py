"""``python -m repro.analysis`` — the static-analysis gate.

Traces the model zoo across aggregation backends and trainers, runs
every applicable registry rule over the jaxprs, reconstructs VMEM
residency for every Pallas launch, lints the source tree, and emits a
text (and optionally JSON) report. ``--strict`` exits nonzero on any
error finding — the CI contract.

The smoke matrix (default, fast-lane friendly):

- combine-level value_and_grad jaxprs for all four combine modes on the
  csc backend — the exact Sum-stage contract (pregather +
  segment-scatter + backward-gather);
- one engine train-step + infer trace per zoo model x backend
  (reference, csc) via :meth:`Trainer.traced_step_jaxpr` — f64 drift,
  host transfers, donation accounting, VMEM, and (csc) pre-gather;
- CompactTrainer bucketed steps over compact mini + cluster views — the
  O(view) full-graph-aval contract per touched bucket;
- srclint over the installed ``repro`` package.

``--full`` widens the trainer sweep to every strategy's staged view and
adds the sequence kernels (flash attention, wkv6) to the VMEM walk —
the nightly lane.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

import numpy as np

from repro.analysis.jaxpr import Finding, JaxprContext, run_rules
from repro.analysis.srclint import lint_tree
from repro.analysis.vmem import DEFAULT_VMEM_BUDGET, iter_kernel_stats

MODELS = ("gcn", "sage", "sage_max", "gat")
BACKENDS = ("reference", "csc")
COMBINE_MODES = ("sum", "mean", "max", "softmax")

# rule subsets per context kind. Combine-level losses are the exact
# Sum-stage contract; model-level train steps legitimately gather and
# scatter the edge axis in NN-Gather, so there the scatter/gather rules
# stay off and pregather (which stays exact) + the step-hygiene rules
# run. Compact steps add the O(view) aval contract.
COMBINE_RULES = ("jaxpr.pregather", "jaxpr.segment-scatter",
                 "jaxpr.backward-gather", "jaxpr.f64-promotion",
                 "vmem.budget")
TRAIN_RULES = ("jaxpr.pregather", "jaxpr.f64-promotion",
               "jaxpr.host-transfer", "jaxpr.donation", "vmem.budget")
INFER_RULES = ("jaxpr.f64-promotion", "jaxpr.host-transfer",
               "vmem.budget")
COMPACT_RULES = ("jaxpr.full-graph-aval", "jaxpr.f64-promotion",
                 "jaxpr.host-transfer", "vmem.budget")


def _graph(n=220, seed=0):
    from repro.graph import sbm_graph
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8,
                     p_in=0.05, p_out=0.005, seed=seed).add_self_loops()


class Report:
    def __init__(self, budget: int):
        self.budget = budget
        self.findings: List[Finding] = []
        self.contexts = 0
        self.kernels: List[dict] = []
        self.lint_files = 0

    def run(self, ctx: JaxprContext, ids) -> None:
        self.contexts += 1
        self.findings.extend(run_rules(ctx, ids=ids))
        for stats in iter_kernel_stats(ctx.closed_jaxpr):
            self.kernels.append(dict(stats.to_json(), label=ctx.label))

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def to_json(self) -> dict:
        return {
            "budget_bytes": self.budget,
            "contexts_traced": self.contexts,
            "lint_files": self.lint_files,
            "findings": [f.to_json() for f in self.findings],
            "kernels": self.kernels,
        }


def check_combine_modes(report: Report, interpret: bool = True) -> None:
    """value_and_grad jaxprs of combine-level losses on the csc backend:
    the exact Sum-stage contract, all four combine modes."""
    import jax
    import jax.numpy as jnp
    from repro.core.aggregate import combine
    from repro.kernels.ops import build_csc_plan

    rng = np.random.default_rng(7)
    E, N, H, D = 400, 90, 2, 8
    ids = rng.integers(0, N // 2, E).astype(np.int32)
    value = jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32)
    logit = jnp.asarray(rng.normal(size=(E, H)), jnp.float32)
    mask = jnp.asarray(rng.random(E) > 0.3, jnp.float32)
    dst = jnp.asarray(ids)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)

    for mode in COMBINE_MODES:
        def loss(value, logit, _mode=mode):
            out = combine(_mode, {"value": value, "logit": logit}, dst,
                          N, mask, backend="csc", plan=plan)
            return jnp.sum(jnp.sin(out) * out)

        jx = jax.make_jaxpr(jax.value_and_grad(loss, argnums=(0, 1)))(
            value, logit)
        report.run(JaxprContext(jx, label=f"combine:{mode}", plan=plan,
                                vmem_budget=report.budget),
                   ids=COMBINE_RULES)


def check_trainers(report: Report, full: bool = False) -> None:
    """One Trainer per zoo model x backend: train-step + infer jaxprs."""
    from repro.config import GNNConfig
    from repro.core.clustering import label_propagation_clusters
    from repro.core.engine import HybridParallelEngine
    from repro.core.partition import build_partitions
    from repro.core.strategies import strategy_views
    from repro.core.trainer import Trainer
    from repro.models import make_gnn
    from repro.optim import adam

    g = _graph()
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    strategies = ("global", "mini", "cluster") if full else ("global",)
    for model_name in MODELS:
        for backend in BACKENDS:
            cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=16,
                            num_classes=4, feature_dim=8,
                            aggregate_backend=backend)
            engine = HybridParallelEngine(make_gnn(cfg),
                                          build_partitions(g, 1))
            trainer = Trainer(engine, adam(1e-2), seed=0)
            plan = engine._csc_meta if backend == "csc" else None
            for strategy in strategies:
                view = next(iter(strategy_views(
                    g, strategy, K=2, seed=0, steps=1, batch_nodes=24,
                    clusters=clusters, clusters_per_batch=2)))
                label = f"train:{model_name}/{backend}/{strategy}"
                jx = trainer.traced_step_jaxpr(view)
                report.run(JaxprContext(
                    jx, label=label, plan=plan,
                    expect_donated=trainer.expected_donated,
                    vmem_budget=report.budget), ids=TRAIN_RULES)
            view = next(iter(strategy_views(g, "global", K=2, steps=1)))
            jx = trainer.traced_infer_jaxpr(view)
            report.run(JaxprContext(
                jx, label=f"infer:{model_name}/{backend}",
                vmem_budget=report.budget), ids=INFER_RULES)


def check_compact_buckets(report: Report, full: bool = False) -> None:
    """CompactTrainer bucketed steps: the O(view) aval contract per
    touched bucket, for both backends."""
    from repro.config import GNNConfig
    from repro.core.clustering import label_propagation_clusters
    from repro.core.strategies import strategy_views
    from repro.core.trainer import CompactTrainer
    from repro.models import make_gnn
    from repro.optim import adam

    g = _graph()
    N, E = g.num_nodes, g.num_edges
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    backends = BACKENDS if full else ("csc",)
    for backend in backends:
        cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                        num_classes=4, feature_dim=8,
                        aggregate_backend=backend)
        trainer = CompactTrainer(make_gnn(cfg), g, adam(1e-2), seed=0)
        view_sets = [
            ("mini", strategy_views(g, "mini", K=2, seed=0, steps=2,
                                    batch_nodes=24, neighbor_cap=4,
                                    compact=True)),
            ("cluster", strategy_views(g, "cluster", K=2, seed=0, steps=2,
                                       clusters=clusters,
                                       clusters_per_batch=2,
                                       compact=True)),
        ]
        for strategy, views in view_sets:
            for i, view in enumerate(views):
                jx = trainer.traced_step_jaxpr(view)
                # a bucket pad that happens to equal the full graph's N
                # or E is not a full-graph tensor — exempt the collision
                # (and surface it in the label so reports show it)
                staged = trainer.stager.stage(view)
                pads = (int(staged.x.shape[0]), int(staged.src.shape[0]))
                exempt = tuple(d for d in pads if d in (N, E))
                report.run(JaxprContext(
                    jx, label=f"compact:{backend}/{strategy}[{i}]",
                    graph_shape=(N, E), exempt_dims=exempt,
                    vmem_budget=report.budget), ids=COMPACT_RULES)


def check_serving(report: Report, full: bool = False) -> None:
    """GNNServer infer paths: the full K-hop step and the cache-hit
    1-hop step obey the same O(view) aval contract as compact training
    (a serving step must never close over full-graph tensors)."""
    import jax
    from repro.config import GNNConfig
    from repro.models import make_gnn
    from repro.serving import GNNServer

    g = _graph()
    N, E = g.num_nodes, g.num_edges
    backends = BACKENDS if full else ("csc",)
    targets = np.arange(0, 24, 2)
    for backend in backends:
        cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                        num_classes=4, feature_dim=8,
                        aggregate_backend=backend)
        model = make_gnn(cfg)
        server = GNNServer(model, model.init(jax.random.PRNGKey(0), 8), g)
        for name, step, builder, stager in (
                ("full", server._full_step, server._builder,
                 server._stager),
                ("hit", server._hit_step, server._hit_builder,
                 server._hit_stager)):
            view = builder.khop_compact(targets)
            block = jax.tree_util.tree_map(np.array, stager.stage(view))
            jx = step.jaxpr(server.params, block)
            pads = (int(block.x.shape[0]), int(block.src.shape[0]))
            exempt = tuple(d for d in pads if d in (N, E))
            report.run(JaxprContext(
                jx, label=f"serving:{backend}/{name}",
                graph_shape=(N, E), exempt_dims=exempt,
                vmem_budget=report.budget), ids=COMPACT_RULES)


def check_sequence_kernels(report: Report) -> None:
    """--full only: the sequence kernels' launch geometry (flash
    attention, wkv6) against the VMEM budget."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import flash_attention_op, wkv6_op

    B, T, H, D = 1, 256, 4, 64
    rng = np.random.default_rng(3)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = mk(B, T, H, D), mk(B, T, H, D), mk(B, T, H, D)
    jx = jax.make_jaxpr(
        lambda q, k, v: flash_attention_op(q, k, v, causal=True))(q, k, v)
    report.run(JaxprContext(jx, label="kernel:flash_attention",
                            vmem_budget=report.budget),
               ids=("jaxpr.f64-promotion", "vmem.budget"))
    w, u = mk(B, T, H, D) * 0.1 + 0.9, mk(H, D)
    jx = jax.make_jaxpr(
        lambda r, k, v, w, u: wkv6_op(r, k, v, w, u))(q, k, v, w, u)
    report.run(JaxprContext(jx, label="kernel:wkv6",
                            vmem_budget=report.budget),
               ids=("jaxpr.f64-promotion", "vmem.budget"))


def check_srclint(report: Report, root: Optional[str] = None) -> None:
    if root is None:
        import repro
        # namespace-package safe: __path__ always holds the package dir
        root = next(iter(repro.__path__))
    root = Path(root)
    report.lint_files = len(list(root.rglob("*.py")))
    report.findings.extend(lint_tree(root))


def run_analysis(strict: bool = False, full: bool = False,
                 budget: int = DEFAULT_VMEM_BUDGET,
                 json_path: Optional[str] = None,
                 lint_root: Optional[str] = None,
                 out=print) -> int:
    report = Report(budget)
    out(f"repro.analysis: budget {budget / 2**20:.1f} MiB, "
        f"{'full' if full else 'smoke'} matrix")
    check_combine_modes(report)
    out(f"  combine contracts: {len(COMBINE_MODES)} modes traced")
    check_trainers(report, full=full)
    check_compact_buckets(report, full=full)
    check_serving(report, full=full)
    out(f"  trainer/compact/serving traces: {report.contexts} "
        f"jaxpr contexts")
    if full:
        check_sequence_kernels(report)
    check_srclint(report, root=lint_root)
    out(f"  srclint: {report.lint_files} files")
    out(f"  pallas launches analyzed: {len(report.kernels)}")

    if json_path:
        Path(json_path).write_text(json.dumps(report.to_json(), indent=2))
        out(f"  json report -> {json_path}")

    errors = report.errors
    if not report.findings:
        out(f"OK: 0 findings over {report.contexts} traced contexts")
    else:
        for f in report.findings:
            out(f.render())
        out(f"{len(report.findings)} findings "
            f"({len(errors)} errors) over {report.contexts} contexts")
    return 1 if (strict and errors) else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis over traced jaxprs, Pallas launch "
                    "geometry, and the repro source tree")
    p.add_argument("--strict", action="store_true",
                   help="exit nonzero on any error finding (the CI gate)")
    p.add_argument("--full", action="store_true",
                   help="widen to every strategy and the sequence kernels")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the JSON report here")
    p.add_argument("--budget-mib", type=float,
                   default=DEFAULT_VMEM_BUDGET / 2**20,
                   help="per-launch VMEM budget in MiB (default 16)")
    p.add_argument("--lint-root", default=None,
                   help="package dir to lint (default: installed repro)")
    args = p.parse_args(argv)
    return run_analysis(strict=args.strict, full=args.full,
                        budget=int(args.budget_mib * 2**20),
                        json_path=args.json, lint_root=args.lint_root)


if __name__ == "__main__":
    sys.exit(main())
