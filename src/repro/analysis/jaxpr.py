"""Jaxpr rule registry: the contract asserts of ``kernels/ops.py``, generalized.

The repo's memory/fusion invariants (no pre-gathered message tensor, no
reference segment scatter on the csc path, O(view) compact steps, ...)
used to live as one-off ``assert`` helpers scattered through
``kernels/ops.py`` and the trainers. This module turns them into a
:class:`Rule` registry over traced jaxprs: every rule walks the same
generalized :func:`jaxpr_eqns` iterator, produces :class:`Finding`
records (rule id, severity, location), and is runnable from tests, the
benches, and the ``python -m repro.analysis`` CI gate alike.

Rule catalog (jaxpr family):

=======================  ====================================================
``jaxpr.pregather``      no ``(nb, L_pad, ...)`` float aval — the pre-gathered
                         message layout the fused kernels eliminated
``jaxpr.segment-scatter``no scatter primitive whose updates carry the plan's
                         edge axis (a reference ``jax.ops.segment_*`` call)
``jaxpr.backward-gather``no ``(N, ...) -> (E, ...)`` gather outside the
                         kernels (the old ``g[segment_ids]`` backward)
``jaxpr.full-graph-aval``no full-graph-shaped ``(N_full, ...)``/``(E_full,
                         ...)`` float aval inside a bucketed compact step —
                         PR 6's O(view) memory claim, machine-checked
``jaxpr.f64-promotion``  no float64 aval anywhere (dtype-promotion drift)
``jaxpr.host-transfer``  no host<->device transfer / callback primitive
                         inside the jitted step
``jaxpr.donation``       the staged view buffers are donated exactly as the
                         trainer promised (``donated_invars`` of the step's
                         pjit equation)
=======================  ====================================================

``vmem.budget`` (Pallas launch geometry) registers itself from
:mod:`repro.analysis.vmem`; the source lint lives in
:mod:`repro.analysis.srclint`.

The legacy helpers (``assert_pregather_free`` / ``assert_sum_stage_fused``
/ ``count_segment_scatters``) survive as thin shims in ``kernels/ops.py``
delegating here and raising :class:`ContractError` — an
``AssertionError`` subclass, so existing ``pytest.raises(AssertionError)``
callers keep passing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp


class ContractError(AssertionError):
    """A registry rule found a violation in assert-mode (the shim API)."""


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One rule violation: what rule, where, and what was seen."""
    rule: str                    # registry id, e.g. "jaxpr.pregather"
    message: str                 # human-readable description of the hit
    severity: str = "error"      # "error" | "warning"
    label: str = ""              # which traced computation was analyzed
    location: str = ""           # eqn/aval/source location when known

    def render(self) -> str:
        where = f" [{self.label}]" if self.label else ""
        loc = f" ({self.location})" if self.location else ""
        return f"{self.severity}: {self.rule}{where}: {self.message}{loc}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "label": self.label, "message": self.message,
                "location": self.location}


# ---------------------------------------------------------------------------
# the generalized jaxpr walker (version-robust across jax releases)
# ---------------------------------------------------------------------------


def _jaxpr_classes() -> Tuple[tuple, tuple]:
    """(ClosedJaxpr types, Jaxpr types) across jax versions.

    Newer jax exposes the public copies under ``jax.extend.core`` and
    deprecates (then removes) the ``jax.core`` names; older releases only
    have ``jax.core``. Collect every importable variant so isinstance
    checks hold whichever module produced the object.
    """
    closed, plain = [], []
    for modname in ("jax.extend.core", "jax.core"):
        try:
            import importlib
            mod = importlib.import_module(modname)
        except ImportError:
            continue
        for name, bucket in (("ClosedJaxpr", closed), ("Jaxpr", plain)):
            cls = getattr(mod, name, None)
            if isinstance(cls, type) and cls not in bucket:
                bucket.append(cls)
    return tuple(closed), tuple(plain)


_CLOSED_TYPES, _JAXPR_TYPES = _jaxpr_classes()


def _as_jaxpr(obj):
    """Duck-typed unwrap: ClosedJaxpr-like -> Jaxpr-like -> None."""
    if isinstance(obj, _JAXPR_TYPES):
        return obj
    if isinstance(obj, _CLOSED_TYPES):
        return obj.jaxpr
    # fallback for versions whose classes import from neither module:
    # anything with .eqns is jaxpr-like; anything wrapping one is closed
    if hasattr(obj, "eqns"):
        return obj
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return None


def jaxpr_eqns(closed_jaxpr, skip_pallas_bodies: bool = False):
    """Yield every equation, recursing into sub-jaxprs (pjit bodies,
    custom_vjp calls, scans, pallas kernel bodies ...) — including the
    VJP jaxprs ``jax.grad``/``jax.value_and_grad`` splice in, so the
    fused-path contracts certify the backward pass too.

    ``skip_pallas_bodies`` stops the recursion at ``pallas_call``
    equations: the gather/scatter fallback checks must not flag the
    kernels' own on-chip block gathers (whose tile shapes can collide
    with the edge/segment dims, e.g. when E == block_e).
    """
    root = _as_jaxpr(closed_jaxpr)
    stack = [root] if root is not None else []
    while stack:
        jaxpr = stack.pop()
        for eqn in jaxpr.eqns:
            yield eqn
            if skip_pallas_bodies and eqn.primitive.name == "pallas_call":
                continue
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (tuple, list))
                            else (val,)):
                    inner = None
                    if isinstance(sub, (str, bytes, int, float, bool,
                                        type(None))):
                        continue
                    inner = _as_jaxpr(sub)
                    if inner is not None:
                        stack.append(inner)


def jaxpr_avals(closed_jaxpr):
    """Yield the output aval of every equation, recursing into sub-jaxprs."""
    for eqn in jaxpr_eqns(closed_jaxpr):
        for var in eqn.outvars:
            yield var.aval


def pallas_src(eqn) -> str:
    """Best-effort kernel source location of a ``pallas_call`` equation."""
    info = eqn.params.get("name_and_src_info")
    return str(info) if info is not None else eqn.primitive.name


# ---------------------------------------------------------------------------
# rule framework
# ---------------------------------------------------------------------------


@dataclass
class JaxprContext:
    """Everything a jaxpr rule may need about one traced computation.

    Optional fields gate rules: a rule requiring ``plan`` (the CSC
    contracts) silently skips contexts without one, and so on — so one
    ``run_rules`` call over a context runs exactly the applicable subset.
    """
    closed_jaxpr: object
    label: str = ""
    # CSC-plan contracts (pregather / segment-scatter / backward-gather)
    plan: Optional[object] = None            # kernels.ops.CSCPlan
    # compact-step O(view) contract: the FULL graph's (N, E); dims that
    # legitimately appear (e.g. a bucket pad that collides) go in exempt
    graph_shape: Optional[Tuple[int, int]] = None
    exempt_dims: Tuple[int, ...] = ()
    # donation contract: how many invars of the step's pjit equation must
    # be donated (None = not checked for this context)
    expect_donated: Optional[int] = None
    # VMEM budget for pallas_call launches (bytes)
    vmem_budget: int = 16 * 1024 * 1024
    extra: dict = field(default_factory=dict)


@dataclass(frozen=True)
class Rule:
    id: str
    description: str
    check: Callable[[JaxprContext], List[Finding]]


RULES: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if rule.id in RULES:
        raise ValueError(f"duplicate rule id {rule.id!r}")
    RULES[rule.id] = rule
    return rule


def rule(id: str, description: str):
    """Decorator: register ``fn(ctx) -> [Finding, ...]`` under ``id``."""
    def wrap(fn):
        register(Rule(id, description, fn))
        return fn
    return wrap


def run_rules(ctx: JaxprContext,
              ids: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the selected rules (default: all registered) over one context."""
    selected = list(RULES.values()) if ids is None else [
        RULES[i] for i in ids]
    findings: List[Finding] = []
    for r in selected:
        findings.extend(r.check(ctx))
    return findings


def check_or_raise(findings: List[Finding]) -> None:
    """Shim helper: raise :class:`ContractError` on any error finding."""
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        raise ContractError("\n".join(f.render() for f in errors))


# ---------------------------------------------------------------------------
# ported CSC-plan contracts (from kernels/ops.py)
# ---------------------------------------------------------------------------


_SCATTER_PRIMS = ("scatter", "scatter-add", "scatter-max", "scatter-min",
                  "scatter-mul")


def _is_segment_scatter(eqn, num_edges: int) -> bool:
    """A scatter whose updates carry the plan's edge axis — the signature
    of a reference ``jax.ops.segment_*`` call (forward or transpose)."""
    if eqn.primitive.name not in _SCATTER_PRIMS:
        return False
    upd = tuple(getattr(eqn.invars[-1].aval, "shape", ()))
    return bool(upd) and upd[0] == num_edges


def count_segment_scatters(closed_jaxpr, plan) -> int:
    """Number of scatter equations whose updates carry the plan's edge
    axis. On model-level jaxprs this can't distinguish a Sum-stage
    fallback from the legitimate NN-Gather transpose, so the end-to-end
    certificate compares the count across backends (csc strictly below
    reference) while the combine-level rules demand zero."""
    return sum(_is_segment_scatter(eqn, plan.num_edges)
               for eqn in jaxpr_eqns(closed_jaxpr, skip_pallas_bodies=True))


@rule("jaxpr.pregather",
      "no (nb, L_pad, ...) float aval — the pre-gathered message layout "
      "the fused kernels eliminated")
def _check_pregather(ctx: JaxprContext) -> List[Finding]:
    if ctx.plan is None:
        return []
    nb, l_pad = ctx.plan.gather_idx.shape[-2:]
    findings = []
    for aval in jaxpr_avals(ctx.closed_jaxpr):
        shape = tuple(getattr(aval, "shape", ()))
        if len(shape) < 2 or shape[:2] != (nb, l_pad):
            continue
        pregather = len(shape) >= 3 or jnp.issubdtype(
            getattr(aval, "dtype", jnp.int32), jnp.floating)
        if pregather:
            findings.append(Finding(
                "jaxpr.pregather",
                f"pre-gathered message tensor {shape} found in jaxpr "
                f"(plan: nb={nb}, L_pad={l_pad})", label=ctx.label))
    return findings


@rule("jaxpr.segment-scatter",
      "no scatter primitive with edge-axis updates on the csc path (a "
      "reference jax.ops.segment_* fallback)")
def _check_segment_scatter(ctx: JaxprContext) -> List[Finding]:
    if ctx.plan is None:
        return []
    E = ctx.plan.num_edges
    findings = []
    for eqn in jaxpr_eqns(ctx.closed_jaxpr, skip_pallas_bodies=True):
        if _is_segment_scatter(eqn, E):
            findings.append(Finding(
                "jaxpr.segment-scatter",
                f"reference segment scatter ({eqn.primitive.name}) found "
                f"on the csc path (E={E})", label=ctx.label))
    return findings


@rule("jaxpr.backward-gather",
      "no (N, ...) -> (E, ...) gather outside the kernels (the old "
      "g[segment_ids] reference backward)")
def _check_backward_gather(ctx: JaxprContext) -> List[Finding]:
    if ctx.plan is None:
        return []
    E, N = ctx.plan.num_edges, ctx.plan.num_segments
    findings = []
    for eqn in jaxpr_eqns(ctx.closed_jaxpr, skip_pallas_bodies=True):
        if eqn.primitive.name != "gather":
            continue
        src = tuple(getattr(eqn.invars[0].aval, "shape", ()))
        out = tuple(getattr(eqn.outvars[0].aval, "shape", ()))
        if out and src and out[0] == E and src[0] == N:
            findings.append(Finding(
                "jaxpr.backward-gather",
                f"reference backward gather ({src} -> {out}) found on "
                f"the csc path (E={E}, N={N})", label=ctx.label))
    return findings


# ---------------------------------------------------------------------------
# new rules
# ---------------------------------------------------------------------------


@rule("jaxpr.full-graph-aval",
      "no full-graph-shaped (N, ...)/(E, ...) float aval inside a "
      "bucketed compact step (the O(view) memory contract)")
def _check_full_graph_aval(ctx: JaxprContext) -> List[Finding]:
    if ctx.graph_shape is None:
        return []
    forbidden = {d for d in ctx.graph_shape if d not in ctx.exempt_dims}
    if not forbidden:
        return []
    findings = []
    for aval in jaxpr_avals(ctx.closed_jaxpr):
        shape = tuple(getattr(aval, "shape", ()))
        if not shape or shape[0] not in forbidden:
            continue
        if not jnp.issubdtype(getattr(aval, "dtype", jnp.int32),
                              jnp.floating):
            continue
        findings.append(Finding(
            "jaxpr.full-graph-aval",
            f"full-graph-shaped float aval {shape} inside a compact "
            f"step (graph N, E = {ctx.graph_shape}) — device memory "
            "must scale with the view, not the graph", label=ctx.label))
    return findings


@rule("jaxpr.f64-promotion",
      "no float64 aval anywhere in the step (dtype-promotion drift)")
def _check_f64(ctx: JaxprContext) -> List[Finding]:
    findings = []
    for eqn in jaxpr_eqns(ctx.closed_jaxpr):
        for var in eqn.outvars:
            dtype = getattr(var.aval, "dtype", None)
            if dtype is not None and str(dtype) == "float64":
                findings.append(Finding(
                    "jaxpr.f64-promotion",
                    f"float64 aval {tuple(var.aval.shape)} produced by "
                    f"'{eqn.primitive.name}' — a weak f64 constant or "
                    "np.float64 scalar is promoting the compute dtype",
                    label=ctx.label))
                break       # one finding per equation is enough
    return findings


_TRANSFER_PRIMS = frozenset({
    "device_put", "copy_to_host_async", "pure_callback", "io_callback",
    "debug_callback", "callback", "infeed", "outfeed",
})


@rule("jaxpr.host-transfer",
      "no host<->device transfer or callback primitive inside the "
      "jitted train step")
def _check_host_transfer(ctx: JaxprContext) -> List[Finding]:
    findings = []
    for eqn in jaxpr_eqns(ctx.closed_jaxpr):
        if eqn.primitive.name in _TRANSFER_PRIMS:
            findings.append(Finding(
                "jaxpr.host-transfer",
                f"host-transfer primitive '{eqn.primitive.name}' inside "
                "the jitted step — every step pays a host sync",
                label=ctx.label))
    return findings


@rule("jaxpr.donation",
      "the staged view buffers are donated exactly as promised "
      "(donated_invars of the step's pjit equation)")
def _check_donation(ctx: JaxprContext) -> List[Finding]:
    if ctx.expect_donated is None:
        return []
    # the traced step is itself jitted, so the outermost equation(s) are
    # pjit calls carrying donated_invars; sum over them
    donated = None
    root = _as_jaxpr(ctx.closed_jaxpr)
    for eqn in (root.eqns if root is not None else ()):
        flags = eqn.params.get("donated_invars")
        if flags is not None:
            donated = (donated or 0) + sum(bool(f) for f in flags)
    if donated is None:
        return [Finding(
            "jaxpr.donation",
            "no pjit equation with donated_invars found — trace the "
            "jitted step itself (jax.make_jaxpr(trainer._step))",
            label=ctx.label)]
    if donated != ctx.expect_donated:
        return [Finding(
            "jaxpr.donation",
            f"{donated} invars donated, expected {ctx.expect_donated} "
            "(the staged view buffers must be donated on accelerator "
            "backends and not on cpu)", label=ctx.label)]
    return []
