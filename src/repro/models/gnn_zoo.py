"""GNN models expressed as MPGNN/TGAR layers.

Each factory returns a :class:`TGARLayer` whose Proj/Prop/Agg functions map
onto the paper's Algorithm 1:

- ``gcn_layer``   — GCN (Kipf & Welling): Proj = W·h, Prop = L(i,j)·n_j,
  Agg = Σ (the spectral-equivalence construction of paper App. A.1).
- ``sage_layer``  — GraphSAGE mean aggregator: Prop = n_j, Agg = mean,
  Apy = ReLU([h ; M]·W).
- ``gat_layer``   — GAT: Prop computes attention logits from (n_i, n_j),
  Agg = softmax-weighted Σ (paper App. C uses this model).
- ``gat_e_layer`` — GAT-E, the paper's in-house model (§5.2.2): edge
  attributes join the attention logit and the message value — a simplified
  GIPA. This is the model used for the Alipay-like benchmark.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tgar import TGARLayer
from repro.nn.layers import _fan_in_init, dense_init, dense_apply


def _leaky_relu(x, slope=0.2):
    return jnp.where(x > 0, x, slope * x)


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------


def gcn_layer(in_dim: int, out_dim: int, activation: bool = True,
              name: str = "gcn") -> TGARLayer:
    def init(key):
        return dense_init(key, in_dim, out_dim, use_bias=True)

    def transform(p, h):                       # Proj_k: n = h W
        return {"n": h @ p["w"]}

    def gather(p, n_src, n_dst, edge_attr, edge_w, edge_mask):
        # Prop_k: m_{j->i} = L(i,j) * n_j   (edge_w carries the GCN norm)
        return {"value": (n_src["n"] * edge_w[:, None])[:, None, :]}

    def apply(p, h, M):                        # Apy_k
        out = M[:, 0, :] + p["b"]
        return jax.nn.relu(out) if activation else out

    return TGARLayer(name, init, transform, gather, apply,
                     combine="sum", out_dim=out_dim, heads=1)


# ---------------------------------------------------------------------------
# GraphSAGE (mean)
# ---------------------------------------------------------------------------


def sage_layer(in_dim: int, out_dim: int, activation: bool = True,
               name: str = "sage", aggregate: str = "mean") -> TGARLayer:
    """GraphSAGE with a pluggable neighbor aggregator: ``aggregate`` is any
    non-attention combine mode ("mean" default; "max" = max-pooling SAGE,
    "sum" = GIN-flavored)."""
    if aggregate not in ("mean", "max", "sum"):
        raise ValueError(f"unknown aggregate {aggregate!r}: expected "
                         "'mean', 'max' or 'sum'")

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w_self": dense_init(k1, in_dim, out_dim),
                "w_neigh": dense_init(k2, in_dim, out_dim)}

    def transform(p, h):
        return {"n": h}                        # Proj = identity; W in Apy

    def gather(p, n_src, n_dst, edge_attr, edge_w, edge_mask):
        return {"value": n_src["n"][:, None, :]}

    def apply(p, h, M):
        out = dense_apply(p["w_self"], h) + dense_apply(p["w_neigh"],
                                                        M[:, 0, :])
        return jax.nn.relu(out) if activation else out

    return TGARLayer(name, init, transform, gather, apply,
                     combine=aggregate, out_dim=out_dim, heads=1)


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------


def gat_layer(in_dim: int, out_dim: int, heads: int = 4,
              activation: bool = True, name: str = "gat") -> TGARLayer:
    hd = out_dim // heads
    if hd * heads != out_dim:
        raise ValueError(f"out_dim {out_dim} must be divisible by "
                         f"heads {heads}")

    def init(key):
        ks = jax.random.split(key, 4)
        return {
            "w": _fan_in_init(ks[0], (in_dim, heads * hd), jnp.float32),
            "a_src": _fan_in_init(ks[1], (heads, hd), jnp.float32),
            "a_dst": _fan_in_init(ks[2], (heads, hd), jnp.float32),
            "b": jnp.zeros((out_dim,), jnp.float32),
        }

    def transform(p, h):
        n = (h @ p["w"]).reshape(h.shape[0], heads, hd)
        # per-node halves of the attention logit (computed once per node,
        # not per edge — the paper's NN-T stage owns node-local math)
        return {"n": n,
                "as": jnp.einsum("nhd,hd->nh", n, p["a_src"]),
                "ad": jnp.einsum("nhd,hd->nh", n, p["a_dst"])}

    def gather(p, n_src, n_dst, edge_attr, edge_w, edge_mask):
        logit = _leaky_relu(n_src["as"] + n_dst["ad"])
        return {"logit": logit, "value": n_src["n"]}

    def apply(p, h, M):
        out = M.reshape(M.shape[0], heads * hd) + p["b"]
        return jax.nn.elu(out) if activation else out

    return TGARLayer(name, init, transform, gather, apply,
                     combine="softmax", out_dim=out_dim, heads=heads)


# ---------------------------------------------------------------------------
# GAT-E (edge-attributed attention — the paper's in-house Alipay model)
# ---------------------------------------------------------------------------


def gat_e_layer(in_dim: int, out_dim: int, edge_dim: int, heads: int = 4,
                activation: bool = True, name: str = "gat_e") -> TGARLayer:
    hd = out_dim // heads
    if hd * heads != out_dim:
        raise ValueError(f"out_dim {out_dim} must be divisible by "
                         f"heads {heads}")

    def init(key):
        ks = jax.random.split(key, 6)
        return {
            "w": _fan_in_init(ks[0], (in_dim, heads * hd), jnp.float32),
            "a_src": _fan_in_init(ks[1], (heads, hd), jnp.float32),
            "a_dst": _fan_in_init(ks[2], (heads, hd), jnp.float32),
            "w_e_att": _fan_in_init(ks[3], (edge_dim, heads), jnp.float32),
            "w_e_val": _fan_in_init(ks[4], (edge_dim, heads * hd),
                                    jnp.float32),
            "b": jnp.zeros((out_dim,), jnp.float32),
        }

    def transform(p, h):
        n = (h @ p["w"]).reshape(h.shape[0], heads, hd)
        return {"n": n,
                "as": jnp.einsum("nhd,hd->nh", n, p["a_src"]),
                "ad": jnp.einsum("nhd,hd->nh", n, p["a_dst"])}

    def gather(p, n_src, n_dst, edge_attr, edge_w, edge_mask):
        # edge attributes join both the attention logit and the value
        e_att = edge_attr @ p["w_e_att"]                       # (E, H)
        e_val = (edge_attr @ p["w_e_val"]).reshape(
            edge_attr.shape[0], heads, hd)
        logit = _leaky_relu(n_src["as"] + n_dst["ad"] + e_att)
        return {"logit": logit, "value": n_src["n"] + e_val}

    def apply(p, h, M):
        out = M.reshape(M.shape[0], heads * hd) + p["b"]
        return jax.nn.elu(out) if activation else out

    return TGARLayer(name, init, transform, gather, apply,
                     combine="softmax", out_dim=out_dim, heads=heads)


# ---------------------------------------------------------------------------
# model factory
# ---------------------------------------------------------------------------


def make_gnn(cfg, feature_dim: Optional[int] = None):
    """Build an MPGNNModel from a GNNConfig."""
    from repro.core.mpgnn import MPGNNModel

    f = feature_dim if feature_dim is not None else cfg.feature_dim
    dims = [f] + [cfg.hidden_dim] * cfg.num_layers
    layers = []
    for k in range(cfg.num_layers):
        last = k == cfg.num_layers - 1
        act = not last
        if cfg.model == "gcn":
            layers.append(gcn_layer(dims[k], dims[k + 1], act,
                                    name=f"gcn{k}"))
        elif cfg.model == "sage":
            agg = "mean" if cfg.mean_aggregate else "sum"
            layers.append(sage_layer(dims[k], dims[k + 1], act,
                                     name=f"sage{k}", aggregate=agg))
        elif cfg.model == "sage_max":
            layers.append(sage_layer(dims[k], dims[k + 1], act,
                                     name=f"sage_max{k}", aggregate="max"))
        elif cfg.model == "gat":
            layers.append(gat_layer(dims[k], dims[k + 1], cfg.num_heads,
                                    act, name=f"gat{k}"))
        elif cfg.model == "gat_e":
            layers.append(gat_e_layer(dims[k], dims[k + 1],
                                      cfg.edge_feature_dim, cfg.num_heads,
                                      act, name=f"gat_e{k}"))
        else:
            raise ValueError(f"unknown GNN model {cfg.model!r}")
    return MPGNNModel(tuple(layers), cfg.num_classes,
                      aggregate_backend=cfg.aggregate_backend)
