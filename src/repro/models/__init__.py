from repro.models.gnn_zoo import (
    gcn_layer, sage_layer, gat_layer, gat_e_layer, make_gnn,
)

__all__ = ["gcn_layer", "sage_layer", "gat_layer", "gat_e_layer", "make_gnn"]
