"""Attention blocks: RoPE / M-RoPE, GQA (+sliding window, qk-norm), MLA.

All functions are pure; KV caches are explicit pytrees threaded through
``serve_step``. Softmax is computed in f32 regardless of activation dtype.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_sum import NEG as NEG_INF  # one masking sentinel
from repro.nn.layers import _fan_in_init, rmsnorm_init, rmsnorm_apply

# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies, shape (head_dim//2,) f32."""
    exponents = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return jnp.asarray(1.0 / (theta ** exponents), jnp.float32)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, hd/2)
    ang = ang[..., None, :]                                 # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10000.0,
                sections=(0.25, 0.375, 0.375)):
    """Multimodal RoPE (Qwen2-VL). positions3: (3, ..., S) = (t, h, w) ids.

    The rotary half-dim is split into three contiguous sections, each rotated
    by its own position stream. ``sections`` are fractions of hd//2.
    """
    hd = x.shape[-1]
    half = hd // 2
    s0 = int(round(sections[0] * half))
    s1 = int(round(sections[1] * half))
    sizes = [s0, s1, half - s0 - s1]
    inv = rope_frequencies(hd, theta)                       # (half,)
    parts, off = [], 0
    for i, sz in enumerate(sizes):
        pos = positions3[i][..., None].astype(jnp.float32)  # (..., S, 1)
        parts.append(pos * inv[off:off + sz])
        off += sz
    ang = jnp.concatenate(parts, axis=-1)[..., None, :]     # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def make_attention_bias(q_pos, k_pos, causal: bool, sliding_window: int = 0,
                        k_valid=None):
    """Additive bias (..., Sq, Sk) in f32: 0 allowed / NEG_INF blocked."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = k_pos[..., None, :].astype(jnp.int32)
    allowed = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        allowed = allowed & (kp <= qp)
    if sliding_window:
        allowed = allowed & (kp > qp - sliding_window)
    if k_valid is not None:
        allowed = allowed & k_valid[..., None, :]
    return jnp.where(allowed, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_init(key, d_model, num_heads, num_kv_heads, head_dim,
                   dtype=jnp.float32, qk_norm=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _fan_in_init(ks[0], (d_model, num_heads * head_dim), dtype),
        "wk": _fan_in_init(ks[1], (d_model, num_kv_heads * head_dim), dtype),
        "wv": _fan_in_init(ks[2], (d_model, num_kv_heads * head_dim), dtype),
        "wo": _fan_in_init(ks[3], (num_heads * head_dim, d_model), dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def _sdpa(q, k, v, bias):
    """q: (B,Sq,Hkv,G,hd)  k,v: (B,Sk,Hkv,hd)  bias: (B,1|Hkv,Sq,Sk)->f32."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = scores + bias[:, :, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out


def attention_apply(p, x, *, num_heads, num_kv_heads, head_dim,
                    positions=None, rope_theta=10000.0, qk_norm=False,
                    norm_eps=1e-5, causal=True, sliding_window=0,
                    cache=None, cache_index=None, kv_x=None, kv_positions=None,
                    mrope_positions=None, valid=None):
    """Unified GQA attention.

    - train/prefill: ``cache is None`` — self attention over x.
    - decode: ``cache`` = {"k","v"} (B, S_max, Hkv, hd); new kv written at
      ``cache_index`` (scalar int array); returns (out, new_cache).
    - cross attention: ``kv_x`` given (encoder memory) — no cache, no rope.
    - ``valid``: (B, P) bool — which of the first P cache slots hold real
      (non-pad) tokens. Prefill passes the prompt's pad mask (P = Sq);
      decode keeps passing it so the pad K/Vs that persist in the cache
      stay masked out of every later step's attention.
    """
    B, Sq, _ = x.shape
    G = num_heads // num_kv_heads
    q = (x @ p["wq"]).reshape(B, Sq, num_kv_heads, G, head_dim)
    src = kv_x if kv_x is not None else x
    Sk_new = src.shape[1]
    k = (src @ p["wk"]).reshape(B, Sk_new, num_kv_heads, head_dim)
    v = (src @ p["wv"]).reshape(B, Sk_new, num_kv_heads, head_dim)

    if qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, norm_eps)

    is_cross = kv_x is not None
    if not is_cross:
        if mrope_positions is not None:
            q = apply_mrope(q.reshape(B, Sq, num_heads, head_dim),
                            mrope_positions, rope_theta
                            ).reshape(B, Sq, num_kv_heads, G, head_dim)
            k = apply_mrope(k, mrope_positions, rope_theta)
        elif positions is not None:
            q = apply_rope(q.reshape(B, Sq, num_heads, head_dim),
                           positions, rope_theta
                           ).reshape(B, Sq, num_kv_heads, G, head_dim)
            kpos = kv_positions if kv_positions is not None else positions
            k = apply_rope(k, kpos, rope_theta)

    new_cache = None
    if cache is not None and "pos" in cache:
        # rolling sliding-window cache: W slots, slot = position mod W.
        # Keeps long_500k decode memory O(window) instead of O(seq).
        W = cache["k"].shape[1]
        idx = cache_index
        if Sq > 1:
            # prefill into the rolling cache: attend within the prompt
            # (causal + window), then store only the last W entries.
            q_pos = (idx + jnp.arange(Sq, dtype=jnp.int32))[None, :]
            bias = make_attention_bias(q_pos, q_pos, causal=True,
                                       sliding_window=sliding_window,
                                       k_valid=(None if valid is None
                                                else valid.astype(bool)))
            bias = bias[:, None] if bias.ndim == 3 else bias
            out = _sdpa(q, k, v, bias)
            out = out.reshape(B, Sq, num_heads * head_dim).astype(x.dtype)
            out = out @ p["wo"]
            last = min(W, Sq)
            tail_pos = idx + Sq - last + jnp.arange(last, dtype=jnp.int32)
            slots = jax.lax.rem(tail_pos, W)
            ck = cache["k"].at[:, slots].set(
                k[:, -last:].astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(
                v[:, -last:].astype(cache["v"].dtype))
            cpos = cache["pos"].at[slots].set(tail_pos)
            return out, {"k": ck, "v": cv, "pos": cpos}
        slot = jax.lax.rem(idx, W)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], idx[None].astype(jnp.int32) if idx.ndim == 0
            else idx.astype(jnp.int32), slot, axis=0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        k, v = ck, cv
        q_pos = (idx + jnp.arange(Sq, dtype=jnp.int32))[None, :]
        k_pos = cpos[None, :]
        k_valid = (cpos >= 0)[None, :]
        if valid is not None:
            # map each slot's stored position back to the prompt's pad
            # mask; generated positions (>= P) are always real
            P = valid.shape[1]
            in_prompt = (cpos >= 0) & (cpos < P)
            slot_ok = jnp.where(
                in_prompt[None, :],
                jnp.take(valid.astype(bool), jnp.clip(cpos, 0, P - 1),
                         axis=1),
                True)
            k_valid = k_valid & slot_ok
        bias = make_attention_bias(q_pos, k_pos, causal=True,
                                   sliding_window=sliding_window,
                                   k_valid=k_valid)
        bias = bias[:, None] if bias.ndim == 3 else bias
    elif cache is not None:
        # write the new kv at cache_index, attend over the whole cache
        idx = cache_index
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        S_max = ck.shape[1]
        k_pos = jnp.arange(S_max, dtype=jnp.int32)[None, :]
        q_pos = (idx + jnp.arange(Sq, dtype=jnp.int32))[None, :]
        k_valid = (k_pos <= (idx + Sq - 1))
        if valid is not None:
            # left-pad slots written at prefill stay in the cache; mask
            # them out of this and every later step's attention
            P = valid.shape[1]
            vfull = jnp.ones((B, S_max), bool)
            vfull = vfull.at[:, :P].set(valid.astype(bool))
            k_valid = k_valid & vfull
        bias = make_attention_bias(q_pos, k_pos, causal=True,
                                   sliding_window=sliding_window,
                                   k_valid=k_valid)
        bias = bias[:, None] if bias.ndim == 3 else bias
    elif is_cross:
        bias = jnp.zeros((B, 1, Sq, Sk_new), jnp.float32)
    else:
        q_pos = positions if positions is not None else (
            jnp.arange(Sq, dtype=jnp.int32)[None, :])
        if q_pos.ndim == 1:
            q_pos = q_pos[None, :]
        bias = make_attention_bias(q_pos, q_pos, causal=causal,
                                   sliding_window=sliding_window)
        if bias.ndim == 3:
            bias = bias[:, None, :, :]
        bias = jnp.broadcast_to(bias, (B, 1) + bias.shape[-2:])

    out = _sdpa(q, k, v, bias)
    out = out.reshape(B, Sq, num_heads * head_dim).astype(x.dtype)
    out = out @ p["wo"]
    if cache is not None:
        return out, new_cache
    return out


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def mla_init(key, d_model, num_heads, mla, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    qh = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    return {
        "wq_a": _fan_in_init(ks[0], (d_model, mla.q_lora_rank), dtype),
        "q_a_norm": rmsnorm_init(mla.q_lora_rank, dtype),
        "wq_b": _fan_in_init(ks[1], (mla.q_lora_rank, num_heads * qh), dtype),
        "wkv_a": _fan_in_init(
            ks[2], (d_model, mla.kv_lora_rank + mla.qk_rope_head_dim), dtype),
        "kv_a_norm": rmsnorm_init(mla.kv_lora_rank, dtype),
        "wk_b": _fan_in_init(
            ks[3], (mla.kv_lora_rank, num_heads * mla.qk_nope_head_dim), dtype),
        "wv_b": _fan_in_init(
            ks[4], (mla.kv_lora_rank, num_heads * mla.v_head_dim), dtype),
        "wo": _fan_in_init(ks[5], (num_heads * mla.v_head_dim, d_model), dtype),
    }


def _mla_qkv(p, x, num_heads, mla, positions, rope_theta, norm_eps):
    """Shared projection: returns q_nope, q_rope, c_kv, k_rope."""
    B, S, _ = x.shape
    qh = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    q = rmsnorm_apply(p["q_a_norm"], x @ p["wq_a"], norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, num_heads, qh)
    q_nope = q[..., : mla.qk_nope_head_dim]
    q_rope = q[..., mla.qk_nope_head_dim:]
    kv = x @ p["wkv_a"]
    c_kv = rmsnorm_apply(p["kv_a_norm"], kv[..., : mla.kv_lora_rank], norm_eps)
    k_rope = kv[..., mla.kv_lora_rank:][:, :, None, :]      # shared head
    if positions is not None:
        q_rope = apply_rope(q_rope, positions, rope_theta)
        k_rope = apply_rope(k_rope, positions, rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_apply(p, x, *, num_heads, mla, positions=None, rope_theta=10000.0,
              norm_eps=1e-5, cache=None, cache_index=None, valid=None):
    """MLA attention.

    prefill/train: decompress K/V per head, standard causal attention.
    decode (cache given): *absorbed* formulation — cache holds only
    ``c_kv`` (B,S,kv_rank) + ``k_rope`` (B,S,rope_dim); queries are projected
    into latent space (q_nope @ wk_b per head), attention runs over the
    compressed cache, and the value up-projection is applied after weighting.
    """
    B, Sq, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(
        p, x, num_heads, mla, positions, rope_theta, norm_eps)
    scale = 1.0 / np.sqrt(mla.qk_nope_head_dim + mla.qk_rope_head_dim)

    if cache is None:
        S = Sq
        k_nope = (c_kv @ p["wk_b"]).reshape(B, S, num_heads,
                                            mla.qk_nope_head_dim)
        v = (c_kv @ p["wv_b"]).reshape(B, S, num_heads, mla.v_head_dim)
        pos = positions if positions is not None else (
            jnp.arange(S, dtype=jnp.int32)[None, :])
        if pos.ndim == 1:
            pos = pos[None, :]
        bias = make_attention_bias(pos, pos, causal=True)
        if bias.ndim == 3:
            bias = bias[:, None]
        scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                               k_rope.astype(jnp.float32))) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
        out = out.reshape(B, Sq, num_heads * mla.v_head_dim).astype(x.dtype)
        return out @ p["wo"]

    # ---- absorbed decode over compressed cache ----------------------------
    idx = cache_index
    cc = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx, axis=1)
    new_cache = {"c_kv": cc, "k_rope": cr}
    S_max = cc.shape[1]
    wk_b = p["wk_b"].reshape(mla.kv_lora_rank, num_heads, mla.qk_nope_head_dim)
    # absorb: q_lat (B,Sq,H,kv_rank)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                         cc.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           cr.astype(jnp.float32))) * scale
    k_pos = jnp.arange(S_max, dtype=jnp.int32)[None, :]
    q_pos = (idx + jnp.arange(Sq, dtype=jnp.int32))[None, :]
    k_valid = k_pos <= (idx + Sq - 1)
    if valid is not None:
        # same pad-slot masking as the GQA cache path
        P = valid.shape[1]
        vfull = jnp.ones((B, S_max), bool)
        vfull = vfull.at[:, :P].set(valid.astype(bool))
        k_valid = k_valid & vfull
    bias = make_attention_bias(q_pos, k_pos, causal=True, k_valid=k_valid)
    if bias.ndim == 3:
        bias = bias[:, None]
    probs = jax.nn.softmax(scores + bias, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, cc.astype(jnp.float32))
    wv_b = p["wv_b"].reshape(mla.kv_lora_rank, num_heads, mla.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv_b.astype(jnp.float32))
    out = out.reshape(B, Sq, num_heads * mla.v_head_dim).astype(x.dtype)
    return out @ p["wo"], new_cache
