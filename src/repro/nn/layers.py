"""Hand-rolled NN building blocks (functional: init -> params dict, apply).

No flax/optax in the environment; everything is an explicit pytree of
``jnp`` arrays so that sharding rules can be attached per-leaf by the
launcher (see ``repro.launch.sharding``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def _fan_in_init(key, shape, dtype, scale=1.0):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def glorot(key, shape, dtype):
    fan_in, fan_out = shape[0], shape[-1]
    limit = np.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit).astype(dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def dense_init(key, in_dim, out_dim, dtype=jnp.float32, use_bias=True,
               scale=1.0):
    kw, kb = jax.random.split(key)
    p = {"w": _fan_in_init(kw, (in_dim, out_dim), dtype, scale)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab, dim, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, dim), jnp.float32)
                      * 0.02).astype(dtype)}


def embedding_apply(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed_apply(p, x):
    """Logits via the (possibly tied) embedding table."""
    return x @ p["table"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": _fan_in_init(k1, (d_model, d_ff), dtype),
        "wi_up": _fan_in_init(k2, (d_model, d_ff), dtype),
        "wo": _fan_in_init(k3, (d_ff, d_model), dtype),
    }


def swiglu_apply(p, x):
    g = jax.nn.silu(x @ p["wi_gate"])
    u = x @ p["wi_up"]
    return (g * u) @ p["wo"]


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype),
        "wo": dense_init(k2, d_ff, d_model, dtype),
    }


def gelu_mlp_apply(p, x):
    return dense_apply(p["wo"], jax.nn.gelu(dense_apply(p["wi"], x)))


# ---------------------------------------------------------------------------
# losses / regularizers
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean CE over (optionally masked) examples. labels: int ids."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def binary_cross_entropy(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    nll = jnp.maximum(logits, 0) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def dropout(key, x, rate, deterministic=False):
    if deterministic or rate == 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0).astype(x.dtype)
