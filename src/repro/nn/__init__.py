from repro.nn.layers import (
    dense_init, dense_apply,
    rmsnorm_init, rmsnorm_apply,
    layernorm_init, layernorm_apply,
    embedding_init, embedding_apply,
    swiglu_init, swiglu_apply,
    gelu_mlp_init, gelu_mlp_apply,
    softmax_cross_entropy,
    binary_cross_entropy,
    dropout,
)
from repro.nn.attention import (
    rope_frequencies, apply_rope, apply_mrope,
    attention_init, attention_apply,
    mla_init, mla_apply,
)

__all__ = [k for k in dir() if not k.startswith("_")]
