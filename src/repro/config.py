"""Config system: architecture configs, input shapes, registry.

Every assigned architecture lives in ``repro/configs/<id>.py`` (dashes →
underscores) and exports ``CONFIG: ArchConfig``. ``get_arch_config(name)``
resolves it. Input shapes are the four assigned workload shapes.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture configs (transformer zoo)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    # capacity factor for expert-parallel dispatch (tokens per expert buffer)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64          # SSD-style heads (TPU adaptation, see DESIGN.md)
    chunk: int = 128
    dt_rank: int = 0            # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 128
    decay_lora: int = 64        # low-rank data-dependent decay (Finch)
    gate_lora: int = 64


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    sliding_window: int = 0          # 0 => full attention
    rope_theta: float = 10000.0
    mrope: bool = False              # multimodal RoPE (qwen2-vl)
    mla: Optional[MLAConfig] = None  # multi-head latent attention (minicpm3)
    # --- mixture of experts -------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_every: int = 1               # MoE FFN on every k-th layer (jamba: 2)
    # --- SSM / hybrid -------------------------------------------------------
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_every: int = 0              # hybrid: 1 attention layer per this many
    # --- encoder-decoder (whisper) ------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frontend output frames
    cross_attention: bool = False
    # --- vlm ----------------------------------------------------------------
    embed_inputs: bool = False       # inputs are precomputed embeddings (stub frontend)
    # --- numerics / misc ----------------------------------------------------
    dtype: str = "bfloat16"
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm (whisper)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 20
    source: str = ""                 # citation for the config

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def param_count(self) -> int:
        """Analytic total parameter count (used for roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = 0
        if self.num_heads:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            per_attn = q + kv + o
        if self.mla is not None:
            m = self.mla
            qh = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_attn = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qh
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.num_heads
                        * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.num_heads * m.v_head_dim * d)
        per_ffn = 3 * d * f  # SwiGLU
        if self.moe is not None:
            moe_ffn = self.moe.num_experts * 3 * d * f \
                + d * self.moe.num_experts
            # average per layer given MoE on every moe_every-th layer
            k = max(self.moe_every, 1)
            per_ffn = moe_ffn / k + (3 * d * f) * (k - 1) / k
        per_mamba = 0
        if self.mamba is not None:
            mc = self.mamba
            d_in = mc.expand * d
            per_mamba = (2 * d * d_in            # in_proj (x, z)
                         + d_in * mc.d_conv      # conv
                         + d_in * (2 * mc.d_state + (mc.dt_rank or d // 16))
                         + (mc.dt_rank or d // 16) * d_in
                         + d_in * d              # out_proj
                         + d_in * mc.d_state)    # A_log
        per_rwkv = 0
        if self.rwkv is not None:
            rc = self.rwkv
            # r,k,v,gate,out projections + low-rank data-dependent decay
            per_rwkv = 5 * d * d + 2 * rc.decay_lora * d
        total = emb
        n_attn, n_mix = self._layer_split()
        if self.rwkv is not None:
            # rwkv: time-mix + channel-mix per layer
            total += self.num_layers * (per_rwkv + 2 * d * f)
        elif self.mamba is not None and self.attn_every:
            total += n_attn * (per_attn + per_ffn)
            total += n_mix * (per_mamba + per_ffn)
        elif self.mamba is not None:
            total += self.num_layers * (per_mamba + per_ffn)
        else:
            total += self.num_layers * (per_attn + per_ffn)
        if self.encoder_layers:
            # encoder self-attn + ffn; decoder additionally has cross-attn
            total += self.encoder_layers * (per_attn + per_ffn)
            total += self.num_layers * per_attn  # cross attention
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE counts only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        k = max(self.moe_every, 1)
        n_moe_layers = self.num_layers // k
        all_experts = n_moe_layers * self.moe.num_experts * 3 * d * f
        active_experts = n_moe_layers * self.moe.top_k * 3 * d * f
        return int(self.param_count() - all_experts + active_experts)

    def _layer_split(self) -> Tuple[int, int]:
        """(attention layers, mixer layers) for hybrid archs."""
        if self.attn_every:
            n_attn = self.num_layers // self.attn_every
            return n_attn, self.num_layers - n_attn
        return self.num_layers, 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = 32
        heads = max(2, min(self.num_heads, 4)) if self.num_heads else 0
        kv = min(self.num_kv_heads, heads) if heads else 0
        kv = max(kv, 1) if heads else 0
        # keep GQA ratio flavor: if original had kv == heads, keep it
        if heads and self.num_kv_heads == self.num_heads:
            kv = heads
        kw = dict(
            num_layers=2, d_model=d, num_heads=heads, num_kv_heads=kv,
            head_dim=hd if heads else 0, d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 64),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2))
        if self.mamba is not None:
            kw["mamba"] = dataclasses.replace(
                self.mamba, d_state=8, head_dim=32, chunk=16)
        if self.rwkv is not None:
            kw["rwkv"] = dataclasses.replace(
                self.rwkv, head_dim=32, chunk=16, decay_lora=16, gate_lora=16)
        if self.attn_every:
            kw["attn_every"] = 2
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=16, qk_rope_head_dim=16,
                                  v_head_dim=16)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ASSIGNED_ARCHS = [
    "dbrx-132b",
    "mixtral-8x7b",
    "qwen3-4b",
    "rwkv6-1.6b",
    "phi3-medium-14b",
    "whisper-base",
    "qwen3-32b",
    "minicpm3-4b",
    "jamba-1.5-large-398b",
    "qwen2-vl-2b",
]


def _module_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_arch_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(name)}")
    return mod.CONFIG


def list_arch_configs():
    return {a: get_arch_config(a) for a in ASSIGNED_ARCHS}


# ---------------------------------------------------------------------------
# GNN training config (the paper's side)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GNNConfig:
    model: str = "gcn"              # gcn | sage | sage_max | gat | gat_e
    num_layers: int = 2
    hidden_dim: int = 16
    num_classes: int = 7
    feature_dim: int = 64
    edge_feature_dim: int = 0       # >0 enables edge-attributed models (GAT-E)
    num_heads: int = 1              # GAT heads
    dropout: float = 0.5
    residual: bool = False
    mean_aggregate: bool = True     # mean vs sum neighbor aggregation
    # Sum-stage aggregation backend: "reference" (jnp segment ops) or
    # "csc" (Pallas CSC-blocked kernels; see repro.core.aggregate)
    aggregate_backend: str = "reference"


@dataclass(frozen=True)
class TrainConfig:
    strategy: str = "global"        # global | mini | cluster
    lr: float = 1e-2
    weight_decay: float = 5e-4
    optimizer: str = "adam"         # sgd | adam | adamw
    steps: int = 200
    batch_nodes: int = 0            # mini-batch: #target nodes (0 = 1%)
    batch_clusters: int = 0         # cluster-batch: #clusters per step
    cluster_halo_hops: int = 0      # boundary halo (paper's optional feature)
    seed: int = 0
    grad_clip: float = 0.0
