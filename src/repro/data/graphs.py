"""Host-side helpers that stage graph features/labels for device batches."""
from __future__ import annotations

import numpy as np


def graph_feature_batch(features: np.ndarray, labels: np.ndarray,
                        node_ids: np.ndarray, pad_to: int = 0) -> dict:
    """Slice features/labels by node ids, padding with id 0 / mask 0."""
    n = len(node_ids)
    size = max(pad_to, n)
    ids = np.zeros(size, np.int32)
    mask = np.zeros(size, np.float32)
    ids[:n] = node_ids
    mask[:n] = 1.0
    return {
        "x": features[ids].astype(np.float32),
        "y": labels[ids].astype(np.int32),
        "mask": mask,
        "ids": ids,
    }
