from repro.data.tokens import SyntheticLMDataset, token_batches
from repro.data.graphs import graph_feature_batch

__all__ = ["SyntheticLMDataset", "token_batches", "graph_feature_batch"]
