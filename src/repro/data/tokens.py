"""Deterministic synthetic LM data pipeline.

No external corpora exist offline, so the pipeline synthesizes a Zipfian
token stream with planted n-gram structure (so a real model can reduce loss
below the unigram entropy — used by the end-to-end training example to show
learning actually happens). The iterator is stateless-resumable: batch ``i``
is a pure function of (seed, i), which is what makes checkpoint-resume exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ngram: int = 3          # planted structure order
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # unigram zipf over a shuffled alphabet
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        probs /= probs.sum()
        self._unigram = probs[rng.permutation(v)]
        # deterministic bigram successor table: token t -> preferred next
        self._succ = rng.integers(0, v, size=v, dtype=np.int64)

    def batch(self, index: int) -> dict:
        """Batch ``index`` as {tokens, labels} int32 (B, S)."""
        rng = np.random.default_rng((self.seed, index))
        B, S, v = self.global_batch, self.seq_len, self.vocab_size
        base = rng.choice(v, size=(B, S + 1), p=self._unigram)
        # plant structure: with prob .5 a token is succ(prev) — learnable
        follow = rng.random((B, S)) < 0.5
        seq = base.copy()
        for t in range(1, S + 1):
            seq[:, t] = np.where(follow[:, t - 1],
                                 self._succ[seq[:, t - 1]], base[:, t])
        return {
            "tokens": seq[:, :S].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def token_batches(vocab_size: int, seq_len: int, global_batch: int,
                  seed: int = 0, start: int = 0) -> Iterator[dict]:
    ds = SyntheticLMDataset(vocab_size, seq_len, global_batch, seed)
    i = start
    while True:
        yield ds.batch(i)
        i += 1
