"""Batched LM serving loop: request queue -> prefill -> decode rounds.

A minimal but real server core: requests arrive with prompts of varying
length, are padded into prefill batches, and decode proceeds in lockstep
rounds over a fixed cache (rolling O(window) for SWA archs). The same
``serve_step`` the multi-pod dry-run lowers (launch/dryrun.py) drives the
loop — one code path from CPU demo to pod serving.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import build_model
from repro.config import get_arch_config
from repro.utils import get_logger

log = get_logger("serve")


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int
    out: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


@dataclass
class ServerStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0


class BatchServer:
    """Fixed-batch lockstep server (padding inactive slots).

    Variable-length prompts are left-padded (right-aligned so the last
    token sits at a shared index) and a per-request validity mask rides
    along through prefill *and* decode: the pad K/Vs persist in the
    cache, so every step masks them out of attention, and per-row RoPE
    positions are pad-shifted so each prompt starts at position 0 —
    batched generations match running each request solo.
    """

    def __init__(self, arch: str, batch_size: int, cache_len: int,
                 reduced: bool = True, seed: int = 0,
                 rolling: bool = True, greedy: bool = True):
        cfg = get_arch_config(arch)
        if reduced:
            cfg = cfg.reduced().replace(dtype="float32")
        self.cfg = cfg
        self.model = build_model(cfg, remat=False,
                                 rolling_window_decode=rolling)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.batch_size = batch_size
        self.cache_len = cache_len
        self.greedy = greedy
        self.stats = ServerStats()
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=cache_len))
        self._decode = jax.jit(self.model.decode_step)

    def _pad_prompts(self, reqs: List[Request]):
        """Left-pad to a common length plus the pad-correction tensors:
        a (B, max_p) validity mask (unused batch slots stay all-True —
        an all-masked row would softmax over nothing) and per-row
        positions shifted so every real prompt starts at 0."""
        max_p = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch_size, max_p), np.int32)
        valid = np.ones((self.batch_size, max_p), bool)
        pads = np.zeros(self.batch_size, np.int32)
        for i, r in enumerate(reqs):
            pads[i] = max_p - len(r.prompt)
            toks[i, pads[i]:] = r.prompt
            valid[i, :pads[i]] = False
        positions = np.maximum(np.arange(max_p)[None] - pads[:, None], 0)
        return (jnp.asarray(toks), jnp.asarray(valid),
                jnp.asarray(positions, jnp.int32),
                jnp.asarray(pads), max_p)

    def run(self, requests: List[Request]) -> ServerStats:
        if len(requests) > self.batch_size:
            raise ValueError(f"{len(requests)} requests exceed the "
                             f"server batch size {self.batch_size}")
        reqs = list(requests)
        toks, valid, positions, pads, plen = self._pad_prompts(reqs)
        t0 = time.perf_counter()
        logits, caches, idx = self._prefill(
            self.params, {"tokens": toks, "valid": valid,
                          "positions": positions})
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * len(reqs)

        cur = jnp.argmax(logits[:, -1], -1)
        for i, r in enumerate(reqs):
            r.out.append(int(cur[i]))
        t0 = time.perf_counter()
        while not all(r.done for r in reqs):
            step_pos = (idx - pads)[:, None].astype(jnp.int32)
            logits, caches, idx = self._decode(
                self.params, {"tokens": cur[:, None], "valid": valid,
                              "positions": step_pos}, caches, idx)
            cur = jnp.argmax(logits[:, -1], -1)
            self.stats.decode_tokens += sum(not r.done for r in reqs)
            for i, r in enumerate(reqs):
                if not r.done:
                    r.out.append(int(cur[i]))
        jax.block_until_ready(cur)
        self.stats.decode_s += time.perf_counter() - t0
        return self.stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    cfg = get_arch_config(args.arch).reduced()
    server = BatchServer(args.arch, args.batch,
                         cache_len=args.prompt_len + args.new_tokens + 8)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, args.prompt_len + 1)
                                    ).astype(np.int32), args.new_tokens)
            for i in range(args.requests)]
    done = []
    for i in range(0, len(reqs), args.batch):
        batch = reqs[i:i + args.batch]
        server.run(batch)
        done.extend(batch)
        log.info("served batch %d: %d requests", i // args.batch,
                 len(batch))
    s = server.stats
    print(f"served {len(done)} requests "
          f"(prefill {s.prefill_tokens} tok @ "
          f"{s.prefill_tokens / max(s.prefill_s, 1e-9):.0f} tok/s, "
          f"decode {s.decode_tokens} tok @ "
          f"{s.decode_tokens / max(s.decode_s, 1e-9):.0f} tok/s)")
    for r in done[:2]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:8]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
