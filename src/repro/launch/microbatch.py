"""Gradient-accumulation microbatching.

The §Roofline fit analysis shows ≥100B-param archs cannot hold a full
1M-token global batch's activations on one pod even with remat; splitting
the global batch into micro-batches bounds activation memory by the
micro-batch size while keeping the optimizer math identical (mean of
per-micro gradients == full-batch gradient for a mean loss).

``unroll=True`` replaces the accumulation ``lax.scan`` with a python loop —
used by the dry-run cost calibration (While bodies are costed once).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# batch-dim index per input key (mrope positions carry a leading stream dim)
_BATCH_AXIS = {"mrope_positions": 1}


def split_batch(batch: dict, n_micro: int) -> dict:
    """Reshape every input to (n_micro, B/n_micro, ...) on its batch dim."""
    out = {}
    for k, v in batch.items():
        ax = _BATCH_AXIS.get(k, 0)
        b = v.shape[ax]
        if b % n_micro != 0:
            raise ValueError(f"batch axis of {k} ({v.shape}) must be a "
                             f"multiple of n_micro={n_micro}")
        new_shape = (v.shape[:ax] + (n_micro, b // n_micro)
                     + v.shape[ax + 1:])
        v = v.reshape(new_shape)
        if ax:
            v = jnp.moveaxis(v, ax, 0)
        out[k] = v
    return out


def microbatched_value_and_grad(loss_fn, n_micro: int, unroll: bool = False):
    """Returns fn(params, batch) -> (mean loss, mean grads)."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)

    def fn(params, batch):
        mb = split_batch(batch, n_micro)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def one(i_or_slice):
            b = i_or_slice
            loss, grads = jax.value_and_grad(loss_fn)(params, b)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
            return loss, grads

        if unroll:
            acc_l = jnp.zeros((), jnp.float32)
            acc_g = zero_g
            for i in range(n_micro):
                b = jax.tree_util.tree_map(lambda v: v[i], mb)
                loss, grads = one(b)
                acc_l = acc_l + loss
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, grads)
        else:
            def body(carry, b):
                loss, grads = one(b)
                al, ag = carry
                return (al + loss,
                        jax.tree_util.tree_map(jnp.add, ag, grads)), None

            (acc_l, acc_g), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), mb)
        scale = 1.0 / n_micro
        grads = jax.tree_util.tree_map(
            lambda g, p: (g * scale).astype(p.dtype), acc_g, params)
        return acc_l * scale, grads

    return fn
