"""Online GNN serving entrypoint + load-test harness.

Trains a quick model (or loads a checkpoint), stands up a
:class:`~repro.serving.server.GNNServer`, then replays a seeded request
trace from concurrent client threads and prints the latency/QPS/cache
report. Everything is deterministic in ``--seed``: the trace is a
skewed categorical draw (a few hot nodes dominate, the realistic serving
regime for a cache), and the per-request logits are independent of the
client count.

    PYTHONPATH=src python -m repro.launch.serve_gnn --dataset cora \
        --steps 100 --requests 500 --clients 4 --max-batch 16
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from repro.utils import get_logger

log = get_logger("serve_gnn")


def request_trace(g, n_requests: int, seed: int = 0,
                  hot_frac: float = 0.1, hot_mass: float = 0.8):
    """A seeded, skewed node-id trace: ``hot_frac`` of the nodes receive
    ``hot_mass`` of the requests (cache-friendly, like production fan-in
    on popular entities); the rest spread uniformly."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    n_hot = max(1, int(n * hot_frac))
    hot = rng.choice(n, size=n_hot, replace=False)
    p = np.full(n, (1.0 - hot_mass) / max(1, n - n_hot))
    p[hot] = hot_mass / n_hot
    p /= p.sum()
    return rng.choice(n, size=n_requests, p=p)


def run_clients(server, trace: np.ndarray, clients: int,
                timeout: float = 60.0):
    """Replay ``trace`` through ``clients`` threads against the armed
    server's batching queue. The trace is split round-robin; each thread
    issues its slice in order. Returns (logits aligned to ``trace``,
    wall seconds)."""
    out = np.empty((len(trace), server.model.num_classes), np.float32)
    errors: list = []

    def client(cid: int):
        try:
            for i in range(cid, len(trace), clients):
                out[i] = server.request(int(trace[i]), timeout=timeout)
        except BaseException as e:      # surface, don't hang the join
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return out, wall


def print_report(server, wall: float, n_requests: int) -> None:
    s = server.server_stats()
    lat, stage = s["latency_ms"], s["stage_s"]
    print(f"served {s['requests']} requests in {s['batches']} batches "
          f"(mean batch {s['mean_batch']:.1f}) in {wall:.2f}s "
          f"-> {n_requests / wall:.0f} QPS")
    print(f"latency ms: p50={lat['p50']:.2f} p99={lat['p99']:.2f} "
          f"mean={lat['mean']:.2f}")
    print(f"stage s: queue_wait={stage['queue_wait']:.2f} "
          f"view_build={stage['view_build']:.2f} "
          f"device_step={stage['device_step']:.2f} "
          f"gather={stage['gather']:.3f}")
    cache = s["cache"]
    if cache.get("enabled", True):
        print(f"cache: hit_rate={cache['hit_rate']:.2f} "
              f"hits={cache['hits']} misses={cache['misses']} "
              f"entries={cache['entries']} staleness={cache['staleness']}")
    else:
        print("cache: disabled")
    tr = s["trace"]
    print(f"trace contract: full={tr['full']['traces']} traces over "
          f"{len(tr['full']['buckets'])} buckets, "
          f"hit={tr['hit']['traces']} over "
          f"{len(tr['hit']['buckets'])} buckets")


def main(argv=None):
    import repro.api as api

    ap = argparse.ArgumentParser(
        description="serve a trained GNN and load-test it")
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--model", default="gcn",
                    choices=["gcn", "sage", "gat", "gat_e"])
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--steps", type=int, default=100,
                    help="quick training run before serving (ignored "
                         "with --checkpoint-dir)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="serve params from this checkpoint instead of "
                         "the fresh training run's")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the historical-embedding cache "
                         "(every request takes the K-hop path)")
    ap.add_argument("--staleness", type=int, default=0)
    args = ap.parse_args(argv)

    job = api.TrainJob(dataset=args.dataset, model=args.model,
                       num_layers=args.layers, hidden=args.hidden,
                       steps=args.steps, seed=args.seed,
                       eval_every=max(1, args.steps - 1))
    log.info("training %s/%s for %d steps ...", args.model, args.dataset,
             args.steps)
    result = api.train(job)
    log.info("trained: final_acc=%.4f (%.1fs)", result.final_acc,
             result.wall_s)

    cfg = api.ServeConfig(max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms,
                          cache=not args.no_cache,
                          staleness=args.staleness,
                          checkpoint_dir=args.checkpoint_dir)
    server = api.serve(result, cfg).start()
    try:
        trace = request_trace(result.graph, args.requests, seed=args.seed)
        _, wall = run_clients(server, trace, args.clients)
    finally:
        server.stop()
    server.assert_compiled_per_bucket()
    print_report(server, wall, args.requests)
    return 0


if __name__ == "__main__":
    sys.exit(main())
