# Launcher: production mesh, auto-FSDP sharding rules, multi-pod dry-run,
# trainers for both the GNN engine (the paper) and the transformer zoo.
