import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination on the production mesh with placeholder devices, and extract
memory / cost / collective artifacts for the roofline analysis.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --mesh single --out results/
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Lowering uses ShapeDtypeStructs with NamedShardings only — no arrays are
materialized. The train step lowers loss+grad+optimizer update (AdamW, f32
m/v) so memory_analysis reflects real training state.
"""
import argparse
import json
import sys
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (ASSIGNED_ARCHS, INPUT_SHAPES, get_arch_config,
                          ArchConfig, InputShape)
from repro.arch import build_model, use_hints
from repro.launch.mesh import make_production_mesh, data_axes
from repro.launch import sharding as sh
from repro.launch.roofline import derive_terms
from repro.optim import adamw

# long_500k policy (DESIGN.md §skips): sub-quadratic archs only; dense archs
# run it only with the sliding-window variant (--swa / arch suffix ":swa").
LONG_OK = {"rwkv6-1.6b", "jamba-1.5-large-398b", "mixtral-8x7b"}
LONG_SKIP_REASON = {
    "qwen3-4b": "full attention; run with --swa for the SWA variant",
    "qwen3-32b": "full attention (O(S^2), 500k infeasible by design)",
    "phi3-medium-14b": "full attention (O(S^2), 500k infeasible by design)",
    "minicpm3-4b": "MLA is full attention over the latent cache",
    "qwen2-vl-2b": "full attention",
    "whisper-base": "enc-dec; decoder positions << 500k by construction",
    "dbrx-132b": "full attention",
}


def applicable(arch: str, shape_name: str, swa: bool) -> Optional[str]:
    """None if runnable, else skip reason."""
    if shape_name == "long_500k" and arch not in LONG_OK:
        if swa and arch in ("qwen3-4b", "phi3-medium-14b", "qwen3-32b"):
            return None
        return LONG_SKIP_REASON.get(arch, "full attention")
    return None


def arch_config(arch: str, swa: bool = False,
                mamba_chunk: int = 0) -> ArchConfig:
    cfg = get_arch_config(arch)
    if swa and cfg.sliding_window == 0 and cfg.num_heads:
        cfg = cfg.replace(sliding_window=4096)
    if mamba_chunk and cfg.mamba is not None:
        import dataclasses
        cfg = cfg.replace(mamba=dataclasses.replace(cfg.mamba,
                                                    chunk=mamba_chunk))
    return cfg


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: InputShape, mesh, model):
    """ShapeDtypeStructs (sharding-annotated) for every input of the step
    that `shape` exercises. No device memory is allocated."""
    dp = data_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tok_S = 1
    else:
        tok_S = S
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jax.ShapeDtypeStruct((B, tok_S, cfg.d_model),
                                               jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, tok_S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, tok_S), jnp.int32)
    if cfg.mrope:
        batch["mrope_positions"] = jax.ShapeDtypeStruct((3, B, tok_S),
                                                        jnp.int32)
    if cfg.encoder_layers:
        if shape.kind == "decode":
            # serving carries the prefill-computed encoder memory
            batch["enc_memory"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        else:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    specs = sh.batch_specs(batch, mesh, dp)
    return sh.named(batch, {k: specs[k] for k in batch}, mesh)


def hint_rules(mesh, seq_shard: bool = True):
    dp = data_axes(mesh)
    dpn = dp if len(dp) > 1 else dp[0]
    return {"batch": dpn, "seq": "model" if seq_shard else None,
            "vocab": "model", "heads_flat": "model"}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def lower_train(cfg, shape, mesh, moe_impl="dense", unroll=False,
                opts=None):
    opts = opts or {}
    dp = data_axes(mesh)
    model = build_model(cfg, moe_impl=moe_impl, mesh=mesh, remat=True)
    model.unroll_layers = unroll
    model.remat_policy = opts.get("remat", "full")
    model.remat_granularity = opts.get("remat_gran", "group")
    opt = adamw(1e-4)
    p_shapes = model.param_shapes()
    p_specs = sh.param_specs(p_shapes, mesh, dp)
    p_named = sh.named(p_shapes, p_specs, mesh)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_specs = {"step": jax.sharding.PartitionSpec(),
               "m": p_specs, "v": p_specs}
    o_named = sh.named(o_shapes, o_specs, mesh)
    batch = input_specs(cfg, shape, mesh, model)

    n_micro = opts.get("microbatch", 1)
    if n_micro > 1:
        from repro.launch.microbatch import microbatched_value_and_grad
        vag = microbatched_value_and_grad(model.loss, n_micro,
                                          unroll=unroll)
    else:
        vag = jax.value_and_grad(model.loss)

    def train_step(params, opt_state, batch):
        loss, grads = vag(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return loss, params, opt_state

    with use_hints(mesh, hint_rules(mesh,
                                    not opts.get("no_seq_shard", False))):
        lowered = jax.jit(train_step,
                          donate_argnums=(0, 1)).lower(p_named, o_named,
                                                       batch)
    return lowered


def lower_prefill(cfg, shape, mesh, moe_impl="dense", unroll=False,
                  opts=None):
    opts = opts or {}
    dp = data_axes(mesh)
    model = build_model(cfg, moe_impl=moe_impl, mesh=mesh, remat=False)
    model.unroll_layers = unroll
    p_shapes = model.param_shapes()
    p_named = sh.named(p_shapes, sh.param_specs(p_shapes, mesh, dp), mesh)
    batch = input_specs(cfg, shape, mesh, model)
    S = shape.seq_len

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=S)

    with use_hints(mesh, hint_rules(mesh)):
        lowered = jax.jit(prefill_step).lower(p_named, batch)
    return lowered


def lower_decode(cfg, shape, mesh, moe_impl="dense",
                 rolling: bool = False, unroll=False, opts=None):
    opts = opts or {}
    dp = data_axes(mesh)
    model = build_model(cfg, moe_impl=moe_impl, mesh=mesh, remat=False,
                        rolling_window_decode=rolling)
    model.unroll_layers = unroll
    p_shapes = model.param_shapes()
    if opts.get("serve_weights") == "model-only":
        # serving layout: weights sharded over 'model' only (replicated
        # over 'data') -> no per-step FSDP all-gather at decode
        p_specs = sh.param_specs(p_shapes, mesh, ())
        p_named = sh.named(p_shapes, p_specs, mesh)
    else:
        p_named = sh.named(p_shapes, sh.param_specs(p_shapes, mesh, dp),
                           mesh)
    batch = input_specs(cfg, shape, mesh, model)
    B, S = shape.global_batch, shape.seq_len
    c_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    c_named = sh.named(c_shapes, sh.cache_specs(c_shapes, mesh, dp), mesh)
    idx = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, caches, batch, index):
        return model.decode_step(params, batch, caches, index)

    with use_hints(mesh, hint_rules(mesh)):
        lowered = jax.jit(serve_step,
                          donate_argnums=(1,)).lower(p_named, c_named,
                                                     batch, idx)
    return lowered


def lower_step(cfg, shape, mesh, moe_impl="dense", rolling=False,
               unroll=False, opts=None):
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh, moe_impl, unroll, opts)
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh, moe_impl, unroll, opts)
    return lower_decode(cfg, shape, mesh, moe_impl, rolling, unroll, opts)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _group_size(cfg: ArchConfig) -> int:
    return (cfg.attn_every
            if (cfg.mamba is not None and cfg.attn_every) else 1)


def run_one(arch: str, shape_name: str, mesh_name: str, moe_impl: str,
            swa: bool, out_dir: Optional[str], verbose: bool = True,
            calibrate: bool = True, opts: Optional[dict] = None,
            tag_suffix: str = "") -> dict:
    from repro.launch.roofline import extract_costs, combine_calibrated

    opts = opts or {}
    shape = INPUT_SHAPES[shape_name]
    skip = applicable(arch, shape_name, swa)
    tag = (f"{arch}{':swa' if swa else ''}|{shape_name}|{mesh_name}|"
           f"{moe_impl}{tag_suffix}")
    if skip:
        rec = {"tag": tag, "status": "skip", "reason": skip}
        if verbose:
            print(f"[dryrun] SKIP {tag}: {skip}")
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fname = tag.replace("|", "__").replace(":", "_") + ".json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    cfg = arch_config(arch, swa, mamba_chunk=opts.get("mamba_chunk", 0))
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    rolling = swa or (arch == "mixtral-8x7b" and shape_name == "long_500k") \
        or opts.get("rolling", False)
    try:
        # the deliverable: full-depth lower + compile must succeed
        lowered = lower_step(cfg, shape, mesh, moe_impl, rolling, opts=opts)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        if calibrate:
            # layer-scan cost calibration (XLA costs While bodies once):
            # 1-group and 2-group variants give exact per-group deltas
            g = _group_size(cfg)
            n_groups = cfg.num_layers // g
            c1 = extract_costs(
                lower_step(cfg.replace(num_layers=g), shape, mesh,
                           moe_impl, rolling, unroll=True,
                           opts=opts).compile())
            c2 = extract_costs(
                lower_step(cfg.replace(num_layers=2 * g), shape, mesh,
                           moe_impl, rolling, unroll=True,
                           opts=opts).compile())
            cost = combine_calibrated(c1, c2, n_groups)
        else:
            cost = extract_costs(compiled)
        terms = derive_terms(arch + (":swa" if swa else ""), shape,
                             mesh_name, chips, cost, mem, hlo, cfg)
        rec = {"tag": tag, "status": "ok", "calibrated": calibrate,
               **terms.as_dict()}
        if verbose:
            print(f"[dryrun] OK   {tag}  "
                  f"flops/dev={terms.hlo_flops_per_device:.3e} "
                  f"mem/dev={terms.memory_per_device_bytes/2**30:.2f}GiB "
                  f"coll/dev={terms.collective_bytes_per_device/2**20:.1f}MiB "
                  f"dom={terms.dominant} "
                  f"useful={terms.useful_flops_ratio:.2f}")
            print(f"[dryrun]      memory_analysis: {mem}")
    except Exception as e:  # noqa: BLE001 — report every failure mode
        rec = {"tag": tag, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = tag.replace("|", "__").replace(":", "_") + ".json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--moe-impl", default="dense", choices=["dense", "ep"])
    ap.add_argument("--swa", action="store_true",
                    help="sliding-window variant for dense archs")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--rolling", action="store_true",
                    help="O(window) rolling decode cache (SWA archs)")
    ap.add_argument("--serve-weights", default="fsdp",
                    choices=["fsdp", "model-only"])
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--remat-gran", default="group",
                    choices=["group", "block"])
    ap.add_argument("--mamba-chunk", type=int, default=0)
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="batch-only activation sharding (SSM archs)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation micro-batches (train)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the unrolled cost-calibration compiles "
                         "(memory analysis only; costs from the scan "
                         "compile are trip-count-undercounted)")
    ap.add_argument("--tag", default="",
                    help="suffix for perf-iteration artifacts")
    args = ap.parse_args(argv)
    opts = {"rolling": args.rolling, "serve_weights": args.serve_weights,
            "remat": args.remat, "remat_gran": args.remat_gran,
            "mamba_chunk": args.mamba_chunk,
            "no_seq_shard": args.no_seq_shard,
            "microbatch": args.microbatch}

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                results.append(run_one(arch, shape, mesh_name,
                                       args.moe_impl, args.swa, args.out,
                                       opts=opts,
                                       calibrate=not args.no_calibrate,
                                       tag_suffix=args.tag))
    bad = [r for r in results if r["status"] == "error"]
    print(f"[dryrun] {len(results)} combos: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skip' for r in results)} skip, "
          f"{len(bad)} error")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
