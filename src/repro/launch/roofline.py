"""Roofline-term derivation from compiled dry-run artifacts (§Roofline).

The container is CPU-only; TPU v5e is the *target*. We therefore derive the
three roofline terms from the compiled executable instead of wall-clock:

  compute    = HLO_FLOPs(per device) / 197 TF/s
  memory     = HLO_bytes(per device) / 819 GB/s
  collective = collective_bytes(per device) / 50 GB/s (1 ICI link, worst
               case; v5e has more links — the term is an upper bound)

``collective_bytes`` is parsed from the post-SPMD HLO: we sum, per
collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), the larger of its result size and first-operand size —
a device must at least read or write that many bytes over the interconnect
path. cost_analysis()/memory_analysis() provide FLOPs and HBM traffic.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Optional

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals from (post-SPMD, per-device) HLO."""
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        kind = None
        for k in _COLLECTIVES:
            # match the op name, e.g. "= bf16[..] all-gather(", not %tags
            if f" {k}(" in s or f" {k}-start(" in s:
                kind = k
                break
        if kind is None:
            continue
        shapes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(s)]
        if not shapes:
            continue
        # result shape(s) come first (possibly a tuple), operands follow;
        # take the max single shape as the bytes the op moves per device.
        totals[kind] += max(shapes)
        counts[kind] += 1
    totals["total"] = sum(totals[k] for k in _COLLECTIVES)
    totals["counts"] = counts
    return totals


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_flops_ratio: float
    memory_per_device_bytes: float
    collective_breakdown: Optional[dict] = None

    def as_dict(self):
        return asdict(self)


def model_flops(cfg, shape, chips: int) -> float:
    """Analytic MODEL_FLOPS for the step, per device.

    train: 6·N_active·tokens; prefill: 2·N_active·tokens;
    decode: 2·N_active·batch (one token per sequence).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.global_batch * shape.seq_len
    else:
        total = 2.0 * n * shape.global_batch
    return total / chips


def extract_costs(compiled) -> dict:
    """(flops, bytes, collective bytes) for one compiled executable."""
    cost = dict(compiled.cost_analysis() or {})
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
        "coll_breakdown": {k: v for k, v in coll.items() if k != "counts"},
    }


def combine_calibrated(c1: dict, c2: dict, n_groups: int) -> dict:
    """Layer-scan calibration: XLA costs While bodies once, so we lower a
    1-group and a 2-group variant; the difference is one group's true cost
    and ``total = c1 + delta·(n_groups-1)`` (see DESIGN.md)."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        delta = c2[k] - c1[k]
        out[k] = max(c1[k] + delta * (n_groups - 1), 0.0)
    out["coll_breakdown"] = {
        k: max(c1["coll_breakdown"].get(k, 0)
               + (c2["coll_breakdown"].get(k, 0)
                  - c1["coll_breakdown"].get(k, 0)) * (n_groups - 1), 0)
        for k in set(c1["coll_breakdown"]) | set(c2["coll_breakdown"])}
    return out


def derive_terms(arch: str, shape, mesh_name: str, chips: int,
                 cost: dict, mem: object, hlo_text: str, cfg
                 ) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes", cost.get("bytes accessed",
                                                      0.0)))
    if "coll" in cost:
        coll = {"total": cost["coll"], **{
            k: v for k, v in cost.get("coll_breakdown", {}).items()}}
    else:
        coll = parse_collective_bytes(hlo_text)
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_accessed / HBM_BW
    t_x = coll["total"] / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                   key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape, chips)
    mem_bytes = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        mem_bytes += float(getattr(mem, attr, 0.0) or 0.0)
    # donated inputs alias outputs — don't count those bytes twice
    mem_bytes -= float(getattr(mem, "alias_size_in_bytes", 0.0) or 0.0)
    return RooflineTerms(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_device=flops, hlo_bytes_per_device=bytes_accessed,
        collective_bytes_per_device=float(coll["total"]),
        t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_x,
        dominant=dominant, model_flops_per_device=mf,
        useful_flops_ratio=(mf / flops) if flops else 0.0,
        memory_per_device_bytes=mem_bytes,
        collective_breakdown={k: v for k, v in coll.items()
                              if k != "counts"},
    )
