"""Auto-FSDP sharding rules: map every parameter / optimizer / cache leaf
to a PartitionSpec on the production mesh.

GraphTheta's hybrid-parallel principle (one batch computed by the whole
worker group) maps here to: weights and optimizer state fully sharded over
('data', 'model'), activations batch-sharded over data (+pod) and
sequence-sharded over model between blocks. Parameters are *not* sharded
over 'pod' (grads all-reduce over DCI once per step instead of paying
per-layer cross-pod all-gathers — the cheaper direction for 2 pods).

The generic rule is greedy: give 'model' to the largest divisible tensor
dim, then 'data' to the largest remaining divisible dim. Leaves under a
layer-stack ("blocks"/"encoder") skip their leading stack dim. Exceptions
(expert dim → 'model' for EP alignment; cache layouts) are keyed by leaf
name. Non-divisible dims are left unsharded — that is what makes the same
rules work for every assigned architecture.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        out.append(("/".join(str(n) for n in names), leaf))
    return out, treedef


def _greedy_spec(shape, mesh, skip_leading: bool, expert_dim: Optional[int],
                 dp=("data",)):
    """dp=() disables the data-axis FSDP assignment (serving layout)."""
    model_n = mesh.shape["model"]
    data_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    spec = [None] * len(shape)
    start = 1 if skip_leading and len(shape) > 1 else 0
    dims = list(range(start, len(shape)))
    used_model = used_data = False
    # expert dim gets 'model' first (EP alignment)
    if expert_dim is not None and expert_dim < len(shape) \
            and shape[expert_dim] % model_n == 0:
        spec[expert_dim] = "model"
        used_model = True
        dims.remove(expert_dim)
    for want in ("model", "data"):
        if want == "model" and used_model:
            continue
        if want == "data" and used_data:
            continue
        n = model_n if want == "model" else data_n
        if n <= 1:
            continue
        cands = sorted((d for d in dims if spec[d] is None and
                        shape[d] % n == 0 and shape[d] >= n),
                       key=lambda d: -shape[d])
        if cands:
            d = cands[0]
            spec[d] = "model" if want == "model" else (
                dp if len(dp) > 1 else dp[0])
            dims.remove(d)
    return P(*spec)


_CACHE_RULES = {
    # name -> callable(shape, mesh, dp) -> PartitionSpec; all cache leaves
    # carry a leading layer-stack dim.
    "k": lambda s, m, dp: _kv_spec(s, m, dp),
    "v": lambda s, m, dp: _kv_spec(s, m, dp),
    "c_kv": lambda s, m, dp: _seq_spec(s, m, dp),
    "k_rope": lambda s, m, dp: _seq_spec(s, m, dp),
    "state": lambda s, m, dp: _head_spec(s, m, dp),
    "conv": lambda s, m, dp: _lastdim_spec(s, m, dp),
    "last": lambda s, m, dp: _lastdim_spec(s, m, dp),
    "pos": lambda s, m, dp: P(*([None] * len(s))),
}


def _div(n, axes_size):
    return axes_size > 1 and n % axes_size == 0 and n >= axes_size


def _dp_size(mesh, dp):
    return int(np.prod([mesh.shape[a] for a in dp]))


def _dp_name(dp):
    return dp if len(dp) > 1 else dp[0]


def _kv_spec(s, mesh, dp):
    # (G, B, S, H, hd): batch -> dp, seq -> model
    spec = [None] * len(s)
    if _div(s[1], _dp_size(mesh, dp)):
        spec[1] = _dp_name(dp)
    if _div(s[2], mesh.shape["model"]):
        spec[2] = "model"
    return P(*spec)


def _seq_spec(s, mesh, dp):
    # (G, B, S, r)
    spec = [None] * len(s)
    if _div(s[1], _dp_size(mesh, dp)):
        spec[1] = _dp_name(dp)
    if _div(s[2], mesh.shape["model"]):
        spec[2] = "model"
    return P(*spec)


def _head_spec(s, mesh, dp):
    # (G, B, H, P, N): batch -> dp, heads -> model
    spec = [None] * len(s)
    if _div(s[1], _dp_size(mesh, dp)):
        spec[1] = _dp_name(dp)
    if len(s) > 2 and _div(s[2], mesh.shape["model"]):
        spec[2] = "model"
    return P(*spec)


def _lastdim_spec(s, mesh, dp):
    spec = [None] * len(s)
    if _div(s[1], _dp_size(mesh, dp)):
        spec[1] = _dp_name(dp)
    if _div(s[-1], mesh.shape["model"]):
        spec[-1] = "model"
    return P(*spec)


_EXPERT_LEAVES = ("wi_gate", "wi_up", "wo")


def param_specs(params_shapes, mesh: Mesh, dp=("data",)):
    """PartitionSpec pytree for parameters (or optimizer state — same
    structure rules apply to any mirrored tree)."""
    flat, treedef = _flatten_with_names(params_shapes)
    specs = []
    for name, leaf in flat:
        shape = leaf.shape
        if len(shape) == 0:
            specs.append(P())
            continue
        parts = name.split("/")
        in_stack = parts[0] in ("blocks", "encoder") or (
            len(parts) > 1 and parts[1] in ("blocks", "encoder"))
        leafname = parts[-1]
        expert_dim = None
        if any(p in ("ffn",) for p in parts) and leafname in _EXPERT_LEAVES \
                and len(shape) >= 3:
            expert_dim = 1 if in_stack else 0
        if len(shape) == 1 or (in_stack and len(shape) == 2):
            specs.append(P(*([None] * len(shape))))   # biases/scales
            continue
        specs.append(_greedy_spec(shape, mesh, in_stack, expert_dim, dp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_specs(cache_shapes, mesh: Mesh, dp=("data",)):
    flat, treedef = _flatten_with_names(cache_shapes)
    specs = []
    for name, leaf in flat:
        leafname = name.split("/")[-1]
        rule = _CACHE_RULES.get(leafname)
        if rule is None:
            specs.append(P(*([None] * len(leaf.shape))))
        else:
            specs.append(rule(leaf.shape, mesh, dp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shapes, mesh: Mesh, dp=("data",)):
    """tokens/labels (B,S) -> (dp, None); embeds (B,S,D) -> (dp, None, None);
    mrope (3,B,S) -> (None, dp, None); enc_frames (B,S,D) -> (dp, ...)."""
    out = {}
    dpn = _dp_name(dp)
    for k, v in batch_shapes.items():
        spec = [None] * len(v.shape)
        bdim = 1 if k == "mrope_positions" else 0
        if _div(v.shape[bdim], _dp_size(mesh, dp)):
            spec[bdim] = dpn
        out[k] = P(*spec)
    return out


def named(tree_shapes, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree_shapes, specs)
