"""Production mesh construction (defined as functions, never at import
time, so importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    if n % model_parallel != 0:
        raise ValueError(f"device count {n} must be a multiple of "
                         f"model_parallel {model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' joins 'data' when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# TPU v5e hardware constants used by the roofline analysis (§Roofline).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
