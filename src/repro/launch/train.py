"""Runnable trainers: (a) the GraphTheta GNN trainer (the paper's system),
(b) a transformer LM trainer over the arch zoo (reduced configs run on CPU;
full configs on a real pod with the same code path).

GNN:
    PYTHONPATH=src python -m repro.launch.train gnn --dataset reddit_like \
        --model gcn --strategy cluster --steps 200
LM:
    PYTHONPATH=src python -m repro.launch.train lm --arch qwen3-4b \
        --reduced --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GNNConfig, get_arch_config
from repro.utils import get_logger

log = get_logger("train")


# ---------------------------------------------------------------------------
# GNN trainer (single-host path; the distributed engine is exercised when
# multiple devices exist — tests use subprocesses with fake devices)
# ---------------------------------------------------------------------------


def train_gnn(dataset: str, model_name: str, strategy: str, steps: int,
              hidden: int = 64, lr: float = 1e-2, seed: int = 0,
              num_layers: int = 2, eval_every: int = 20,
              use_engine: Optional[int] = None,
              partition_method: str = "1d_src",
              prefetch_workers: Optional[int] = None,
              compact: bool = False, fault_policy=None,
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 0, resume: bool = False) -> dict:
    from repro.graph import make_dataset
    from repro.models import make_gnn
    from repro.core.mpgnn import loss_block, accuracy_block
    from repro.core.strategies import global_batch_view, strategy_views
    from repro.core.clustering import label_propagation_clusters
    from repro.optim import adam

    g = make_dataset(dataset, seed=seed)
    edge_dim = (g.edge_features.shape[1]
                if g.edge_features is not None else 0)
    if model_name == "gat_e" and edge_dim == 0:
        raise ValueError("gat_e needs an edge-attributed dataset "
                         "(alipay_like)")
    g = g.add_self_loops() if model_name == "gcn" else g
    num_classes = int(g.labels.max()) + 1
    cfg = GNNConfig(model=model_name, num_layers=num_layers,
                    hidden_dim=hidden, num_classes=num_classes,
                    feature_dim=g.node_features.shape[1],
                    edge_feature_dim=edge_dim, num_heads=4)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(seed), cfg.feature_dim)
    opt = adam(lr, weight_decay=5e-4)

    # views per strategy, through the shared strategy_views entry point.
    # mini: 10% of labeled nodes per step (the paper's 1% suits graphs
    # with ~100k+ labeled nodes; tiny synthetics need larger batches)
    labeled = int((g.train_mask if g.train_mask is not None
                   else np.ones(g.num_nodes, bool)).sum())
    clusters = None
    if strategy == "cluster":
        clusters = label_propagation_clusters(
            g, max_cluster_size=max(64, g.num_nodes // 50), seed=seed)
    # compact sampled-subgraph views (local-id blocks + bucketed padding)
    # apply to the sampling strategies; the global view IS the graph
    compact = compact and strategy in ("mini", "cluster")
    views = strategy_views(
        g, strategy, cfg.num_layers, seed=seed,
        batch_nodes=max(32, labeled // 10), clusters=clusters,
        clusters_per_batch=max(1, (int(clusters.max()) + 1) // 20)
        if clusters is not None else 0,
        halo_hops=0, compact=compact)

    gcn_norm = model_name == "gcn"
    test_mask = (g.test_mask if g.test_mask is not None else g.train_mask)

    if use_engine:
        # distributed path: the compiled-once Trainer drives the engine
        # (vectorized shard_view + prefetch pipeline + eval through the
        # engine's distributed infer)
        from repro.core.partition import build_partitions
        from repro.core.engine import HybridParallelEngine
        from repro.core.trainer import Trainer
        sg = build_partitions(g, use_engine, method=partition_method,
                              gcn_norm=gcn_norm)
        engine = HybridParallelEngine(model, sg)
        trainer = Trainer(engine, opt, params=params,
                          fault_policy=fault_policy)
        gbv = global_batch_view(g, cfg.num_layers)
        mask = test_mask.astype(np.float32)
        t0 = time.perf_counter()
        out = trainer.fit(views, steps=steps, eval_every=eval_every,
                          eval_view=gbv, eval_mask=mask,
                          prefetch_workers=prefetch_workers,
                          checkpoint_every=checkpoint_every,
                          checkpoint_dir=checkpoint_dir, resume=resume,
                          log_every=1, log=log.info)
        wall = time.perf_counter() - t0
        trainer.assert_compiled_once()
        history = [{"step": e["step"], "loss": e["loss"],
                    "test_acc": e["eval_acc"]} for e in out["evals"]]
        if history and history[-1]["step"] == steps:
            final_acc = history[-1]["test_acc"]   # fit already evaluated
        else:
            final_acc = trainer.evaluate(gbv, mask)
            history.append({"step": steps, "loss": out["losses"][-1],
                            "test_acc": final_acc})
        return {"history": history, "wall_s": wall,
                "params": trainer.params, "final_acc": final_acc,
                "model": model, "graph": g}

    # checkpoint/fault flags need a supervised trainer; the bucketed
    # trainer accepts dense views too (one full-graph bucket), so route
    # runtime-flagged single-process runs through it rather than
    # silently dropping the flags on the bare jit loop below
    needs_runtime = (fault_policy is not None or bool(checkpoint_dir)
                     or checkpoint_every > 0 or resume)
    if compact or needs_runtime:
        # bucketed compact path: CompactTrainer stages each view into a
        # small fixed menu of padded shapes (compiled once per bucket)
        from repro.core.trainer import CompactTrainer
        trainer = CompactTrainer(model, g, opt, params=params,
                                 gcn_norm=gcn_norm,
                                 fault_policy=fault_policy)
        gbv = global_batch_view(g, cfg.num_layers)
        mask = test_mask.astype(np.float32)
        t0 = time.perf_counter()
        out = trainer.fit(views, steps=steps, eval_every=eval_every,
                          eval_view=gbv, eval_mask=mask,
                          prefetch_workers=prefetch_workers,
                          checkpoint_every=checkpoint_every,
                          checkpoint_dir=checkpoint_dir, resume=resume,
                          log_every=1, log=log.info)
        wall = time.perf_counter() - t0
        trainer.assert_compiled_per_bucket()
        history = [{"step": e["step"], "loss": e["loss"],
                    "test_acc": e["eval_acc"]} for e in out["evals"]]
        if history and history[-1]["step"] == steps:
            final_acc = history[-1]["test_acc"]
        else:
            final_acc = trainer.evaluate(gbv, mask)
            history.append({"step": steps, "loss": out["losses"][-1],
                            "test_acc": final_acc})
        return {"history": history, "wall_s": wall,
                "params": trainer.params, "final_acc": final_acc,
                "model": model, "graph": g}

    opt_state = opt.init(params)

    @jax.jit
    def local_step(params, opt_state, block):
        loss_v, grads = jax.value_and_grad(
            lambda p: loss_block(model, p, block))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss_v

    history = []
    t0 = time.perf_counter()
    for step in range(steps):
        view = next(views)
        block = view.as_block(gcn_norm=gcn_norm,
                              csc_plan=cfg.aggregate_backend == "csc")
        params, opt_state, loss_v = local_step(params, opt_state, block)
        loss = float(loss_v)
        if step % eval_every == 0 or step == steps - 1:
            gb = global_batch_view(g, cfg.num_layers).as_block(
                gcn_norm=gcn_norm,
                csc_plan=cfg.aggregate_backend == "csc")
            acc = float(accuracy_block(model, params, gb,
                                       mask=test_mask.astype(np.float32)))
            history.append({"step": step, "loss": loss, "test_acc": acc})
            log.info("step=%d strategy=%s loss=%.4f test_acc=%.4f",
                     step, strategy, loss, acc)
    wall = time.perf_counter() - t0
    return {"history": history, "wall_s": wall, "params": params,
            "final_acc": history[-1]["test_acc"], "model": model,
            "graph": g}


# ---------------------------------------------------------------------------
# LM trainer
# ---------------------------------------------------------------------------


def train_lm(arch: str, steps: int, batch: int, seq: int,
             reduced: bool = True, lr: float = 3e-4, seed: int = 0,
             log_every: int = 10, checkpoint_dir: Optional[str] = None,
             vocab_cap: int = 1024) -> dict:
    from repro.arch import build_model
    from repro.data import SyntheticLMDataset
    from repro.optim import adamw, warmup_cosine_schedule
    from repro.checkpoint import save_checkpoint
    import repro.arch.model as arch_model

    cfg = get_arch_config(arch)
    if reduced:
        cfg = cfg.reduced().replace(dtype="float32",
                                    vocab_size=min(cfg.reduced().vocab_size,
                                                   vocab_cap))
    arch_model.LOSS_CHUNK = min(arch_model.LOSS_CHUNK, seq)
    model = build_model(cfg, remat=not reduced)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw(warmup_cosine_schedule(lr, max(10, steps // 20), steps))
    opt_state = opt.init(params)
    ds = SyntheticLMDataset(cfg.vocab_size, seq, batch, seed=seed)
    rng = np.random.default_rng(seed)

    def make_batch(i):
        b = ds.batch(i)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.embed_inputs:
            # stub frontend: embed via the table (frontends are stubs)
            out["embeds"] = params["embed"]["table"][out["tokens"]]
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(seq)[None], (batch, seq))
            out["mrope_positions"] = jnp.asarray(
                np.stack([pos, pos, pos]), jnp.int32)
        if cfg.encoder_layers:
            out["enc_frames"] = jnp.asarray(rng.normal(
                size=(batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        return out

    @jax.jit
    def step_fn(params, opt_state, batch_):
        loss, grads = jax.value_and_grad(model.loss)(params, batch_)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        params, opt_state, loss = step_fn(params, opt_state, make_batch(i))
        if i % log_every == 0 or i == steps - 1:
            lv = float(loss)
            history.append({"step": i, "loss": lv})
            log.info("arch=%s step=%d loss=%.4f", arch, i, lv)
    wall = time.perf_counter() - t0
    if checkpoint_dir:
        save_checkpoint(checkpoint_dir, steps, {"params": params})
    return {"history": history, "wall_s": wall, "params": params,
            "final_loss": history[-1]["loss"], "model": model, "cfg": cfg}


def main(argv=None):
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    g = sub.add_parser("gnn")
    g.add_argument("--dataset", default="cora")
    g.add_argument("--model", default="gcn",
                   choices=["gcn", "sage", "gat", "gat_e"])
    g.add_argument("--strategy", default="global",
                   choices=["global", "mini", "cluster"])
    g.add_argument("--steps", type=int, default=100)
    g.add_argument("--hidden", type=int, default=64)
    g.add_argument("--layers", type=int, default=2)
    g.add_argument("--engine-partitions", type=int, default=0,
                   help="use the distributed engine with P partitions "
                        "(requires that many jax devices)")
    g.add_argument("--partition-method", default="1d_src",
                   choices=["1d_src", "1d_dst", "vertex_cut"])
    g.add_argument("--prefetch-workers", type=int, default=None,
                   help="view-builder threads for the engine path "
                        "(default: min(4, cores-1); deterministic for "
                        "any count)")
    g.add_argument("--compact", action="store_true",
                   help="compact sampled-subgraph views (relabeled "
                        "local-id blocks, size-bucketed padding) for "
                        "mini/cluster; dense masks stay the parity oracle")
    ft = g.add_argument_group(
        "fault tolerance",
        "supervised training runtime (repro.runtime): retries with "
        "capped exponential backoff, divergence recovery, hardened "
        "checkpoints. Off by default (zero overhead); any flag here "
        "enables the runtime (single-process runs switch to the "
        "bucketed trainer, which handles dense views too).")
    ft.add_argument("--fault-retries", type=int, default=None,
                    metavar="N",
                    help="retry transient view-build / staging / step / "
                         "checkpoint failures up to N times (default "
                         "policy: 3)")
    ft.add_argument("--fault-backoff", type=float, default=None,
                    metavar="SECONDS",
                    help="base backoff before the first retry; grows "
                         "exponentially with deterministic jitter "
                         "(default 0.05s, capped at 2s)")
    ft.add_argument("--on-divergence", default=None,
                    choices=["raise", "skip_view", "rollback"],
                    help="reaction to a non-finite loss: raise (default),"
                         " skip_view (discard the poison update and move "
                         "on), or rollback (restore the last valid "
                         "checkpoint and continue past the poison view)")
    ft.add_argument("--check-finite", action="store_true",
                    help="sync and guard every step's loss (serializes "
                         "the step pipeline; implied by a non-raise "
                         "--on-divergence)")
    ft.add_argument("--step-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="watchdog: fail loudly if a step's loss is not "
                         "available within this many seconds")
    ft.add_argument("--checkpoint-dir", default=None,
                    help="directory for step_<N>.npz checkpoints "
                         "(atomic, checksummed; required by "
                         "--on-divergence rollback)")
    ft.add_argument("--checkpoint-every", type=int, default=0,
                    metavar="STEPS",
                    help="save a checkpoint every N steps (0 = never)")
    ft.add_argument("--resume", action="store_true",
                    help="resume from the newest VALID checkpoint in "
                         "--checkpoint-dir (corrupt files are skipped); "
                         "fresh start if none")
    ft.add_argument("--keep-checkpoints", type=int, default=0,
                    metavar="K",
                    help="retain only the newest K checkpoints "
                         "(0 = keep all)")
    lm = sub.add_parser("lm")
    lm.add_argument("--arch", required=True)
    lm.add_argument("--steps", type=int, default=50)
    lm.add_argument("--batch", type=int, default=8)
    lm.add_argument("--seq", type=int, default=128)
    lm.add_argument("--reduced", action="store_true", default=True)
    lm.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args(argv)

    if args.cmd == "gnn":
        fault_policy = None
        ft_flags = (args.fault_retries, args.fault_backoff,
                    args.on_divergence, args.step_timeout)
        if args.check_finite or any(f is not None for f in ft_flags):
            from repro.runtime import FaultPolicy
            kw = {"check_finite": args.check_finite,
                  "keep_checkpoints": args.keep_checkpoints}
            if args.fault_retries is not None:
                kw["max_retries"] = args.fault_retries
            if args.fault_backoff is not None:
                kw["backoff_base"] = args.fault_backoff
            if args.on_divergence is not None:
                kw["on_divergence"] = args.on_divergence
            if args.step_timeout is not None:
                kw["timeouts"] = {"step": args.step_timeout}
            fault_policy = FaultPolicy(**kw)
        out = train_gnn(args.dataset, args.model, args.strategy, args.steps,
                        hidden=args.hidden, num_layers=args.layers,
                        use_engine=args.engine_partitions or None,
                        partition_method=args.partition_method,
                        prefetch_workers=args.prefetch_workers,
                        compact=args.compact, fault_policy=fault_policy,
                        checkpoint_dir=args.checkpoint_dir,
                        checkpoint_every=args.checkpoint_every,
                        resume=args.resume)
        print(f"final test acc: {out['final_acc']:.4f} "
              f"({out['wall_s']:.1f}s)")
    else:
        out = train_lm(args.arch, args.steps, args.batch, args.seq,
                       reduced=args.reduced,
                       checkpoint_dir=args.checkpoint_dir)
        print(f"final loss: {out['final_loss']:.4f} ({out['wall_s']:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
