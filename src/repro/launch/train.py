"""Runnable trainers: (a) the GraphTheta GNN trainer (the paper's system),
(b) a transformer LM trainer over the arch zoo (reduced configs run on CPU;
full configs on a real pod with the same code path).

GNN:
    PYTHONPATH=src python -m repro.launch.train gnn --dataset reddit_like \
        --model gcn --strategy cluster --steps 200
LM:
    PYTHONPATH=src python -m repro.launch.train lm --arch qwen3-4b \
        --reduced --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GNNConfig, get_arch_config
from repro.utils import get_logger

log = get_logger("train")


# ---------------------------------------------------------------------------
# GNN trainer (single-host path; the distributed engine is exercised when
# multiple devices exist — tests use subprocesses with fake devices)
# ---------------------------------------------------------------------------


def train_gnn(dataset: str, model_name: str, strategy: str, steps: int,
              hidden: int = 64, lr: float = 1e-2, seed: int = 0,
              num_layers: int = 2, eval_every: int = 20,
              use_engine: Optional[int] = None,
              partition_method: str = "1d_src",
              prefetch_workers: Optional[int] = None,
              prefetch_mode: str = "thread",
              compact: bool = False, fault_policy=None,
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 0, resume: bool = False) -> dict:
    """Deprecated shim — construct a :class:`repro.api.TrainJob` and call
    :func:`repro.api.train` instead (same knobs, one typed surface; see
    the README migration table). Kept for the legacy kwargs + return
    dict; single-process runs now always go through the bucketed
    :class:`~repro.core.trainer.CompactTrainer` (dense views stage as
    one full-graph bucket, so the math is unchanged)."""
    import repro.api as api
    job = api.TrainJob(
        dataset=dataset, model=model_name, strategy=strategy, steps=steps,
        hidden=hidden, lr=lr, seed=seed, num_layers=num_layers,
        eval_every=eval_every, engine_partitions=use_engine or 0,
        partition_method=partition_method,
        prefetch_workers=prefetch_workers, prefetch_mode=prefetch_mode,
        compact=compact,
        fault_policy=fault_policy, checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every, resume=resume)
    return api.train(job, log=log.info).as_dict()


# ---------------------------------------------------------------------------
# LM trainer
# ---------------------------------------------------------------------------


def train_lm(arch: str, steps: int, batch: int, seq: int,
             reduced: bool = True, lr: float = 3e-4, seed: int = 0,
             log_every: int = 10, checkpoint_dir: Optional[str] = None,
             vocab_cap: int = 1024) -> dict:
    from repro.arch import build_model
    from repro.data import SyntheticLMDataset
    from repro.optim import adamw, warmup_cosine_schedule
    from repro.checkpoint import save_checkpoint
    import repro.arch.model as arch_model

    cfg = get_arch_config(arch)
    if reduced:
        cfg = cfg.reduced().replace(dtype="float32",
                                    vocab_size=min(cfg.reduced().vocab_size,
                                                   vocab_cap))
    arch_model.LOSS_CHUNK = min(arch_model.LOSS_CHUNK, seq)
    model = build_model(cfg, remat=not reduced)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw(warmup_cosine_schedule(lr, max(10, steps // 20), steps))
    opt_state = opt.init(params)
    ds = SyntheticLMDataset(cfg.vocab_size, seq, batch, seed=seed)
    rng = np.random.default_rng(seed)

    def make_batch(i):
        b = ds.batch(i)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.embed_inputs:
            # stub frontend: embed via the table (frontends are stubs)
            out["embeds"] = params["embed"]["table"][out["tokens"]]
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(seq)[None], (batch, seq))
            out["mrope_positions"] = jnp.asarray(
                np.stack([pos, pos, pos]), jnp.int32)
        if cfg.encoder_layers:
            out["enc_frames"] = jnp.asarray(rng.normal(
                size=(batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        return out

    @jax.jit
    def step_fn(params, opt_state, batch_):
        loss, grads = jax.value_and_grad(model.loss)(params, batch_)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        params, opt_state, loss = step_fn(params, opt_state, make_batch(i))
        if i % log_every == 0 or i == steps - 1:
            lv = float(loss)
            history.append({"step": i, "loss": lv})
            log.info("arch=%s step=%d loss=%.4f", arch, i, lv)
    wall = time.perf_counter() - t0
    if checkpoint_dir:
        save_checkpoint(checkpoint_dir, steps, {"params": params})
    return {"history": history, "wall_s": wall, "params": params,
            "final_loss": history[-1]["loss"], "model": model, "cfg": cfg}


def main(argv=None):
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    g = sub.add_parser("gnn")
    g.add_argument("--dataset", default="cora")
    g.add_argument("--model", default="gcn",
                   choices=["gcn", "sage", "gat", "gat_e"])
    g.add_argument("--strategy", default="global",
                   choices=["global", "mini", "cluster"])
    g.add_argument("--steps", type=int, default=100)
    g.add_argument("--hidden", type=int, default=64)
    g.add_argument("--layers", type=int, default=2)
    g.add_argument("--engine-partitions", type=int, default=0,
                   help="use the distributed engine with P partitions "
                        "(requires that many jax devices)")
    g.add_argument("--partition-method", default="1d_src",
                   choices=["1d_src", "1d_dst", "vertex_cut"])
    g.add_argument("--prefetch-workers", type=int, default=None,
                   help="view-builder threads for the engine path "
                        "(default: min(4, cores-1); deterministic for "
                        "any count)")
    g.add_argument("--prefetch-mode", default="thread",
                   choices=["thread", "process"],
                   help="view construction pool: in-process threads "
                        "(default) or supervised sampler processes over "
                        "shared memory (GIL-free builds, bit-identical "
                        "trajectory; degrades to threads with a warning "
                        "where shared memory is unavailable)")
    g.add_argument("--compact", action="store_true",
                   help="compact sampled-subgraph views (relabeled "
                        "local-id blocks, size-bucketed padding) for "
                        "mini/cluster; dense masks stay the parity oracle")
    ft = g.add_argument_group(
        "fault tolerance",
        "supervised training runtime (repro.runtime): retries with "
        "capped exponential backoff, divergence recovery, hardened "
        "checkpoints. Off by default (zero overhead); any flag here "
        "enables the runtime (single-process runs switch to the "
        "bucketed trainer, which handles dense views too).")
    ft.add_argument("--fault-retries", type=int, default=None,
                    metavar="N",
                    help="retry transient view-build / staging / step / "
                         "checkpoint failures up to N times (default "
                         "policy: 3)")
    ft.add_argument("--fault-backoff", type=float, default=None,
                    metavar="SECONDS",
                    help="base backoff before the first retry; grows "
                         "exponentially with deterministic jitter "
                         "(default 0.05s, capped at 2s)")
    ft.add_argument("--on-divergence", default=None,
                    choices=["raise", "skip_view", "rollback"],
                    help="reaction to a non-finite loss: raise (default),"
                         " skip_view (discard the poison update and move "
                         "on), or rollback (restore the last valid "
                         "checkpoint and continue past the poison view)")
    ft.add_argument("--check-finite", action="store_true",
                    help="sync and guard every step's loss (serializes "
                         "the step pipeline; implied by a non-raise "
                         "--on-divergence)")
    ft.add_argument("--step-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="watchdog: fail loudly if a step's loss is not "
                         "available within this many seconds")
    ft.add_argument("--checkpoint-dir", default=None,
                    help="directory for step_<N>.npz checkpoints "
                         "(atomic, checksummed; required by "
                         "--on-divergence rollback)")
    ft.add_argument("--checkpoint-every", type=int, default=0,
                    metavar="STEPS",
                    help="save a checkpoint every N steps (0 = never)")
    ft.add_argument("--resume", action="store_true",
                    help="resume from the newest VALID checkpoint in "
                         "--checkpoint-dir (corrupt files are skipped); "
                         "fresh start if none")
    ft.add_argument("--keep-checkpoints", type=int, default=0,
                    metavar="K",
                    help="retain only the newest K checkpoints "
                         "(0 = keep all)")
    lm = sub.add_parser("lm")
    lm.add_argument("--arch", required=True)
    lm.add_argument("--steps", type=int, default=50)
    lm.add_argument("--batch", type=int, default=8)
    lm.add_argument("--seq", type=int, default=128)
    lm.add_argument("--reduced", action="store_true", default=True)
    lm.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args(argv)

    if args.cmd == "gnn":
        fault_policy = None
        ft_flags = (args.fault_retries, args.fault_backoff,
                    args.on_divergence, args.step_timeout)
        if args.check_finite or any(f is not None for f in ft_flags):
            from repro.runtime import FaultPolicy
            kw = {"check_finite": args.check_finite,
                  "keep_checkpoints": args.keep_checkpoints}
            if args.fault_retries is not None:
                kw["max_retries"] = args.fault_retries
            if args.fault_backoff is not None:
                kw["backoff_base"] = args.fault_backoff
            if args.on_divergence is not None:
                kw["on_divergence"] = args.on_divergence
            if args.step_timeout is not None:
                kw["timeouts"] = {"step": args.step_timeout}
            fault_policy = FaultPolicy(**kw)
        # SIGINT/SIGTERM during fit: raise in the main thread so fit's
        # finally drains the prefetch service (no orphaned sampler
        # processes), api.train saves a final checkpoint, and the CLI
        # exits nonzero (128 + signum, the shell convention)
        import signal
        from repro.runtime.faults import TrainingInterrupted

        def _interrupt(signum, frame):
            raise TrainingInterrupted(signum)

        previous = {s: signal.signal(s, _interrupt)
                    for s in (signal.SIGINT, signal.SIGTERM)}
        try:
            out = train_gnn(args.dataset, args.model, args.strategy,
                            args.steps,
                            hidden=args.hidden, num_layers=args.layers,
                            use_engine=args.engine_partitions or None,
                            partition_method=args.partition_method,
                            prefetch_workers=args.prefetch_workers,
                            prefetch_mode=args.prefetch_mode,
                            compact=args.compact,
                            fault_policy=fault_policy,
                            checkpoint_dir=args.checkpoint_dir,
                            checkpoint_every=args.checkpoint_every,
                            resume=args.resume)
        except TrainingInterrupted as e:
            where = (f"checkpoint saved to {args.checkpoint_dir}"
                     if args.checkpoint_dir else "no --checkpoint-dir, "
                     "progress discarded")
            print(f"interrupted by signal {e.signum} — {where}",
                  file=sys.stderr)
            return 128 + e.signum
        finally:
            for s, h in previous.items():
                signal.signal(s, h)
        print(f"final test acc: {out['final_acc']:.4f} "
              f"({out['wall_s']:.1f}s)")
    else:
        out = train_lm(args.arch, args.steps, args.batch, args.seq,
                       reduced=args.reduced,
                       checkpoint_dir=args.checkpoint_dir)
        print(f"final loss: {out['final_loss']:.4f} ({out['wall_s']:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
