"""The public facade: ``train()`` / ``infer()`` / ``serve()``.

One typed surface over what used to be scattered across ``Trainer`` vs
``CompactTrainer`` ctor kwargs, ``strategy_views(..., compact=...)`` and
the ``launch/train.py`` flag soup::

    import repro.api as api

    result = api.train(api.TrainJob(dataset="cora", strategy="mini",
                                    compact=True, steps=200))
    logits = api.infer(result, nodes=[3, 7, 11])
    server = api.serve(result, api.ServeConfig(max_batch=16))

``train`` routes to the right trainer from the job alone — the
distributed :class:`~repro.core.trainer.Trainer` when
``engine_partitions`` is set, the bucketed
:class:`~repro.core.trainer.CompactTrainer` otherwise (it drives dense
and compact views alike) — and every trainer is a
:class:`~repro.core.trainer.BaseTrainer`, so callers can keep training,
checkpointing or evaluating through one type. The old entrypoints
(``repro.launch.train.train_gnn``, direct trainer construction) remain
as thin shims; see the README migration table.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Union

import jax
import numpy as np

from repro.graph.csr import Graph


@dataclass
class TrainJob:
    """Everything one GNN training run needs, in one place.

    ``dataset`` is a registered dataset name (``repro.graph.make_dataset``)
    or an already-built :class:`Graph` (used as-is — no self-loop edit).
    Strategy knobs that don't apply to the chosen strategy are ignored,
    matching the old ``strategy_views`` behavior.
    """
    dataset: Union[str, Graph] = "cora"
    model: str = "gcn"                 # gcn | sage | sage_max | gat | gat_e
    strategy: str = "global"           # global | mini | cluster
    steps: int = 100
    num_layers: int = 2
    hidden: int = 64
    lr: float = 1e-2
    weight_decay: float = 5e-4
    seed: int = 0
    eval_every: int = 20
    # view construction
    compact: bool = False              # compact views + bucketed trainer
    batch_nodes: int = 0               # mini (0 = 10% of labeled nodes)
    clusters_per_batch: int = 0        # cluster (0 = num_clusters // 20)
    halo_hops: int = 0
    neighbor_cap: int = 0
    # distributed engine
    engine_partitions: int = 0         # 0 = single-process bucketed path
    partition_method: str = "1d_src"
    prefetch_workers: Optional[int] = None
    prefetch_mode: str = "thread"      # thread | process (sampler procs)
    # fault tolerance / checkpointing (repro.runtime)
    fault_policy: Optional[Any] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    resume: bool = False
    log_every: int = 1


@dataclass
class ServeConfig:
    """Knobs of the online inference server (:mod:`repro.serving`)."""
    max_batch: int = 32
    max_wait_ms: float = 2.0
    max_queue: Optional[int] = None    # bounded admission (None = 8*batch)
    cache: bool = True                 # historical-embedding cache
    staleness: int = 0                 # max version age for a cache hit
    buckets: Optional[Any] = None      # BucketSpec (None = graph ladder)
    slots: int = 2
    checkpoint_dir: Optional[str] = None   # serve params from a checkpoint


@dataclass
class TrainResult:
    """What ``train()`` hands back — and what ``infer()``/``serve()``
    consume, so the three entrypoints chain without the caller ever
    touching trainer internals."""
    params: Any
    model: Any
    graph: Graph
    history: list
    final_acc: float
    wall_s: float
    gcn_norm: bool = True
    trainer: Optional[Any] = None      # the BaseTrainer (engine or bucketed)

    def as_dict(self) -> dict:
        """The legacy ``launch.train.train_gnn`` return shape."""
        return {"history": self.history, "wall_s": self.wall_s,
                "params": self.params, "final_acc": self.final_acc,
                "model": self.model, "graph": self.graph}


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def _resolve_graph(job: TrainJob) -> Graph:
    if isinstance(job.dataset, Graph):
        return job.dataset
    from repro.graph import make_dataset
    g = make_dataset(job.dataset, seed=job.seed)
    # GCN's spectral norm assumes self-loops (named datasets only — a
    # caller-supplied Graph is trusted to be ready to train on)
    return g.add_self_loops() if job.model == "gcn" else g


def _build(job: TrainJob):
    """(graph, model, params, opt, views, eval_view, eval_mask) for a
    job — the shared front half of every training path."""
    from repro.core.strategies import global_batch_view, strategy_views
    from repro.models import make_gnn
    from repro.optim import adam
    from repro.config import GNNConfig

    g = _resolve_graph(job)
    edge_dim = (g.edge_features.shape[1]
                if g.edge_features is not None else 0)
    if job.model == "gat_e" and edge_dim == 0:
        raise ValueError("gat_e needs an edge-attributed dataset "
                         "(alipay_like)")
    cfg = GNNConfig(model=job.model, num_layers=job.num_layers,
                    hidden_dim=job.hidden,
                    num_classes=int(g.labels.max()) + 1,
                    feature_dim=g.node_features.shape[1],
                    edge_feature_dim=edge_dim, num_heads=4)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(job.seed), cfg.feature_dim)
    opt = adam(job.lr, weight_decay=job.weight_decay)

    labeled = int((g.train_mask if g.train_mask is not None
                   else np.ones(g.num_nodes, bool)).sum())
    clusters = None
    clusters_per_batch = 0
    if job.strategy == "cluster":
        from repro.core.clustering import label_propagation_clusters
        clusters = label_propagation_clusters(
            g, max_cluster_size=max(64, g.num_nodes // 50), seed=job.seed)
        clusters_per_batch = (job.clusters_per_batch
                              or max(1, (int(clusters.max()) + 1) // 20))
    # compact sampled-subgraph views apply to the sampling strategies;
    # the global view IS the graph
    compact = job.compact and job.strategy in ("mini", "cluster")
    views = strategy_views(
        g, job.strategy, job.num_layers, seed=job.seed,
        batch_nodes=job.batch_nodes or max(32, labeled // 10),
        clusters=clusters, clusters_per_batch=clusters_per_batch,
        halo_hops=job.halo_hops, neighbor_cap=job.neighbor_cap,
        compact=compact)
    eval_view = global_batch_view(g, job.num_layers)
    test_mask = (g.test_mask if g.test_mask is not None else g.train_mask)
    eval_mask = (test_mask if test_mask is None
                 else test_mask.astype(np.float32))
    return g, model, params, opt, views, eval_view, eval_mask


def make_trainer(job: TrainJob):
    """The job's :class:`~repro.core.trainer.BaseTrainer` plus its view
    stream and eval pieces — for callers that want the training loop's
    parts without running it. ``train()`` is this + ``fit`` + packaging."""
    g, model, params, opt, views, eval_view, eval_mask = _build(job)
    if job.engine_partitions:
        from repro.core.partition import build_partitions
        from repro.core.engine import HybridParallelEngine
        from repro.core.trainer import Trainer
        sg = build_partitions(g, job.engine_partitions,
                              method=job.partition_method,
                              gcn_norm=job.model == "gcn")
        trainer = Trainer(HybridParallelEngine(model, sg), opt,
                          params=params, fault_policy=job.fault_policy)
    else:
        from repro.core.trainer import CompactTrainer
        trainer = CompactTrainer(model, g, opt, params=params,
                                 gcn_norm=job.model == "gcn",
                                 fault_policy=job.fault_policy)
    return trainer, views, eval_view, eval_mask, g, model


def train(job: TrainJob, log=None) -> TrainResult:
    """Run the job end to end: build graph/model/views, fit the right
    trainer, certify its trace contract, evaluate. Deterministic in
    ``job.seed`` (prefetch parallelism never changes the trajectory)."""
    from repro.runtime.faults import TrainingInterrupted
    from repro.utils import get_logger
    log = log or get_logger("api").info
    trainer, views, eval_view, eval_mask, g, model = make_trainer(job)
    t0 = time.perf_counter()
    try:
        out = trainer.fit(views, steps=job.steps,
                          eval_every=job.eval_every,
                          eval_view=eval_view, eval_mask=eval_mask,
                          prefetch_workers=job.prefetch_workers,
                          prefetch_mode=job.prefetch_mode,
                          checkpoint_every=job.checkpoint_every,
                          checkpoint_dir=job.checkpoint_dir,
                          resume=job.resume,
                          log_every=job.log_every, log=log)
    except TrainingInterrupted:
        # a signal handler fired mid-fit: fit's finally already drained
        # the prefetch service (no orphaned sampler processes); persist
        # the progress so --resume can pick the run back up
        if job.checkpoint_dir:
            trainer.save(job.checkpoint_dir)
            log(f"interrupted at step {trainer.step_num} — checkpoint "
                f"saved to {job.checkpoint_dir}")
        raise
    wall = time.perf_counter() - t0
    trainer.assert_trace_contract()
    history = [{"step": e["step"], "loss": e["loss"],
                "test_acc": e["eval_acc"]} for e in out["evals"]]
    if history and history[-1]["step"] == trainer.step_num:
        final_acc = history[-1]["test_acc"]   # fit already evaluated
    else:
        final_acc = trainer.evaluate(eval_view, eval_mask)
        loss = out["losses"][-1] if out["losses"] else float("nan")
        history.append({"step": trainer.step_num, "loss": loss,
                        "test_acc": final_acc})
    return TrainResult(params=trainer.params, model=model, graph=g,
                       history=history, final_acc=final_acc, wall_s=wall,
                       gcn_norm=job.model == "gcn", trainer=trainer)


# ---------------------------------------------------------------------------
# infer / serve
# ---------------------------------------------------------------------------


def infer(result: TrainResult,
          nodes: Optional[Sequence[int]] = None) -> np.ndarray:
    """One-shot offline inference: full-graph logits (``(N, C)``), or the
    requested nodes' rows. For sustained request traffic use
    :func:`serve` — batching, bucketed compilation and the embedding
    cache live there."""
    from repro.core.mpgnn import forward_block
    from repro.core.strategies import global_batch_view
    model, g = result.model, result.graph
    block = global_batch_view(g, model.K).as_block(
        gcn_norm=result.gcn_norm,
        csc_plan=getattr(model, "aggregate_backend", "reference") == "csc")
    logits = np.asarray(forward_block(model, result.params, block))
    logits = logits[:g.num_nodes]
    if nodes is None:
        return logits
    return logits[np.asarray(nodes, np.int64)]


def serve(result: TrainResult,
          config: Optional[ServeConfig] = None):
    """An online :class:`~repro.serving.server.GNNServer` over the
    trained model. ``config.checkpoint_dir`` serves the params stored in
    a checkpoint instead of the in-memory ones (the train -> checkpoint
    -> serve round trip)."""
    from repro.serving import GNNServer
    config = config or ServeConfig()
    params = result.params
    if config.checkpoint_dir:
        from repro.checkpoint import load_checkpoint
        params = load_checkpoint(config.checkpoint_dir)["params"]
    return GNNServer(result.model, params, result.graph,
                     buckets=config.buckets, cache=config.cache,
                     staleness=config.staleness,
                     max_batch=config.max_batch,
                     max_wait_ms=config.max_wait_ms,
                     max_queue=config.max_queue,
                     gcn_norm=result.gcn_norm, slots=config.slots)


__all__ = ["TrainJob", "ServeConfig", "TrainResult", "train", "infer",
           "serve", "make_trainer"]
