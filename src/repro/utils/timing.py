"""Wall-clock timing helpers for benchmarks."""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating timer; use as context manager or .tic()/.toc()."""
    name: str = ""
    total_s: float = 0.0
    count: int = 0
    _t0: float = field(default=0.0, repr=False)

    def tic(self):
        self._t0 = time.perf_counter()
        return self

    def toc(self) -> float:
        dt = time.perf_counter() - self._t0
        self.total_s += dt
        self.count += 1
        return dt

    def __enter__(self):
        return self.tic()

    def __exit__(self, *exc):
        self.toc()
        return False

    @property
    def mean_us(self) -> float:
        return (self.total_s / max(self.count, 1)) * 1e6


@contextlib.contextmanager
def timed(sink: dict, key: str):
    t0 = time.perf_counter()
    try:
        yield
    finally:
        sink[key] = sink.get(key, 0.0) + (time.perf_counter() - t0)
