"""Version compatibility shims for the jax API surface we use.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg ``check_rep`` -> ``check_vma``
along the way; this wrapper presents one signature for both.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                      # jax < 0.6: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Uniform shard_map: per-device ``f`` over ``mesh`` with the
    replication/VMA check toggled by ``check`` on any jax version."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})
