"""Pytree helpers used across the framework (no flax/optax available)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree) -> int:
    """Total bytes of all array leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "dtype") and hasattr(leaf, "size"):
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def tree_count_params(tree) -> int:
    """Total element count of all array leaves."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def tree_global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)
