from repro.utils.tree import (
    tree_size_bytes,
    tree_count_params,
    tree_zeros_like,
    tree_cast,
    tree_global_norm,
    tree_add,
    tree_scale,
)
from repro.utils.timing import Timer, timed
from repro.utils.logging import get_logger

__all__ = [
    "tree_size_bytes",
    "tree_count_params",
    "tree_zeros_like",
    "tree_cast",
    "tree_global_norm",
    "tree_add",
    "tree_scale",
    "Timer",
    "timed",
    "get_logger",
]
