"""Transformer blocks shared by the assigned architectures.

One ``block_init/block_apply`` pair covers: dense SwiGLU decoders (qwen3,
phi3), GQA w/ qk-norm, sliding-window (mixtral), MoE FFN (dbrx, mixtral,
jamba), MLA (minicpm3), Mamba mixer (jamba), RWKV-6 (rwkv6), enc-dec with
cross-attention (whisper), and M-RoPE (qwen2-vl). The kind of each layer is
static config; caches are explicit pytrees.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.nn.layers import (
    rmsnorm_init, rmsnorm_apply, layernorm_init, layernorm_apply,
    swiglu_init, swiglu_apply, gelu_mlp_init, gelu_mlp_apply,
)
from repro.nn.attention import attention_init, attention_apply, mla_init, \
    mla_apply
from repro.arch.moe import moe_init, moe_ffn_dense, moe_ffn_ep
from repro.arch.mamba import mamba_init, mamba_apply, mamba_init_cache
from repro.arch.rwkv6_block import (
    rwkv_time_init, rwkv_time_apply, rwkv_channel_init, rwkv_channel_apply,
    rwkv_init_cache,
)
from repro.arch.hints import shard_hint


def _norm_init(cfg: ArchConfig, dtype):
    if getattr(cfg, "norm_type", "rmsnorm") == "layernorm":
        return layernorm_init(cfg.d_model, dtype)
    return rmsnorm_init(cfg.d_model, dtype)


def norm_apply(cfg: ArchConfig, p, x):
    if "bias" in p:
        return layernorm_apply(p, x, cfg.norm_eps)
    return rmsnorm_apply(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# block init
# ---------------------------------------------------------------------------


def block_init(key, cfg: ArchConfig, kind: str, dtype,
               cross_attention: bool = False, use_moe: bool = True):
    """kind: "attn" | "mamba" | "rwkv". ``use_moe``: whether THIS layer's
    FFN is MoE (jamba puts MoE on every moe_every-th layer only)."""
    ks = jax.random.split(key, 6)
    moe_here = cfg.moe is not None and use_moe
    p: dict = {"norm1": _norm_init(cfg, dtype)}
    if kind == "attn":
        if cfg.mla is not None:
            p["attn"] = mla_init(ks[0], cfg.d_model, cfg.num_heads, cfg.mla,
                                 dtype)
        else:
            p["attn"] = attention_init(
                ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, dtype, qk_norm=cfg.qk_norm)
        if cross_attention:
            p["norm_x"] = _norm_init(cfg, dtype)
            p["xattn"] = attention_init(
                ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, dtype)
        p["norm2"] = _norm_init(cfg, dtype)
        if moe_here:
            p["ffn"] = moe_init(ks[2], cfg.d_model, cfg.d_ff,
                                cfg.moe.num_experts, dtype)
        elif getattr(cfg, "norm_type", "rmsnorm") == "layernorm":
            p["ffn"] = gelu_mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "mamba":
        p["mixer"] = mamba_init(ks[0], cfg.d_model, cfg.mamba, dtype)
        p["norm2"] = _norm_init(cfg, dtype)
        if moe_here:
            p["ffn"] = moe_init(ks[2], cfg.d_model, cfg.d_ff,
                                cfg.moe.num_experts, dtype)
        else:
            p["ffn"] = swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "rwkv":
        p["time"] = rwkv_time_init(ks[0], cfg.d_model, cfg.rwkv, dtype)
        p["norm2"] = _norm_init(cfg, dtype)
        p["channel"] = rwkv_channel_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p


def block_cache_init(cfg: ArchConfig, kind: str, batch: int, cache_len: int,
                     dtype, rolling: bool = False):
    """Decode cache for one block of the given kind."""
    if kind == "attn":
        if cfg.mla is not None:
            m = cfg.mla
            return {"c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank),
                                      dtype),
                    "k_rope": jnp.zeros((batch, cache_len,
                                         m.qk_rope_head_dim), dtype)}
        hd = cfg.resolved_head_dim
        c = {"k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
             "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype)}
        if rolling:
            c["pos"] = jnp.full((cache_len,), -1, jnp.int32)
        return c
    if kind == "mamba":
        return mamba_init_cache(None, batch, cfg.mamba, cfg.d_model, dtype)
    if kind == "rwkv":
        return rwkv_init_cache(batch, cfg.d_model, cfg.rwkv, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def _ffn_apply(p_ffn, x, cfg: ArchConfig, moe_impl: str, mesh):
    if cfg.moe is not None and "router" in p_ffn:
        if moe_impl == "ep" and mesh is not None:
            dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
            return moe_ffn_ep(p_ffn, x, cfg.moe, mesh, axis="model",
                              dp_axis=dp)
        return moe_ffn_dense(p_ffn, x, cfg.moe)
    if "wi" in p_ffn:                       # gelu mlp (whisper)
        return gelu_mlp_apply(p_ffn, x), jnp.zeros((), jnp.float32)
    return swiglu_apply(p_ffn, x), jnp.zeros((), jnp.float32)


def block_apply(p, x, cfg: ArchConfig, kind: str, *,
                positions=None, mrope_positions=None, causal=True,
                cache=None, cache_index=None, enc_memory=None,
                moe_impl: str = "dense", mesh=None,
                sliding_window: Optional[int] = None, valid=None):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss).
    ``valid``: (B, P) pad mask over the first P cache slots (serving
    with left-padded prompts); only the attention paths consume it."""
    aux = jnp.zeros((), jnp.float32)
    sw = cfg.sliding_window if sliding_window is None else sliding_window
    new_cache = None

    if kind == "attn":
        h = norm_apply(cfg, p["norm1"], x)
        h = shard_hint(h, "batch", "seq", None)
        if cfg.mla is not None:
            if cache is not None:
                a, c_attn = mla_apply(
                    p["attn"], h, num_heads=cfg.num_heads, mla=cfg.mla,
                    positions=positions, rope_theta=cfg.rope_theta,
                    norm_eps=cfg.norm_eps, cache=cache,
                    cache_index=cache_index, valid=valid)
            else:
                a = mla_apply(p["attn"], h, num_heads=cfg.num_heads,
                              mla=cfg.mla, positions=positions,
                              rope_theta=cfg.rope_theta,
                              norm_eps=cfg.norm_eps)
                c_attn = None
        else:
            out = attention_apply(
                p["attn"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                norm_eps=cfg.norm_eps, causal=causal, sliding_window=sw,
                cache=cache, cache_index=cache_index,
                mrope_positions=mrope_positions, valid=valid)
            a, c_attn = out if cache is not None else (out, None)
        x = x + a
        if enc_memory is not None:
            hx = norm_apply(cfg, p["norm_x"], x)
            x = x + attention_apply(
                p["xattn"], hx, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, kv_x=enc_memory,
                causal=False)
        h2 = norm_apply(cfg, p["norm2"], x)
        h2 = shard_hint(h2, "batch", "seq", None)
        f, aux = _ffn_apply(p["ffn"], h2, cfg, moe_impl, mesh)
        x = x + f
        new_cache = c_attn

    elif kind == "mamba":
        h = norm_apply(cfg, p["norm1"], x)
        m, c_m = mamba_apply(p["mixer"], h, cfg.mamba, cache=cache)
        x = x + m
        h2 = norm_apply(cfg, p["norm2"], x)
        f, aux = _ffn_apply(p["ffn"], h2, cfg, moe_impl, mesh)
        x = x + f
        new_cache = c_m

    elif kind == "rwkv":
        h = norm_apply(cfg, p["norm1"], x)
        t, c_t = rwkv_time_apply(p["time"], h, cfg.rwkv, cfg.norm_eps,
                                 cache=cache["time"] if cache else None)
        x = x + t
        h2 = norm_apply(cfg, p["norm2"], x)
        c, c_c = rwkv_channel_apply(p["channel"], h2,
                                    cache=cache["channel"] if cache else None)
        x = x + c
        new_cache = ({"time": c_t, "channel": c_c}
                     if cache is not None else None)
    else:
        raise ValueError(kind)

    x = shard_hint(x, "batch", "seq", None)
    return x, new_cache, aux
