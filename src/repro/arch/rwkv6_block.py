"""RWKV-6 ("Finch") block: time mixing with data-dependent decay + channel
mixing. arXiv:2404.05892.

Faithful pieces: token-shift interpolation, low-rank **data-dependent
decay** w_t = exp(-exp(w0 + tanh(x̂ A) B)) (the Finch signature), per-head
WKV recurrence with bonus ``u``, SiLU gate, squared-ReLU channel mix.
Simplification (noted in DESIGN.md): static token-shift mixing
coefficients (RWKV-5 style) instead of the data-dependent ddlerp — the
recurrence itself, which is what the system exercises, is unchanged.

The train path uses the chunked log-domain formulation (pure-jnp mirror of
kernels/wkv6.py — the Pallas kernel is the serving hot path); decode is the
O(1) per-token recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import _fan_in_init, rmsnorm_init, rmsnorm_apply


def rwkv_time_init(key, d_model, rc, dtype):
    ks = jax.random.split(key, 9)
    H = d_model // rc.head_dim
    return {
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_v": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_w": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_g": jnp.full((d_model,), 0.5, jnp.float32),
        "w_r": _fan_in_init(ks[0], (d_model, d_model), dtype),
        "w_k": _fan_in_init(ks[1], (d_model, d_model), dtype),
        "w_v": _fan_in_init(ks[2], (d_model, d_model), dtype),
        "w_g": _fan_in_init(ks[3], (d_model, d_model), dtype),
        "w_o": _fan_in_init(ks[4], (d_model, d_model), dtype),
        # data-dependent decay lora (Finch): w0 + tanh(x A) B
        "decay_w0": jnp.full((d_model,), -2.0, jnp.float32),
        "decay_A": _fan_in_init(ks[5], (d_model, rc.decay_lora),
                                jnp.float32),
        "decay_B": _fan_in_init(ks[6], (rc.decay_lora, d_model),
                                jnp.float32),
        "bonus_u": (jax.random.normal(ks[7], (H, rc.head_dim), jnp.float32)
                    * 0.1),
        "ln_x": rmsnorm_init(d_model, jnp.float32),
    }


def rwkv_channel_init(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "w_k": _fan_in_init(ks[0], (d_model, d_ff), dtype),
        "w_v": _fan_in_init(ks[1], (d_ff, d_model), dtype),
        "w_r": _fan_in_init(ks[2], (d_model, d_model), dtype),
    }


def _token_shift(x, last):
    """shifted[t] = x[t-1]; shifted[0] = last (carried across steps)."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def wkv_chunked(r, k, v, w, u, chunk):
    """Pure-jnp chunked WKV (same math as kernels/wkv6.py), fully parallel
    over chunks: intra-chunk pairwise-decay attention is batched, and the
    chunk-boundary state recurrence is a log-depth ``associative_scan``
    over affine maps (see mamba._ssd_chunked for why: TPU parallelism and
    honest While-free cost accounting).

    r,k,w: (B,T,H,K) v: (B,T,H,V) u: (H,K) -> (o, S_final).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    nc = T // chunk
    f32 = lambda a: a.astype(jnp.float32)
    rc_ = f32(r).reshape(B, nc, chunk, H, K)
    kc = f32(k).reshape(B, nc, chunk, H, K)
    vc = f32(v).reshape(B, nc, chunk, H, V)
    lw = jnp.log(jnp.maximum(f32(w), 1e-12)).reshape(B, nc, chunk, H, K)
    la = jnp.cumsum(lw, axis=2)
    la_ex = la - lw

    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (u_i < t_i)[None, None, :, :, None]
    diag = (t_i == u_i)[None, None, :, :, None]

    # ---- intra-chunk (parallel over chunks) --------------------------------
    ldiff = la_ex[:, :, :, None] - la[:, :, None]          # (B,nc,L,L,H,K)
    decay = jnp.where(strict[..., None], jnp.exp(ldiff), 0.0)
    scores = jnp.einsum("bclhk,bcmhk,bclmhk->bclmh", rc_, kc, decay)
    db = jnp.einsum("bclhk,bclhk,hk->bclh", rc_, kc, u)
    scores = scores + jnp.where(diag, db[:, :, :, None], 0.0)
    o = jnp.einsum("bclmh,bcmhv->bclhv", scores, vc)

    # ---- per-chunk state summaries ------------------------------------------
    la_last = la[:, :, -1]                                 # (B,nc,H,K)
    k_dec = kc * jnp.exp(la_last[:, :, None] - la)
    Bhat = jnp.einsum("bclhk,bclhv->bchkv", k_dec, vc)     # (B,nc,H,K,V)
    A = jnp.exp(la_last)                                   # (B,nc,H,K)

    def combine(l_, r_):
        a1, b1 = l_
        a2, b2 = r_
        return a2 * a1, a2[..., None] * b1 + b2

    A_acc, B_acc = jax.lax.associative_scan(combine, (A, Bhat), axis=1)
    S_final = B_acc[:, -1]
    S_prev = jnp.concatenate(
        [jnp.zeros_like(B_acc[:, :1]), B_acc[:, :-1]], axis=1)

    # ---- inter-chunk contribution --------------------------------------------
    o = o + jnp.einsum("bclhk,bchkv->bclhv", rc_ * jnp.exp(la_ex), S_prev)
    return o.reshape(B, T, H, V), S_final


def rwkv_time_apply(p, x, rc, norm_eps, cache=None):
    """Time mixing. cache (decode): {"last": (B,1,D), "state": (B,H,K,V)}."""
    B, T, D = x.shape
    H = D // rc.head_dim
    K = rc.head_dim
    last = cache["last"] if cache is not None else jnp.zeros(
        (B, 1, D), x.dtype)
    xs = _token_shift(x, last)
    xr = _mix(x, xs, p["mu_r"])
    xk = _mix(x, xs, p["mu_k"])
    xv = _mix(x, xs, p["mu_v"])
    xw = _mix(x, xs, p["mu_w"])
    xg = _mix(x, xs, p["mu_g"])
    r = (xr @ p["w_r"]).reshape(B, T, H, K)
    k = (xk @ p["w_k"]).reshape(B, T, H, K)
    v = (xv @ p["w_v"]).reshape(B, T, H, K)
    g = jax.nn.silu(xg @ p["w_g"])
    # Finch data-dependent decay, in (0,1): exp(-exp(.))
    dd = p["decay_w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ p["decay_A"]) @ p["decay_B"]
    w = jnp.exp(-jnp.exp(dd)).reshape(B, T, H, K)

    new_cache = None
    if cache is None:
        chunk = min(rc.chunk, T)
        if T % chunk != 0:
            raise ValueError(f"sequence length {T} must be a multiple of "
                             f"chunk {chunk}")
        o, _ = wkv_chunked(r, k, v, w.astype(jnp.float32), p["bonus_u"],
                           chunk)
    elif T > 1:
        # prefill: fresh chunked pass, cache built from the final state
        # (assumes the incoming cache is zero-initialized)
        chunk = min(rc.chunk, T)
        if T % chunk != 0:
            raise ValueError(f"sequence length {T} must be a multiple of "
                             f"chunk {chunk}")
        o, S = wkv_chunked(r, k, v, w.astype(jnp.float32), p["bonus_u"],
                           chunk)
        new_cache = {"last": x[:, -1:], "state": S}
    else:
        S = cache["state"]                                 # (B,H,K,V) f32
        r1 = r[:, 0].astype(jnp.float32)
        k1 = k[:, 0].astype(jnp.float32)
        v1 = v[:, 0].astype(jnp.float32)
        w1 = w[:, 0].astype(jnp.float32)
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        o = jnp.einsum("bhk,bhkv->bhv", r1,
                       S + p["bonus_u"][None, :, :, None] * kv)[:, None]
        S = w1[..., None] * S + kv
        new_cache = {"last": x[:, -1:], "state": S}

    o = o.reshape(B, T, D)
    o = rmsnorm_apply(p["ln_x"], o, norm_eps).astype(x.dtype)
    return (o * g) @ p["w_o"], new_cache


def rwkv_channel_apply(p, x, cache=None):
    """Channel mixing. cache (decode): {"last": (B,1,D)}."""
    B, T, D = x.shape
    last = cache["last"] if cache is not None else jnp.zeros(
        (B, 1, D), x.dtype)
    xs = _token_shift(x, last)
    xk = _mix(x, xs, p["mu_k"])
    xr = _mix(x, xs, p["mu_r"])
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    new_cache = {"last": x[:, -1:]} if cache is not None else None
    return out, new_cache


def rwkv_init_cache(batch, d_model, rc, dtype):
    H = d_model // rc.head_dim
    return {
        "time": {"last": jnp.zeros((batch, 1, d_model), dtype),
                 "state": jnp.zeros((batch, H, rc.head_dim, rc.head_dim),
                                    jnp.float32)},
        "channel": {"last": jnp.zeros((batch, 1, d_model), dtype)},
    }
