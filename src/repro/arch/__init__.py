from repro.arch.model import TransformerLM, build_model, layer_kinds
from repro.arch.hints import use_hints, shard_hint

__all__ = ["TransformerLM", "build_model", "layer_kinds", "use_hints",
           "shard_hint"]
