"""Activation-sharding hints, active only when the launcher arms a mesh.

Models call ``shard_hint(x, "batch", "seq", None)`` with logical axis names;
the launcher maps logical -> mesh axes (GraphTheta-style: one batch is
computed by the whole worker group — DESIGN.md §5). On a bare CPU (smoke
tests) hints are no-ops. Non-divisible dims are silently left unsharded.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_RULES: Optional[dict] = None   # logical name -> mesh axis (or tuple)
_MESH = None


@contextlib.contextmanager
def use_hints(mesh, rules: dict):
    global _RULES, _MESH
    prev = (_RULES, _MESH)
    _RULES, _MESH = rules, mesh
    try:
        yield
    finally:
        _RULES, _MESH = prev


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def shard_hint(x, *logical):
    """Constrain x's sharding; logical names resolve through active rules."""
    if _RULES is None or _MESH is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"logical axes {logical} do not match array "
                         f"rank {x.ndim} (shape {x.shape})")
    spec = []
    for dim, name in zip(x.shape, logical):
        axis = _RULES.get(name) if name is not None else None
        if axis is None:
            spec.append(None)
            continue
        size = _axis_size(_MESH, axis)
        spec.append(axis if (size > 1 and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_MESH, P(*spec)))
