"""Selective SSM (Mamba) block — TPU-adapted chunked (SSD-style) scan.

Hardware adaptation (DESIGN.md): Mamba-1's per-(channel,state) decay makes
the chunked form VPU-bound; following Mamba-2/SSD we use **one scalar decay
per head per step**, which turns both intra-chunk and state-carry math into
MXU matmuls. Heads are independent → sharded over the 'model' mesh axis
(sequence stays unsharded: the chunk scan is a sequential dependency, the
reason SSMs don't sequence-parallelize — noted in DESIGN.md §5).

Decode is the O(1) recurrence: conv window cache + (H, P, N) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.layers import _fan_in_init
from repro.arch.hints import shard_hint


def mamba_init(key, d_model, mc, dtype):
    d_in = mc.expand * d_model
    H = d_in // mc.head_dim
    dt_rank = mc.dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    # A init in [1, H] log-spaced (standard S4/Mamba init), scalar per head
    a = np.linspace(1.0, 16.0, H).astype(np.float32)
    return {
        "in_proj": _fan_in_init(ks[0], (d_model, 2 * d_in), dtype),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, d_in), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": _fan_in_init(ks[2], (d_in, dt_rank + 2 * mc.d_state),
                               dtype),
        "dt_proj": _fan_in_init(ks[3], (dt_rank, H), jnp.float32),
        "dt_bias": jnp.asarray(np.log(np.expm1(
            np.clip(np.exp(np.random.default_rng(0).uniform(
                np.log(1e-3), np.log(1e-1), H)), 1e-4, None))),
            jnp.float32),
        "A_log": jnp.asarray(np.log(a), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": _fan_in_init(ks[4], (d_in, d_model), dtype),
    }


def _causal_conv(x, w, b):
    """x (B,T,C), w (K,C) depthwise causal conv."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(xh, dt, a_log_cum, Bm, Cm, chunk):
    """Chunked selective scan — fully parallel over chunks.

    Intra-chunk terms and per-chunk state summaries are batched einsums;
    the only sequential dependency — the chunk-boundary state recurrence
    S_j = A_j ⊙ S_{j-1} + B̂_j — is a log-depth ``associative_scan`` over
    affine maps, not a While loop. (Besides exposing parallelism on the
    TPU, this keeps XLA's cost model honest: While bodies are costed once
    regardless of trip count — see DESIGN.md §roofline-methodology.)

    xh: (B,T,H,P)  dt: (B,T,H)  a_log_cum: chunk-local cumsum(log a),
    Bm, Cm: (B,T,N). Returns y (B,T,H,P) and final state (B,H,P,N).
    """
    B_, T, H, P_ = xh.shape
    N = Bm.shape[-1]
    nc = T // chunk
    xc = xh.reshape(B_, nc, chunk, H, P_).astype(jnp.float32)
    dtc = dt.reshape(B_, nc, chunk, H).astype(jnp.float32)
    lac = a_log_cum.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, chunk, N).astype(jnp.float32)

    # ---- intra-chunk (parallel over chunks) --------------------------------
    G = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)              # (B,nc,L,L)
    diff = lac[:, :, :, None, :] - lac[:, :, None, :, :]   # (B,nc,L,L,H) <=0
    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (u_i <= t_i)[None, None, :, :, None]
    M = jnp.where(causal, jnp.exp(diff), 0.0) * G[..., None] \
        * dtc[:, :, None, :, :]
    y = jnp.einsum("bclmh,bcmhp->bclhp", M, xc)

    # ---- per-chunk state summaries ------------------------------------------
    la_last = lac[:, :, -1, :]                              # (B,nc,H)
    damp = jnp.exp(la_last[:, :, None, :] - lac)            # (B,nc,L,H)
    dB = jnp.einsum("bclh,bcln->bclhn", dtc * damp, Bc)
    Bhat = jnp.einsum("bclhn,bclhp->bchpn", dB, xc)         # (B,nc,H,P,N)
    A = jnp.exp(la_last)                                    # (B,nc,H)

    # ---- associative scan over affine maps S -> A∘S + B̂ ---------------------
    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a2 * a1, a2[..., None, None] * b1 + b2

    A_acc, B_acc = jax.lax.associative_scan(combine, (A, Bhat), axis=1)
    S_final = B_acc[:, -1]                                  # (B,H,P,N)
    # exclusive prefix: state entering chunk j
    S_prev = jnp.concatenate(
        [jnp.zeros_like(B_acc[:, :1]), B_acc[:, :-1]], axis=1)

    # ---- inter-chunk contribution (parallel) ---------------------------------
    y = y + jnp.exp(lac)[..., None] * jnp.einsum(
        "bcln,bchpn->bclhp", Cc, S_prev)
    return y.reshape(B_, T, H, P_), S_final


def mamba_apply(p, x, mc, cache=None):
    """x (B,T,D). cache (decode): {"conv": (B,K-1,d_in), "state": (B,H,P,N)}.

    Returns (out, new_cache_or_None).
    """
    B, T, D = x.shape
    d_in = mc.expand * D
    H = d_in // mc.head_dim
    P_ = mc.head_dim
    N = mc.d_state
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)

    new_cache = None
    if cache is None or T > 1:
        xc = _causal_conv(xi, p["conv_w"], p["conv_b"])
    else:
        window = jnp.concatenate([cache["conv"], xi], axis=1)  # (B,K-1+T,d)
        K = p["conv_w"].shape[0]
        xc = jnp.einsum("btc,tc->bc", window[:, -K:],
                        p["conv_w"].astype(jnp.float32))[:, None, :]
        xc = (xc + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        new_conv = window[:, -(K - 1):]
    xc = jax.nn.silu(xc)
    xc = shard_hint(xc, "batch", None, "heads_flat")

    proj = xc @ p["x_proj"]
    dt_rank = p["dt_proj"].shape[0]
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])                  # (B,T,H)
    A = -jnp.exp(p["A_log"])                              # (H,) negative
    log_a = dt * A[None, None, :]                          # (B,T,H) <= 0
    xh = xc.reshape(B, T, H, P_)

    if cache is None or T > 1:
        chunk = min(mc.chunk, T)
        if T % chunk != 0:
            raise ValueError(f"sequence length {T} must be a multiple of "
                             f"chunk {chunk}")
        la_chunklocal = jnp.cumsum(
            log_a.reshape(B, T // chunk, chunk, H), axis=2
        ).reshape(B, T, H)
        y, S = _ssd_chunked(xh, dt, la_chunklocal, Bm, Cm, chunk)
        if cache is not None:
            # prefill: cache = final SSM state + conv window tail
            # (assumes the incoming cache is zero-initialized)
            K = p["conv_w"].shape[0]
            tail = jnp.pad(xi, ((0, 0), (max(K - 1 - T, 0), 0), (0, 0)))
            new_cache = {"conv": tail[:, -(K - 1):], "state": S}
    else:
        # one-step recurrence
        S = cache["state"]                                # (B,H,P,N)
        a = jnp.exp(log_a[:, 0])                          # (B,H)
        dB = jnp.einsum("bh,bn->bhn", dt[:, 0], Bm[:, 0].astype(jnp.float32))
        S = a[:, :, None, None] * S + jnp.einsum(
            "bhn,bhp->bhpn", dB, xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", S,
                       Cm[:, 0].astype(jnp.float32))[:, None]
        new_cache = {"conv": new_conv, "state": S}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, d_in).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, new_cache


def mamba_init_cache(p, batch, mc, d_model, dtype):
    d_in = mc.expand * d_model
    H = d_in // mc.head_dim
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, d_in), dtype),
        "state": jnp.zeros((batch, H, mc.head_dim, mc.d_state),
                           jnp.float32),
    }
