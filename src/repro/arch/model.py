"""Unified model for all assigned architectures: train / prefill / decode.

Layer stacking uses ``lax.scan`` over parameter stacks so the HLO stays
small at 40–72 layers (one While loop per homogeneous group). Hybrid archs
(jamba) scan over *groups*: each group is [attn, mamba × (attn_every-1)];
the mamba sub-stack is an inner scan. Whisper is a bidirectional encoder
scan + causal decoder scan with cross-attention.

The ``kind`` of the model's inputs (tokens / precomputed embeddings /
encoder frames) follows the family; ``repro.launch.dryrun.input_specs``
builds matching ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.nn.layers import embedding_init, rmsnorm_init, layernorm_init, \
    _fan_in_init
from repro.arch.blocks import (
    block_init, block_apply, block_cache_init, norm_apply,
)
from repro.arch.hints import shard_hint

LOSS_CHUNK = 512


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def layer_kinds(cfg: ArchConfig):
    """Static per-layer kind list."""
    if cfg.rwkv is not None:
        return ["rwkv"] * cfg.num_layers
    if cfg.mamba is not None and cfg.attn_every:
        kinds = []
        for i in range(cfg.num_layers):
            kinds.append("attn" if i % cfg.attn_every == 0 else "mamba")
        return kinds
    if cfg.mamba is not None:
        return ["mamba"] * cfg.num_layers
    return ["attn"] * cfg.num_layers


@dataclass
class TransformerLM:
    cfg: ArchConfig
    moe_impl: str = "dense"
    mesh: Any = None
    remat: bool = True
    rolling_window_decode: bool = False   # O(window) SWA decode cache
    unroll_layers: bool = False   # python loop instead of lax.scan (used by
    #                               the dry-run cost calibration: While
    #                               bodies are costed once regardless of
    #                               trip count, unrolled bodies are exact)
    remat_policy: str = "full"    # full | dots | none  (§Perf knob)
    remat_granularity: str = "group"   # group | block: block-level saves
    #                                    each block input -> backward only
    #                                    recomputes one block at a time

    # ------------------------------------------------------------------ init

    def _group_structure(self):
        """(group_kinds, n_groups): layers = group_kinds * n_groups."""
        cfg = self.cfg
        kinds = layer_kinds(cfg)
        if cfg.attn_every and cfg.mamba is not None:
            g = cfg.attn_every
            if cfg.num_layers % g != 0:
                raise ValueError(f"num_layers {cfg.num_layers} must be a "
                                 f"multiple of attn_every {g}")
            return kinds[:g], cfg.num_layers // g
        return [kinds[0]], cfg.num_layers

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 8)
        params: dict = {}
        if not cfg.embed_inputs:
            params["embed"] = embedding_init(keys[0], cfg.vocab_size,
                                             cfg.d_model, dt)
        else:
            params["embed"] = embedding_init(keys[0], cfg.vocab_size,
                                             cfg.d_model, dt)  # lm head use
        group_kinds, n_groups = self._group_structure()

        if cfg.moe is not None and cfg.moe_every > 1:
            if len(group_kinds) % cfg.moe_every != 0:
                raise ValueError(
                    "group size must divide moe_every for uniform layer "
                    f"scan (got {len(group_kinds)} % {cfg.moe_every})")

        def init_group(k):
            ks = jax.random.split(k, len(group_kinds))
            return [block_init(
                ks[i], cfg, kind, dt,
                cross_attention=cfg.cross_attention,
                use_moe=(cfg.moe_every <= 1
                         or i % cfg.moe_every == cfg.moe_every - 1))
                    for i, kind in enumerate(group_kinds)]

        gkeys = jax.random.split(keys[1], n_groups)
        params["blocks"] = jax.vmap(init_group)(gkeys)
        if cfg.encoder_layers:
            enc_cfg = cfg
            ekeys = jax.random.split(keys[2], cfg.encoder_layers)
            params["encoder"] = jax.vmap(
                lambda k: block_init(k, enc_cfg, "attn", dt))(ekeys)
            params["enc_norm"] = (layernorm_init(cfg.d_model, dt)
                                  if cfg.norm_type == "layernorm"
                                  else rmsnorm_init(cfg.d_model, dt))
        params["final_norm"] = (layernorm_init(cfg.d_model, dt)
                                if getattr(cfg, "norm_type", "rmsnorm")
                                == "layernorm"
                                else rmsnorm_init(cfg.d_model, dt))
        if not cfg.tie_embeddings:
            params["lm_head"] = _fan_in_init(
                keys[3], (cfg.d_model, cfg.vocab_size), dt)
        return params

    def param_shapes(self, key=None):
        key = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(self.init, key)

    # ------------------------------------------------------------- backbone

    def _encoder(self, params, frames):
        # unrolled python loop (few layers; keeps XLA cost analysis exact)
        cfg = self.cfg
        x = frames
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        for i in range(cfg.encoder_layers):
            p = jax.tree_util.tree_map(lambda a: a[i], params["encoder"])
            x, _, _ = block_apply(p, x, cfg, "attn", positions=pos,
                                  causal=False, moe_impl=self.moe_impl,
                                  mesh=self.mesh)
        return norm_apply(cfg, params["enc_norm"], x)

    def _backbone(self, params, x, *, positions, mrope_positions=None,
                  caches=None, cache_index=None, enc_memory=None,
                  valid=None, train: bool = False):
        """Runs all layer groups. caches: pytree stacked (n_groups, ...) per
        group slot, or None. Returns (x, new_caches, aux_total)."""
        cfg = self.cfg
        group_kinds, n_groups = self._group_structure()

        do_remat = train and self.remat and self.remat_policy != "none"
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if self.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)

        def one_block(x, p_i, c_i, kind):
            return block_apply(
                p_i, x, cfg, kind, positions=positions,
                mrope_positions=mrope_positions, causal=True,
                cache=c_i, cache_index=cache_index,
                enc_memory=enc_memory, moe_impl=self.moe_impl,
                mesh=self.mesh, sliding_window=cfg.sliding_window,
                valid=valid)

        block_fns = {}
        for kind in set(group_kinds):
            fn = functools.partial(one_block, kind=kind)
            if do_remat and self.remat_granularity == "block":
                fn = jax.checkpoint(fn, policy=policy)
            block_fns[kind] = fn

        def group_apply(x, p_group, c_group):
            new_cs = []
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(group_kinds):
                c = None if c_group is None else c_group[i]
                x, nc, a = block_fns[kind](x, p_group[i], c)
                new_cs.append(nc)
                aux = aux + a
            return x, new_cs, aux

        if do_remat and self.remat_granularity == "group":
            group_apply = jax.checkpoint(group_apply, policy=policy,
                                         static_argnums=())

        def scan_body(carry, inp):
            x, aux = carry
            if caches is None:
                p_group = inp
                x, _, a = group_apply(x, p_group, None)
                return (x, aux + a), None
            p_group, c_group = inp
            x, ncs, a = group_apply(x, p_group, c_group)
            return (x, aux + a), ncs

        if self.unroll_layers:
            aux = jnp.zeros((), jnp.float32)
            new_caches = []
            take = lambda t, i: jax.tree_util.tree_map(lambda a: a[i], t)
            for gi in range(n_groups):
                p_group = take(params["blocks"], gi)
                c_group = None if caches is None else take(caches, gi)
                x, ncs, a = group_apply(x, p_group, c_group)
                aux = aux + a
                new_caches.append(ncs)
            if caches is not None:
                new_caches = jax.tree_util.tree_map(
                    lambda *xs_: jnp.stack(xs_), *new_caches)
            else:
                new_caches = None
            return x, new_caches, aux

        xs = (params["blocks"] if caches is None
              else (params["blocks"], caches))
        (x, aux), new_caches = jax.lax.scan(scan_body,
                                            (x, jnp.zeros((), jnp.float32)),
                                            xs)
        return x, new_caches, aux

    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.embed_inputs:
            x = batch["embeds"].astype(_dtype(cfg))
        else:
            x = params["embed"]["table"][batch["tokens"]]
        return shard_hint(x, "batch", "seq", None)

    def _logits(self, params, h):
        cfg = self.cfg
        table = (params["embed"]["table"].T if cfg.tie_embeddings
                 else params["lm_head"])
        logits = h @ table.astype(h.dtype)
        return shard_hint(logits, "batch", None, "vocab")

    # ------------------------------------------------------------------ loss

    def loss(self, params, batch):
        """Next-token CE, computed in LOSS_CHUNK-sized sequence chunks so
        the (B,S,V) logits tensor is never materialized."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None]
        mrope = batch.get("mrope_positions") if cfg.mrope else None
        enc_memory = None
        if cfg.encoder_layers:
            enc_memory = self._encoder(params, batch["enc_frames"].astype(
                _dtype(cfg)))
        h, _, aux = self._backbone(params, x, positions=positions,
                                   mrope_positions=mrope,
                                   enc_memory=enc_memory, train=True)
        h = norm_apply(cfg, params["final_norm"], h)
        labels = batch["labels"]

        chunk = min(LOSS_CHUNK, S)
        if S % chunk != 0:
            raise ValueError(f"sequence length {S} must be a multiple of "
                             f"the loss chunk {chunk}")
        nchunk = S // chunk
        # unrolled python loop: never materializes (B,S,V) logits, and
        # keeps the lm-head FLOPs visible to XLA cost analysis (a scan
        # body would be costed once regardless of trip count)
        total = jnp.zeros((), jnp.float32)
        for i in range(nchunk):
            hcc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
            ycc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk,
                                               axis=1)
            logits = self._logits(params, hcc).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, ycc[..., None], axis=-1)[..., 0]
            total = total + jnp.sum(logz - ll)
        ce = total / (B * S)
        lb_coef = cfg.moe.load_balance_coef if cfg.moe is not None else 0.0
        return ce + lb_coef * aux

    # ------------------------------------------------------------- serving

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        group_kinds, n_groups = self._group_structure()
        rolling = (self.rolling_window_decode and cfg.sliding_window
                   and cfg.mamba is None)
        eff_len = (min(cache_len, cfg.sliding_window)
                   if rolling else cache_len)

        def one_group(_):
            return [block_cache_init(cfg, kind, batch_size, eff_len, dt,
                                     rolling=bool(rolling))
                    for kind in group_kinds]

        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[one_group(i) for i in range(n_groups)]) if n_groups > 1 else \
            jax.tree_util.tree_map(lambda x: x[None], one_group(0))

    def prefill(self, params, batch, cache_len: int):
        """Full-sequence forward filling the cache; returns (last_logits,
        caches, next_index). Optional batch keys for left-padded serving:
        ``positions`` (B, S) per-row RoPE positions (pad-shifted so each
        prompt starts at 0) and ``valid`` (B, S) pad mask — pad tokens
        are then invisible to causal attention."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        caches = self.init_cache(B, cache_len)
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None]
        mrope = batch.get("mrope_positions") if cfg.mrope else None
        enc_memory = None
        if cfg.encoder_layers:
            enc_memory = self._encoder(
                params, batch["enc_frames"].astype(_dtype(cfg)))
        h, new_caches, _ = self._backbone(
            params, x, positions=positions, mrope_positions=mrope,
            caches=caches, cache_index=jnp.zeros((), jnp.int32),
            enc_memory=enc_memory, valid=batch.get("valid"))
        h = norm_apply(cfg, params["final_norm"], h)
        logits = self._logits(params, h[:, -1:])
        return logits, new_caches, jnp.asarray(S, jnp.int32)

    def decode_step(self, params, batch, caches, index):
        """One-token step. batch: {"tokens": (B,1)} (or embeds for vlm;
        enc_memory recomputed from enc_frames for whisper). Left-padded
        serving keeps passing ``valid`` (B, P) — the prompt's pad K/Vs
        persist in the cache — and per-row ``positions`` (B, 1)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        positions = batch.get("positions")
        if positions is None:
            positions = index[None, None].astype(jnp.int32)
        mrope = batch.get("mrope_positions") if cfg.mrope else None
        enc_memory = None
        if cfg.encoder_layers:
            if "enc_memory" in batch:
                # serving: encoder output computed once at prefill and
                # carried by the server (avoids per-token recompute)
                enc_memory = batch["enc_memory"].astype(_dtype(cfg))
            else:
                enc_memory = self._encoder(
                    params, batch["enc_frames"].astype(_dtype(cfg)))
        h, new_caches, _ = self._backbone(
            params, x, positions=positions, mrope_positions=mrope,
            caches=caches, cache_index=index, enc_memory=enc_memory,
            valid=batch.get("valid"))
        h = norm_apply(cfg, params["final_norm"], h)
        logits = self._logits(params, h)
        return logits, new_caches, index + 1


def build_model(cfg: ArchConfig, moe_impl: str = "dense", mesh=None,
                remat: bool = True,
                rolling_window_decode: bool = False) -> TransformerLM:
    return TransformerLM(cfg, moe_impl=moe_impl, mesh=mesh, remat=remat,
                         rolling_window_decode=rolling_window_decode)
