"""Mixture-of-Experts FFN: router + two dispatch implementations.

``moe_impl="dense"`` (baseline) — every expert runs on every token, outputs
weighted by the (renormalized) top-k router gates. Mathematically identical
to sparse dispatch but burns num_experts/top_k× the FLOPs: this is the
"no clever routing" floor whose waste the roofline's MODEL_FLOPS/HLO ratio
exposes, and the starting point of the MoE hillclimb.

``moe_impl="ep"`` (optimized) — GraphTheta-style expert parallelism: token→
expert routing is a bipartite message-pass; like the paper's master/mirror
sync we move **only routed tokens** via ``all_to_all`` inside ``shard_map``
over the 'model' axis (DESIGN.md §4). Experts are sharded over that axis
(padded with dead experts when num_experts < axis size); capacity-bounded
buffers keep shapes static. Equivalent to dense dispatch whenever no
expert overflows its capacity (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.layers import _fan_in_init
from repro.utils.compat import shard_map


def moe_init(key, d_model, d_ff, num_experts, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": _fan_in_init(ks[0], (d_model, num_experts), jnp.float32),
        "wi_gate": _fan_in_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "wi_up": _fan_in_init(ks[2], (num_experts, d_model, d_ff), dtype),
        "wo": _fan_in_init(ks[3], (num_experts, d_ff, d_model), dtype),
    }


def router_gates(p, x, moe_cfg):
    """Renormalized top-k gates (B,S,E) + Switch-style load-balance aux."""
    logits = x.astype(jnp.float32) @ p["router"]          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, moe_cfg.top_k)
    onehot = jax.nn.one_hot(topi, probs.shape[-1], dtype=probs.dtype)
    mask = jnp.sum(onehot, axis=-2)                        # (B,S,E) 0/1
    gated = probs * mask
    gated = gated / jnp.maximum(gated.sum(-1, keepdims=True), 1e-9)
    frac = jnp.mean(mask, axis=(0, 1))                     # routed fraction
    prob = jnp.mean(probs, axis=(0, 1))
    aux = probs.shape[-1] * jnp.sum(frac * prob)
    return gated, aux


def moe_ffn_dense(p, x, moe_cfg):
    """Baseline: all experts on all tokens, gate-weighted combine."""
    gates, aux = router_gates(p, x, moe_cfg)               # (B,S,E)
    h_g = jnp.einsum("bsd,edf->ebsf", x, p["wi_gate"])
    h_u = jnp.einsum("bsd,edf->ebsf", x, p["wi_up"])
    h = jax.nn.silu(h_g) * h_u
    y = jnp.einsum("ebsf,efd->ebsd", h, p["wo"])
    out = jnp.einsum("ebsd,bse->bsd", y, gates.astype(y.dtype))
    return out.astype(x.dtype), aux


def moe_ffn_ep(p, x, moe_cfg, mesh, axis: str = "model", dp_axis=None):
    """Expert-parallel dispatch via shard_map over ``axis``.

    x: (B, S, D) — B sharded over ``dp_axis`` (if given), S over ``axis``.
    Routed tokens move twice over the expert axis (dispatch + return), the
    only communication — the master/mirror rule applied to the bipartite
    token→expert graph.
    """
    from jax.sharding import PartitionSpec as P

    n_dev = mesh.shape[axis]
    E = moe_cfg.num_experts
    E_pad = max(E, n_dev)
    if E_pad % n_dev != 0:
        raise ValueError(f"expert count {E} must pad to a multiple of "
                         f"the device count {n_dev}")
    per_dev = E_pad // n_dev

    gates, aux = router_gates(p, x, moe_cfg)               # global (B,S,E)

    def local(x_l, gates_l, wi_g, wi_u, wo):
        b, s, d = x_l.shape
        T = b * s
        xt = x_l.reshape(T, d)
        g = gates_l.reshape(T, E)
        cap = max(1, int(np.ceil(T * moe_cfg.top_k / E
                                 * moe_cfg.capacity_factor)))
        sel = g > 0                                        # (T, E)
        pos = jnp.cumsum(sel.astype(jnp.int32), axis=0) - 1
        keep = sel & (pos < cap)
        flat_keep = keep.reshape(-1)
        tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, E)).reshape(-1)
        be = jnp.where(flat_keep,
                       jnp.broadcast_to(jnp.arange(E)[None, :],
                                        (T, E)).reshape(-1), E_pad - 1)
        bp = jnp.where(flat_keep, pos.reshape(-1), cap - 1)
        contrib = jnp.where(flat_keep[:, None], xt[tok_idx], 0)
        buf = jnp.zeros((E_pad, cap, d), x_l.dtype)
        buf = buf.at[be, bp].add(contrib, mode="drop")

        # ---- dispatch: send expert-slices to their owners -------------------
        buf = buf.reshape(n_dev, per_dev, cap, d)
        buf = jax.lax.all_to_all(buf, axis, 0, 0)          # rows by sender
        buf = jnp.moveaxis(buf, 0, 1)                      # (per_dev, n_dev, cap, d)
        buf = buf.reshape(per_dev, n_dev * cap, d)

        # ---- local experts ---------------------------------------------------
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wi_g)) \
            * jnp.einsum("ecd,edf->ecf", buf, wi_u)
        y = jnp.einsum("ecf,efd->ecd", h, wo)

        # ---- return: back to the senders ------------------------------------
        y = y.reshape(per_dev, n_dev, cap, d)
        y = jnp.moveaxis(y, 1, 0)                          # (n_dev, per_dev, cap, d)
        y = jax.lax.all_to_all(y, axis, 0, 0)
        y = y.reshape(E_pad, cap, d)

        picked = jnp.where(flat_keep[:, None], y[be, bp], 0)
        w = (g.reshape(-1) * flat_keep).astype(picked.dtype)
        out = jnp.zeros((T, d), picked.dtype)
        out = out.at[tok_idx].add(picked * w[:, None], mode="drop")
        return out.reshape(b, s, d)

    wi_g, wi_u, wo = p["wi_gate"], p["wi_up"], p["wo"]
    if E_pad != E:
        padn = E_pad - E
        zp = lambda a: jnp.concatenate(
            [a, jnp.zeros((padn,) + a.shape[1:], a.dtype)], axis=0)
        wi_g, wi_u, wo = zp(wi_g), zp(wi_u), zp(wo)

    x_spec = P(dp_axis, axis, None)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, x_spec, P(axis), P(axis), P(axis)),
        out_specs=x_spec,
    )(x, gates.astype(x.dtype), wi_g, wi_u, wo)
    return out.astype(x.dtype), aux
