"""Fault-tolerant training runtime: supervision, retry, and recovery.

The layer between the trainers and everything that can fail — view
construction, device staging, step execution, checkpoint I/O. See
:mod:`repro.runtime.faults` (policy / injection / retry),
:mod:`repro.runtime.prefetch` (supervised in-process prefetch),
:mod:`repro.runtime.procpool` (supervised sampler *processes* over
shared-memory view slots), and ``python -m repro.runtime.chaos`` (the
chaos harness CI runs).
"""
from repro.runtime.faults import (DivergenceError, FaultInjector,
                                  FaultPolicy, FaultRetriesExceeded,
                                  InjectedFault, PrefetchShutdownError,
                                  Retrier, SlotCorruptionError,
                                  StepTimeoutError, TrainingInterrupted,
                                  TransientError, WorkerKilled,
                                  sync_with_timeout)
from repro.runtime.prefetch import StreamPrefetcher, ViewPrefetcher
from repro.runtime.procpool import (ProcessViewService,
                                    ProcPoolUnavailable,
                                    shared_memory_available)

__all__ = [
    "DivergenceError", "FaultInjector", "FaultPolicy",
    "FaultRetriesExceeded", "InjectedFault", "PrefetchShutdownError",
    "ProcessViewService", "ProcPoolUnavailable", "Retrier",
    "SlotCorruptionError", "StepTimeoutError", "StreamPrefetcher",
    "TrainingInterrupted", "TransientError", "ViewPrefetcher",
    "WorkerKilled", "shared_memory_available", "sync_with_timeout",
]
