"""Fault-tolerant training runtime: supervision, retry, and recovery.

The layer between the trainers and everything that can fail — view
construction, device staging, step execution, checkpoint I/O. See
:mod:`repro.runtime.faults` (policy / injection / retry),
:mod:`repro.runtime.prefetch` (supervised prefetch pipelines), and
``python -m repro.runtime.chaos`` (the chaos harness CI runs).
"""
from repro.runtime.faults import (DivergenceError, FaultInjector,
                                  FaultPolicy, FaultRetriesExceeded,
                                  InjectedFault, PrefetchShutdownError,
                                  Retrier, StepTimeoutError,
                                  TransientError, WorkerKilled,
                                  sync_with_timeout)
from repro.runtime.prefetch import StreamPrefetcher, ViewPrefetcher

__all__ = [
    "DivergenceError", "FaultInjector", "FaultPolicy",
    "FaultRetriesExceeded", "InjectedFault", "PrefetchShutdownError",
    "Retrier", "StepTimeoutError", "StreamPrefetcher", "TransientError",
    "ViewPrefetcher", "WorkerKilled", "sync_with_timeout",
]
