"""Supervised host-side prefetch pipelines.

PR 5's prefetch pool made view construction parallel and deterministic;
this module makes it *survivable*. The design premise (and the reason
recovery is cheap): view *i* of a :class:`~repro.core.views.ViewStream`
is a pure function of ``(seed, i)``, so any failed or hung build can be
retried — on the same worker, or on a different one — and the recovered
stream is **bit-identical** to a fault-free run. Supervision therefore
never costs reproducibility, which is the trajectory-invariance
contract ``tests/test_faults.py`` asserts.

Two pipelines, mirroring :mod:`repro.core.trainer`'s (which now imports
them from here):

- :class:`ViewPrefetcher` — the double-buffered daemon pipeline for
  plain iterators. Hardened ``close()``: the producer is drained and
  unblocked deterministically (cancel flag checked on every bounded
  put), and a thread that refuses to die raises
  :class:`~repro.runtime.faults.PrefetchShutdownError` instead of being
  silently leaked.
- :class:`StreamPrefetcher` — the worker pool over an indexable
  ViewStream, now supervised: per-index builds are retryable units (a
  :class:`~repro.runtime.faults.Retrier` wraps build+prepare), a worker
  killed mid-build (:class:`~repro.runtime.faults.WorkerKilled` — the
  OOM-kill stand-in) has its claimed index **requeued** and a
  replacement worker respawned (capped by
  ``policy.max_worker_respawns``), and a build that exceeds the
  policy's ``view_build`` timeout is reassigned to another worker (the
  stale claim's eventual result is discarded by generation check).
  Emit order is by index throughout, so none of this is observable in
  the staged sequence.

With ``runtime=None`` both classes are the zero-overhead production
pipelines (no retry wrapper, no watchdog) plus the hardened close.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Iterable, Iterator, Optional

from repro.runtime.faults import (PrefetchShutdownError, Retrier,
                                  WorkerKilled)


class ViewPrefetcher:
    """Double-buffered host pipeline over a plain view iterator.

    A daemon thread pulls views, runs ``prepare`` (shard + stage) and
    parks up to ``depth`` staged views in a bounded queue, so staging
    for step *i+1* overlaps device compute for step *i*. Exceptions in
    the thread re-raise in the consumer; exhaustion is signalled with a
    sentinel. With a ``runtime`` retrier, ``prepare`` becomes a
    retryable ``view_build`` stage (the pulled view is in hand, so a
    transient staging failure re-prepares the same view).
    """

    _END = object()

    def __init__(self, views: Iterable, prepare, depth: int = 2,
                 runtime: Optional[Retrier] = None):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._cancel = threading.Event()
        if runtime is not None:
            raw = prepare
            prepare = lambda v: runtime("view_build", lambda: raw(v))
        self._thread = threading.Thread(
            target=self._run, args=(views, prepare), daemon=True,
            name="view-prefetch")
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded put that gives up when the consumer cancelled (so an
        abandoned fit can't leave the thread pinning staged buffers)."""
        while not self._cancel.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, views, prepare):
        try:
            for v in views:
                if self._cancel.is_set() or not self._put(prepare(v)):
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced in __next__
            self._err = e
        finally:
            self._put(self._END)

    def close(self, timeout: float = 5.0):
        """Unblock and retire the producer; staged-but-unconsumed views
        are dropped. The queue is drained *while* joining (a producer
        mid-``put`` wakes on the drain or the cancel flag, whichever is
        first), and a thread still alive past ``timeout`` raises — a
        silently leaked daemon pins staged device buffers and hides a
        hung view source."""
        self._cancel.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive():
            try:
                self._q.get_nowait()
            except queue.Empty:
                # drain is opportunistic; the join below is the real wait
                pass  # lint: waive=src.silent-except
            self._thread.join(timeout=0.05)
            if time.monotonic() >= deadline:
                break
        if self._thread.is_alive():
            raise PrefetchShutdownError(
                f"prefetch thread {self._thread.name!r} still alive "
                f"{timeout}s after close() — the view iterator or "
                "prepare() is blocked in non-cancellable code")

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._END:
            self._thread.join()
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class StreamPrefetcher:
    """Supervised worker pool over an indexable ViewStream.

    ``workers`` threads each own a private ViewBuilder and claim view
    indices — requeued (recovered) indices first, then a shared counter;
    finished (built + sharded + staged) views land in a reorder buffer
    and are emitted strictly in index order. Since ``stream.build(i)``
    derives its RNG from ``(seed, i)``, the emitted sequence is
    bit-identical to sequential construction no matter how the OS
    schedules the workers — or how many of them fault.

    Run-ahead is bounded: no worker starts index i until
    ``i - emitted < depth + workers - 1``, so at most ~depth staged views
    wait in the buffer while every worker stays busy. The stream's cursor
    advances only as views are *emitted* (not as they are built), which is
    what makes the cursor checkpointable mid-pipeline.

    Supervision (only with a ``runtime`` retrier):

    - build+prepare runs under the retrier's ``view_build`` stage —
      transient failures back off and retry the same index;
    - :class:`WorkerKilled` escaping a build requeues the claimed index
      and respawns a replacement thread (up to
      ``policy.max_worker_respawns`` deaths, then the pool aborts);
    - a claim older than the policy's ``view_build`` timeout is
      reassigned by the consumer; the stale build's result is discarded
      via a per-claim generation id (rebuilds are bit-identical, so a
      double build is waste, never corruption).
    """

    def __init__(self, stream, prepare, steps: Optional[int],
                 workers: int = 1, depth: int = 2,
                 runtime: Optional[Retrier] = None):
        self._stream = stream
        self._start = stream.cursor
        left = (None if stream.length is None
                else max(0, stream.length - self._start))
        if steps is None:
            self._limit = left
        else:
            self._limit = steps if left is None else min(steps, left)
        self._prepare = prepare
        self._runtime = runtime
        self._cond = threading.Condition()
        self._results: dict = {}
        self._next_build = 0
        self._emitted = 0
        self._requeue: list = []        # recovered indices, claimed first
        self._claims: dict = {}         # index -> (claim_id, t_claimed)
        self._claim_ids = itertools.count()
        self._err: Optional[BaseException] = None
        self._cancel = False
        self._cancel_evt = threading.Event()   # cancellable injected hangs
        # keyed injections are pure functions of the index, so a requeued
        # index would fault again forever; each index gets at most one
        # shot per injection point (marked at first claim, under the lock)
        self._hang_armed: set = set()
        self._kill_armed: set = set()
        self._corrupt_armed: set = set()
        self._respawns = 0
        self._worker_seq = itertools.count()
        # materialize the graph's lazy CSC index before the fan-out so
        # worker-thread builders never race the unlocked cache
        stream.g.csc()
        workers = max(1, workers)
        self._workers = workers
        self._max_ahead = max(1, depth) + workers - 1
        self._threads: list = []
        with self._cond:
            for _ in range(workers):
                self._spawn()

    # -- worker lifecycle --------------------------------------------------

    def _spawn(self):
        """Start one worker thread (caller holds the cond lock or is
        __init__)."""
        t = threading.Thread(target=self._work, daemon=True,
                             name=f"view-stream-{next(self._worker_seq)}")
        self._threads.append(t)
        t.start()

    def _claimable(self) -> bool:
        if self._requeue:
            return True
        if self._limit is not None and self._next_build >= self._limit:
            return False
        return (self._next_build - self._emitted) < self._max_ahead

    def _done_producing(self) -> bool:
        """No index left to claim, now or after any future requeue."""
        return (not self._requeue and not self._claims
                and self._limit is not None
                and self._next_build >= self._limit)

    def _claim(self) -> Optional[tuple]:
        """Blocking claim of the next index; None = pool shutting down.
        Caller must NOT hold the cond lock."""
        with self._cond:
            while (not self._cancel and self._err is None
                   and not self._claimable() and not self._done_producing()):
                self._cond.wait()
            if (self._cancel or self._err is not None
                    or self._done_producing()):
                return None
            if self._requeue:
                i = self._requeue.pop(0)
            else:
                i = self._next_build
                self._next_build += 1
            cid = next(self._claim_ids)
            self._claims[i] = (cid, time.monotonic())
            return i, cid

    def _build_one(self, i: int, builder):
        def build():
            item = self._prepare(
                self._stream.build(self._start + i, builder))
            return item

        rt = self._runtime
        if rt is None:
            return build()
        inj = rt.injector
        if inj is not None:
            with self._cond:
                do_hang = i not in self._hang_armed
                self._hang_armed.add(i)
            if do_hang:
                # an injected stall: cancellable (wakes on close()), and
                # the consumer-side watchdog reassigns i meanwhile.
                # proc_hang is the process-level point's thread analog,
                # so one chaos plan covers both prefetch modes
                inj.maybe_hang("view_hang", i, inj.hang_seconds,
                               self._cancel_evt.wait)
                inj.maybe_hang("proc_hang", i, inj.hang_seconds,
                               self._cancel_evt.wait)
            with self._cond:
                do_kill = i not in self._kill_armed
                self._kill_armed.add(i)
            if do_kill:
                inj.maybe_fail("worker_kill", key=i)
                # SIGKILL's thread analog: maybe_fail maps proc_kill to
                # WorkerKilled (requeue + respawn, same supervision)
                inj.maybe_fail("proc_kill", key=i)
            with self._cond:
                do_corrupt = i not in self._corrupt_armed
                self._corrupt_armed.add(i)
            if do_corrupt and inj.fires("slot_corrupt", key=i):
                # a corrupted handoff's thread analog: the first build
                # is discarded (as a corrupt slot would be) and the
                # pure view rebuilt bit-exactly below
                self._stream.build(self._start + i, builder)
        return rt("view_build", build, key=i, label=f"view[{i}]")

    def _work(self):
        try:
            builder = self._stream.make_builder()
            while True:
                claim = self._claim()
                if claim is None:
                    return
                i, cid = claim
                try:
                    item = self._build_one(i, builder)
                except WorkerKilled:
                    with self._cond:
                        if self._claims.get(i, (None,))[0] == cid:
                            del self._claims[i]
                            self._requeue.append(i)
                        self._respawns += 1
                        policy = (self._runtime.policy if self._runtime
                                  else None)
                        cap = (policy.max_worker_respawns if policy
                               else 0)
                        if self._respawns > cap:
                            self._err = RuntimeError(
                                f"prefetch pool: {self._respawns} worker "
                                f"deaths exceed max_worker_respawns={cap}")
                        else:
                            self._spawn()
                        self._cond.notify_all()
                    return
                with self._cond:
                    if self._claims.get(i, (None,))[0] == cid:
                        # still ours — a watchdog reassignment would have
                        # dropped the claim (discard the stale build)
                        del self._claims[i]
                        self._results[i] = item
                    self._cond.notify_all()
        except BaseException as e:  # noqa: BLE001 — surfaced in __next__
            with self._cond:
                if self._err is None:
                    self._err = e
                self._cond.notify_all()

    # -- consumer side -----------------------------------------------------

    def close(self, timeout: float = 5.0):
        with self._cond:
            self._cancel = True
            self._results.clear()
            self._cond.notify_all()
        self._cancel_evt.set()
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
        stuck = [t.name for t in self._threads if t.is_alive()]
        if stuck:
            raise PrefetchShutdownError(
                f"prefetch workers {stuck} still alive {timeout}s after "
                "close() — a build is blocked in non-cancellable code")

    def _stall_timeout(self) -> Optional[float]:
        if self._runtime is None:
            return None
        return self._runtime.policy.timeout("view_build")

    def _reassign_stale(self, now: float, stall: float) -> None:
        """Requeue any claim older than the view_build timeout (caller
        holds the cond lock). The claim entry is dropped, so the hung
        build's eventual result fails its generation check."""
        stale = [i for i, (_, t0) in self._claims.items()
                 if now - t0 > stall]
        for i in stale:
            del self._claims[i]
            self._requeue.append(i)
        if stale:
            self._cond.notify_all()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        stall = self._stall_timeout()
        with self._cond:
            if self._limit is not None and self._emitted >= self._limit:
                raise StopIteration
            while self._emitted not in self._results and self._err is None:
                if stall is None:
                    self._cond.wait()
                else:
                    self._cond.wait(timeout=min(0.05, stall / 4))
                    self._reassign_stale(time.monotonic(), stall)
            if self._emitted not in self._results:
                err = self._err
                raise err
            item = self._results.pop(self._emitted)
            self._emitted += 1
            self._cond.notify_all()
        # cursor = views handed to the consumer, exact for checkpointing
        self._stream.seek(self._start + self._emitted)
        return item
