"""Chaos harness: prove the fault-tolerant runtime's contracts by
running real training twice — fault-free vs. under deterministic
injected faults — and requiring the loss trajectories **bit-identical**.

CI runs two lanes:

- ``python -m repro.runtime.chaos --smoke`` (fast lane): one combined
  scenario per trainer — a killed prefetch worker, failed view builds,
  a failed device staging and a failed checkpoint save, all in one fit —
  plus one process-mode scenario (a sampler process SIGKILLed mid-build
  under ``prefetch_mode="process"``).
- ``python -m repro.runtime.chaos`` (nightly): the full sweep over
  injection point x policy combinations, the process-fault sweep
  ({proc_kill, proc_hang, slot_corrupt} x {thread, process} x
  {engine, compact} — process-mode scenarios also certify thread/
  process trajectory parity, since the baseline is always thread mode),
  plus the divergence-recovery scenarios (skip_view / rollback) which
  change the trajectory by design and are checked for their recovery
  semantics instead.

Exit code 0 iff every scenario holds. Each scenario also re-certifies
the compiled-once / compiled-per-bucket contract — recovery must never
retrace.
"""
from __future__ import annotations

import argparse
import sys
import tempfile

import numpy as np

from repro.runtime.faults import FaultInjector, FaultPolicy


# quiet, fast policy for chaos runs: no real sleeping between retries
FAST = dict(backoff_base=0.0, backoff_cap=0.0, jitter=0.0)


def _graph(n=160, seed=0):
    from repro.graph import sbm_graph
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8,
                     p_in=0.05, p_out=0.005, seed=seed).add_self_loops()


def _engine_trainer(g, fault_policy=None, injector=None, seed=0):
    from repro.config import GNNConfig
    from repro.core.engine import HybridParallelEngine
    from repro.core.partition import build_partitions
    from repro.core.trainer import Trainer
    from repro.models import make_gnn
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)
    engine = HybridParallelEngine(make_gnn(cfg), build_partitions(g, 1))
    return Trainer(engine, _adam(), seed=seed, fault_policy=fault_policy,
                   injector=injector)


def _compact_trainer(g, fault_policy=None, injector=None, seed=0,
                     backend="reference"):
    from repro.config import GNNConfig
    from repro.core.trainer import CompactTrainer
    from repro.models import make_gnn
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8,
                    aggregate_backend=backend)
    return CompactTrainer(make_gnn(cfg), g, _adam(), seed=seed,
                          fault_policy=fault_policy, injector=injector)


def _adam():
    from repro.optim import adam
    return adam(1e-2)


def _views(g, seed=0, compact=False):
    from repro.core.strategies import strategy_views
    return strategy_views(g, "mini", K=2, seed=seed, batch_nodes=24,
                          compact=compact)


def _fit(trainer, g, steps, compact=False, workers=2, mode="thread",
         **kw):
    out = trainer.fit(_views(g, compact=compact), steps=steps,
                      prefetch_workers=workers, prefetch_mode=mode, **kw)
    return out


def run_scenario(name: str, plan: dict, trainer_kind: str = "engine",
                 policy_kw: dict = None, steps: int = 8,
                 backend: str = "reference", mode: str = "thread",
                 hang_seconds: float = 0.5, verbose=print) -> bool:
    """One chaos scenario: baseline vs injected run, bit-identical
    trajectory required (plus: the faults actually fired, and the
    compile contracts held). Returns pass/fail.

    The baseline always runs fault-free in thread mode, so a
    ``mode="process"`` scenario certifies both recovery invariance AND
    thread/process mode parity in one comparison.
    """
    g = _graph()
    compact = trainer_kind == "compact"
    make = _compact_trainer if compact else _engine_trainer
    mk_kw = {"backend": backend} if compact else {}

    base = make(g, **mk_kw)
    ref = _fit(base, g, steps, compact=compact)["losses"]

    policy = FaultPolicy(**{**FAST, **(policy_kw or {})})
    inj = FaultInjector(plan, seed=0, hang_seconds=hang_seconds)
    tr = make(g, fault_policy=policy, injector=inj, **mk_kw)
    with tempfile.TemporaryDirectory() as d:
        out = _fit(tr, g, steps, compact=compact, mode=mode,
                   checkpoint_dir=d, checkpoint_every=3)
    got = out["losses"]

    ok = True
    if inj.total_fired() == 0:
        verbose(f"  [{name}] FAIL: no fault fired (plan {plan})")
        ok = False
    if list(map(float, got)) != list(map(float, ref)):
        verbose(f"  [{name}] FAIL: trajectory diverged\n"
                f"    ref {ref}\n    got {got}")
        ok = False
    try:
        if compact:
            tr.assert_compiled_per_bucket()
        else:
            tr.assert_compiled_once()
    except AssertionError as e:
        verbose(f"  [{name}] FAIL: compile contract broken: {e}")
        ok = False
    if ok:
        verbose(f"  [{name}] ok ({inj.total_fired()} faults injected, "
                f"{len(got)} steps bit-identical)")
    return ok


def run_divergence(name: str, action: str, trainer_kind: str = "engine",
                   steps: int = 8, verbose=print) -> bool:
    """Divergence-recovery scenario: inject a simulated non-finite loss
    and check the policy's action recovered the run (these change the
    trajectory by design, so the check is semantic, not bitwise)."""
    g = _graph()
    compact = trainer_kind == "compact"
    make = _compact_trainer if compact else _engine_trainer
    policy = FaultPolicy(on_divergence=action, **FAST)
    inj = FaultInjector({"diverge": {4}}, seed=0)
    tr = make(g, fault_policy=policy, injector=inj)
    with tempfile.TemporaryDirectory() as d:
        out = _fit(tr, g, steps, compact=compact, checkpoint_dir=d,
                   checkpoint_every=2)
    ok = True
    diverges = [e for e in out["events"] if e.get("stage") == "diverge"]
    if len(diverges) != 1:
        verbose(f"  [{name}] FAIL: expected 1 divergence event, got "
                f"{len(diverges)}")
        ok = False
    if not all(np.isfinite(out["losses"])):
        verbose(f"  [{name}] FAIL: non-finite loss leaked into history")
        ok = False
    # the poison step's update was discarded / rolled back, yet the fit
    # ran to completion over the remaining views
    if out["steps"] < steps - 1:
        verbose(f"  [{name}] FAIL: fit stopped at step {out['steps']}")
        ok = False
    try:
        if compact:
            tr.assert_compiled_per_bucket()
        else:
            tr.assert_compiled_once()
    except AssertionError as e:
        verbose(f"  [{name}] FAIL: compile contract broken: {e}")
        ok = False
    if ok:
        verbose(f"  [{name}] ok (1 divergence, action={action}, "
                f"{out['steps']} steps completed)")
    return ok


SMOKE_PLAN = {
    "worker_kill": {1},          # kill the worker building view 1
    "view_build": {2},           # fail view 2's build (retried)
    "device_put": {0},           # fail one staging batch (retried)
    "checkpoint_save": {0},      # fail the first save attempt (retried)
}

# nightly: every injection point alone, then paired with tighter policies
SWEEP_POINTS = ("view_build", "device_put", "step", "checkpoint_save",
                "worker_kill")
SWEEP_POLICIES = {
    "default": {},
    "retries1": {"max_retries": 1},
    "finite": {"check_finite": True},
}

# process-level faults: each point has a thread-mode analog in
# StreamPrefetcher, so every plan runs under BOTH prefetch modes. The
# process-mode proc_hang needs a child stall longer than the watchdog
# (the sleeping child sends no heartbeats; the parent's claim-age
# watchdog must kill + respawn it, not wait it out), so those scenarios
# tighten worker_heartbeat_s and stretch hang_seconds.
PROC_SWEEP_POINTS = ("proc_kill", "proc_hang", "slot_corrupt")


def _proc_scenario_kw(point: str, mode: str) -> dict:
    kw = {"mode": mode}
    if mode == "process" and point == "proc_hang":
        kw["hang_seconds"] = 30.0
        kw["policy_kw"] = {"worker_heartbeat_s": 0.75}
    return kw


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="chaos harness for the fault-tolerant runtime")
    ap.add_argument("--smoke", action="store_true",
                    help="fast-lane subset: one combined scenario per "
                         "trainer + one rollback e2e")
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args(argv)

    results = []
    print("chaos: baseline-vs-injected trajectory invariance")
    if args.smoke:
        results.append(run_scenario(
            "smoke/engine", SMOKE_PLAN, "engine", steps=args.steps))
        results.append(run_scenario(
            "smoke/compact", SMOKE_PLAN, "compact", steps=args.steps))
        results.append(run_scenario(
            "smoke/procpool", {"proc_kill": {1}}, "engine",
            mode="process", steps=args.steps))
        results.append(run_divergence(
            "smoke/rollback", "rollback", "engine", steps=args.steps))
    else:
        for point in SWEEP_POINTS:
            for pname, pkw in SWEEP_POLICIES.items():
                occ = {1} if point == "worker_kill" else {0, 2}
                results.append(run_scenario(
                    f"{point}/{pname}", {point: occ}, "engine",
                    policy_kw=pkw, steps=args.steps))
        # process-fault sweep: every process point under both prefetch
        # modes and both trainers — recovery must be invisible AND the
        # two modes must emit bit-identical trajectories
        for point in PROC_SWEEP_POINTS:
            for mode in ("thread", "process"):
                for kind in ("engine", "compact"):
                    results.append(run_scenario(
                        f"{point}/{mode}/{kind}", {point: {1}}, kind,
                        steps=args.steps,
                        **_proc_scenario_kw(point, mode)))
        results.append(run_scenario(
            "combined/engine", SMOKE_PLAN, "engine", steps=args.steps))
        for backend in ("reference", "csc"):
            results.append(run_scenario(
                f"combined/compact-{backend}", SMOKE_PLAN, "compact",
                steps=args.steps, backend=backend))
        for action in ("skip_view", "rollback"):
            for kind in ("engine", "compact"):
                results.append(run_divergence(
                    f"diverge/{action}/{kind}", action, kind,
                    steps=args.steps))
    passed = sum(results)
    print(f"chaos: {passed}/{len(results)} scenarios passed")
    return 0 if passed == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
