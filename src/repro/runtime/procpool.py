"""Supervised multi-process sampler service over shared-memory view slots.

ROADMAP item 1(a): move view construction out of the trainer process —
the paper's regime (1,024 small-memory workers, §5) makes sampler
failure the steady state, and the GIL makes in-process builder threads
a scaling ceiling. :class:`ProcessViewService` is a drop-in replacement
for :class:`~repro.runtime.prefetch.StreamPrefetcher` (same constructor
shape, same iterator contract) that spawns N sampler **processes**
(``spawn`` context — each re-opens the graph read-only from its own
pickled copy, caches pruned) and moves finished views back through
shared-memory ring slots::

    trainer process                      sampler process (x N)
    ---------------                      ---------------------
    _schedule() --- task queue (i, slot) ---> build view i
    _poll_done() <- per-worker done queue --  write slot: seqlock odd
                    (ready/ok/err)            -> payload -> len/crc32/i
    verify seq even + crc  <== shm ring ====  -> seqlock even
    unpickle -> prepare() -> emit in order    heartbeat[wid] = monotonic

Integrity is layered: the per-slot **seqlock** (odd = writer inside,
even = stable; re-checked after the payload copy) means a half-written
slot is never *consumed*, and the **crc32** over the payload means a
torn or corrupted write is *detected* — both downgrade to a requeue,
because view ``i`` is a pure function of ``(seed, i)`` and a rebuild is
bit-exact. The same purity makes every recovery invisible in the
emitted sequence: kill -9 mid-build, a hung worker, a corrupted slot —
the trainer sees the identical view stream, in index order.

Supervision (the heart of it):

- **heartbeats** — each worker stamps a shared ``float64`` slot while
  polling and around every build; the parent's claim-age watchdog
  declares a worker hung when its claim AND its heartbeat are both
  older than ``FaultPolicy.worker_heartbeat_s``, then terminate→kill→
  respawns it and requeues the claim (``worker_heartbeat_s`` must
  exceed an honest build time — a false positive costs a rebuild,
  never correctness);
- **capped respawn** — dead or hung processes are respawned up to
  ``FaultPolicy.max_proc_respawns``, then the pool aborts with a typed
  :class:`~repro.runtime.faults.FaultRetriesExceeded`;
- **graceful close()** — stop scheduling, send exit sentinels, join
  with a deadline, escalate terminate→kill for stragglers, unlink the
  shared segments; zero child processes survive a clean close.

Fault injection: the child rebuilds its own deterministic
:class:`~repro.runtime.faults.FaultInjector` from the parent's plan and
applies the process-level points keyed by view index — ``proc_kill``
(os.kill SIGKILL), ``proc_hang`` (sleep without heartbeats),
``slot_corrupt`` (flip payload bytes after the crc was computed).
Because ``fires(point, key=i)`` is a pure function, the parent *replays
the same decision* when it detects the failure, so the parent-side
injector's ``fired`` record (what chaos scenarios assert on) matches
the child's without any cross-process channel.

When shared memory is unavailable the trainers degrade to the
in-process :class:`~repro.runtime.prefetch.StreamPrefetcher` with a
one-time warning (see :func:`warn_unavailable_once`).
"""
from __future__ import annotations

import copy
import os
import pickle
import queue as _queue
import signal
import struct
import threading
import time
import traceback
import warnings
import zlib
from typing import Iterator, Optional

from repro.runtime.faults import (FaultInjector, FaultPolicy,
                                  FaultRetriesExceeded,
                                  PrefetchShutdownError, Retrier,
                                  SlotCorruptionError)

try:
    import multiprocessing
    from multiprocessing import connection as _mpconn
    from multiprocessing import shared_memory as _shm
except ImportError:                      # pragma: no cover - stdlib
    multiprocessing = None
    _mpconn = None
    _shm = None

import numpy as np


class ProcPoolUnavailable(RuntimeError):
    """Shared memory / process spawning is unusable here — callers
    degrade to the in-process thread pool."""


# injection points the child process owns (everything else — staging,
# step, checkpoint — fires parent-side as usual)
PROC_POINTS = ("proc_kill", "proc_hang", "slot_corrupt")

# slot layout: | seq u64 | length u64 | crc32 u32 | view index i64 | pad |
# payload starts at byte 32. seq is the seqlock generation: odd while a
# writer is inside, even when stable.
_SEQ = struct.Struct("<Q")
_META = struct.Struct("<QIq")
_PAYLOAD_OFF = 32

_DEGRADE_WARNED = False


def warn_unavailable_once(reason: str) -> None:
    """One-time RuntimeWarning when ``prefetch_mode='process'`` degrades
    to the in-process StreamPrefetcher."""
    global _DEGRADE_WARNED
    if not _DEGRADE_WARNED:
        warnings.warn(
            f"prefetch_mode='process' unavailable ({reason}); degrading "
            "to in-process thread prefetch (StreamPrefetcher)",
            RuntimeWarning, stacklevel=3)
        _DEGRADE_WARNED = True


def shared_memory_available() -> bool:
    """Probe: can we create (and unlink) a shared-memory segment?"""
    if _shm is None or multiprocessing is None:
        return False
    try:
        seg = _shm.SharedMemory(create=True, size=8)
    except Exception:  # noqa: BLE001 — the probe IS the error handling
        return False
    try:
        seg.close()
        seg.unlink()
    except OSError:
        # already gone / platform quirk: the probe still succeeded
        pass  # lint: waive=src.silent-except
    return True


# ---------------------------------------------------------------------------
# view (de)serialization: everything but the graph crosses the boundary
# ---------------------------------------------------------------------------


def _strip_view(view) -> tuple:
    """A picklable graph-free snapshot of a view (the graph is shared
    state both sides already hold)."""
    from repro.core.views import CompactView, GraphView
    if isinstance(view, CompactView):
        return ("compact", view.K, view.strategy, view.nodes,
                view.hop_offsets, view.src_local, view.dst_local,
                view.edge_ids, view.loss_local, dict(view.meta))
    if isinstance(view, GraphView):
        return ("dense", view.K, view.strategy, view.node_active,
                view.edge_active, view.loss_mask, dict(view.meta))
    raise TypeError(f"cannot serialize view of type {type(view).__name__}")


def _restore_view(g, state: tuple):
    from repro.core.views import CompactView, GraphView
    kind = state[0]
    if kind == "compact":
        return CompactView(g, state[1], state[2], state[3], state[4],
                           state[5], state[6], state[7], state[8],
                           state[9])
    return GraphView(g, state[1], state[2], state[3], state[4], state[5],
                     state[6])


def _sampler_stream(stream):
    """A copy of ``stream`` fit to ship to a spawn worker: builder
    detached, the graph's lazy caches (CSR/CSC/plans/base blocks) pruned
    so each sampler re-derives them read-only instead of shipping
    megabytes of parent state."""
    s = copy.copy(stream)
    s._builder = None
    g = copy.copy(stream.g)
    g._csr = g._csc = g._gcn_norm = None
    g._csc_plans = {}
    g._base_blocks = {}
    s.g = g
    cache = getattr(s, "cache", None)    # ClusterViewStream
    if cache is not None and getattr(cache, "g", None) is stream.g:
        cache = copy.copy(cache)
        cache.g = g
        s.cache = cache
    return s


def _slot_bytes_for(stream) -> int:
    """A capacity bound covering any view the stream can emit (dense
    mask views and compact relabeled views alike), plus headroom for
    pickle framing."""
    g, K = stream.g, stream.K
    n, e = int(g.num_nodes), int(g.num_edges)
    dense = 4 * K * (n + e) + 4 * n
    compact = 16 * n + 24 * e + 8 * (K + 2)
    return max(dense, compact) + 65536


# ---------------------------------------------------------------------------
# the sampler process
# ---------------------------------------------------------------------------


def _write_slot(buf, base: int, payload: bytes, index: int) -> None:
    """Seqlocked slot write: odd seq while inside, even when stable."""
    seq0 = _SEQ.unpack_from(buf, base)[0]
    if seq0 % 2:
        seq0 += 1     # previous writer died mid-write; realign to even
    _SEQ.pack_into(buf, base, seq0 + 1)
    buf[base + _PAYLOAD_OFF:base + _PAYLOAD_OFF + len(payload)] = payload
    _META.pack_into(buf, base + 8, len(payload), zlib.crc32(payload),
                    index)
    _SEQ.pack_into(buf, base, seq0 + 2)


def _mute_child_shm_tracking() -> None:
    """Stop this (sampler) process registering shm attachments with the
    shared resource tracker: the parent owns both segments' lifetimes
    (close+unlink in ``close()``), and N children registering then
    unregistering the same names races the tracker's bookkeeping."""
    try:
        from multiprocessing import resource_tracker

        def _noop_register(name, rtype):
            if rtype != "shared_memory":
                resource_tracker._real_register(name, rtype)

        if not hasattr(resource_tracker, "_real_register"):
            resource_tracker._real_register = resource_tracker.register
            resource_tracker.register = _noop_register
    except Exception:  # noqa: BLE001
        # best-effort: worst case is a spurious tracker warning at exit
        pass  # lint: waive=src.silent-except


def _worker_main(wid: int, start: int, stream, shm_name: str,
                 hb_name: str, nworkers: int, slot_bytes: int,
                 task_q, done_q, inj_spec) -> None:
    """One sampler process: claim tasks from ``task_q``, build views
    (pure in ``(seed, i)``), write them into shared-memory slots, report
    on ``done_q``. Heartbeats via the shared ``hb`` array."""
    # ctrl-C belongs to the trainer: the parent's close() retires us
    signal.signal(signal.SIGINT, signal.SIG_IGN)
    _mute_child_shm_tracking()
    seg = _shm.SharedMemory(name=shm_name)
    hbseg = _shm.SharedMemory(name=hb_name)
    hb = np.ndarray((nworkers,), np.float64, buffer=hbseg.buf)
    inj = FaultInjector(*inj_spec) if inj_spec is not None else None
    try:
        builder = stream.make_builder()
        hb[wid] = time.monotonic()
        done_q.put(("ready", wid, os.getpid()))
        while True:
            hb[wid] = time.monotonic()
            try:
                task = task_q.get(timeout=0.1)
            except _queue.Empty:
                continue
            if task is None:
                return
            i, slot, inject = task
            hb[wid] = time.monotonic()
            if inject and inj is not None:
                if inj.fires("proc_hang", key=i):
                    # a stall with NO heartbeats — exactly what the
                    # parent's claim-age watchdog exists to catch
                    time.sleep(inj.hang_seconds)
                if inj.fires("proc_kill", key=i):
                    os.kill(os.getpid(), signal.SIGKILL)
            try:
                view = stream.build(start + i, builder)
                payload = pickle.dumps(
                    _strip_view(view), protocol=pickle.HIGHEST_PROTOCOL)
                if len(payload) > slot_bytes - _PAYLOAD_OFF:
                    raise ValueError(
                        f"view {i} serialized to {len(payload)} bytes > "
                        f"slot capacity {slot_bytes - _PAYLOAD_OFF}")
                base = slot * slot_bytes
                _write_slot(seg.buf, base, payload, i)
                if inject and inj is not None and inj.fires(
                        "slot_corrupt", key=i):
                    # flip a payload byte AFTER the crc went in: the
                    # parent must detect this, never consume it
                    off = base + _PAYLOAD_OFF
                    seg.buf[off] = seg.buf[off] ^ 0xFF
            except Exception:  # noqa: BLE001 — reported to the parent
                done_q.put(("err", wid, i, slot, traceback.format_exc()))
            else:
                hb[wid] = time.monotonic()
                done_q.put(("ok", wid, i, slot))
    finally:
        seg.close()
        hbseg.close()


# ---------------------------------------------------------------------------
# the parent-side service
# ---------------------------------------------------------------------------


class _Worker:
    """Parent-side record of one sampler process.

    ``done`` is per-worker on purpose: a multiprocessing queue shared by
    N writers serializes sends on one cross-process write lock, and a
    worker SIGKILLed while its feeder thread holds that lock blocks
    every *other* worker's replies forever (observed as a livelock with
    fresh heartbeats, so the watchdog never fires). With exactly one
    writer per queue, a dying writer can only poison its own channel —
    which dies with it and is retired **without draining**.
    """

    __slots__ = ("wid", "proc", "q", "done", "ready")

    def __init__(self, wid: int):
        self.wid = wid
        self.proc = None
        self.q = None
        self.done = None
        self.ready = False


class ProcessViewService:
    """Iterator of prepared views built by supervised sampler processes.

    Drop-in for :class:`~repro.runtime.prefetch.StreamPrefetcher`: same
    constructor shape ``(stream, prepare, steps, workers, depth,
    runtime)``, same strict index-order emission, same cursor contract
    (``stream.seek`` advances only as views are *emitted*), and the same
    determinism guarantee — the emitted sequence is bit-identical to
    sequential construction for any worker count and through any
    supervised recovery. ``prepare`` (shard + device staging) runs in
    the parent, where the jitted step lives.
    """

    def __init__(self, stream, prepare, steps: Optional[int],
                 workers: int = 1, depth: int = 2,
                 runtime: Optional[Retrier] = None):
        if not shared_memory_available():
            raise ProcPoolUnavailable(
                "multiprocessing.shared_memory cannot allocate segments "
                "on this platform")
        self._stream = stream
        self._start = stream.cursor
        left = (None if stream.length is None
                else max(0, stream.length - self._start))
        if steps is None:
            self._limit = left
        else:
            self._limit = steps if left is None else min(steps, left)
        self._prepare = prepare
        self._runtime = runtime
        self._policy = runtime.policy if runtime is not None \
            else FaultPolicy()
        workers = max(1, workers)
        self._nworkers = workers
        self._max_ahead = max(1, depth) + workers - 1
        self._slot_bytes = _slot_bytes_for(stream)
        self._nslots = workers + 2
        self.events: list = []
        self._err: Optional[BaseException] = None
        self._closed = False
        self._respawns = 0
        self._emitted = 0
        self._next_build = 0
        self._requeue: list = []
        self._suppress: set = set()     # recovered indices: no re-inject
        self._results: dict = {}
        self._claims: dict = {}         # wid -> (i, slot, t_assigned)
        self._free = list(range(self._nslots))

        try:
            self._ctx = multiprocessing.get_context("spawn")
        except ValueError as e:
            raise ProcPoolUnavailable(f"no spawn context: {e}") from e
        self._seg = _shm.SharedMemory(
            create=True, size=self._nslots * self._slot_bytes)
        self._hbseg = _shm.SharedMemory(create=True, size=8 * workers)
        self._hb = np.ndarray((workers,), np.float64,
                              buffer=self._hbseg.buf)
        self._hb[:] = time.monotonic()
        # what ships to every sampler: caches pruned, builder detached
        self._child_stream = _sampler_stream(stream)
        inj = runtime.injector if runtime is not None else None
        self._inj = inj
        self._inj_spec = None
        if inj is not None:
            plan = {p: inj.plan[p] for p in PROC_POINTS if p in inj.plan}
            if plan:
                self._inj_spec = (plan, inj.seed, inj.hang_seconds)
        self._workers = [_Worker(w) for w in range(workers)]
        try:
            for w in self._workers:
                self._spawn(w)
        except BaseException:
            self.close()
            raise

    # -- worker lifecycle --------------------------------------------------

    def _spawn(self, w: _Worker) -> None:
        w.q = self._ctx.Queue()
        w.done = self._ctx.Queue()   # single-writer reply channel
        w.ready = False
        self._hb[w.wid] = time.monotonic()
        proc = self._ctx.Process(
            target=_worker_main, name=f"view-sampler-{w.wid}",
            args=(w.wid, self._start, self._child_stream,
                  self._seg.name, self._hbseg.name, self._nworkers,
                  self._slot_bytes, w.q, w.done, self._inj_spec),
            daemon=True)
        # assigned only after a successful start: close() must never try
        # to join a process that was never launched
        proc.start()
        w.proc = proc

    def _kill_proc(self, proc) -> None:
        """terminate → join → kill → join escalation."""
        proc.terminate()
        proc.join(timeout=1.0)
        if proc.is_alive():
            proc.kill()
            proc.join(timeout=5.0)

    def _retire_queue(self, q) -> None:
        if q is None:
            return
        q.close()
        q.cancel_join_thread()

    def _record_detected(self, point: str, i: int) -> None:
        """Replay the child's (pure) injection decision into the
        parent-side injector, so ``fired`` reflects detected process
        faults without a cross-process channel. A genuine (un-injected)
        fault replays to False and is recorded only in ``events``."""
        if self._inj is not None:
            self._inj.fires(point, key=i)

    def _event(self, rec: dict) -> None:
        self.events.append(rec)
        rt = self._runtime
        if rt is not None:
            with rt._lock:
                rt.events.append(rec)

    def _requeue_index(self, i: int, slot: int) -> None:
        """Claim recovery: the index rebuilds bit-exactly (pure in
        ``(seed, i)``), with injection suppressed so a keyed fault fires
        at most once per index."""
        self._suppress.add(i)
        self._requeue.append(i)
        self._free.append(slot)

    def _on_worker_death(self, w: _Worker, reason: str) -> None:
        claim = self._claims.pop(w.wid, None)
        if claim is not None:
            i, slot, _ = claim
            self._requeue_index(i, slot)
        self._event({"stage": reason, "worker": w.wid,
                     "view": None if claim is None else claim[0]})
        # retired WITHOUT draining: a write torn by the death can leave
        # the pipe with a length prefix and no body, and a recv on it
        # would block forever. The requeue above makes any lost reply
        # moot — the index rebuilds bit-exactly.
        self._retire_queue(w.q)
        self._retire_queue(w.done)
        w.q = None
        w.done = None
        w.proc = None
        self._respawns += 1
        if self._respawns > self._policy.max_proc_respawns:
            if self._err is None:
                self._err = FaultRetriesExceeded(
                    f"process pool: {self._respawns} sampler deaths "
                    "exceed max_proc_respawns="
                    f"{self._policy.max_proc_respawns}")
            return
        self._spawn(w)

    # -- the scheduling / supervision loop (consumer-driven) ---------------

    def _next_task(self) -> Optional[int]:
        if self._requeue:
            return self._requeue.pop(0)
        if self._limit is not None and self._next_build >= self._limit:
            return None
        if (self._next_build - self._emitted) >= self._max_ahead:
            return None
        i = self._next_build
        self._next_build += 1
        return i

    def _schedule(self) -> None:
        for w in self._workers:
            if (w.proc is None or not w.ready
                    or self._claims.get(w.wid) is not None
                    or not self._free):
                continue
            i = self._next_task()
            if i is None:
                return
            slot = self._free.pop()
            self._claims[w.wid] = (i, slot, time.monotonic())
            w.q.put((i, slot, i not in self._suppress))

    def _read_slot(self, slot: int, i: int):
        base = slot * self._slot_bytes
        buf = self._seg.buf
        seq = _SEQ.unpack_from(buf, base)[0]
        length, crc, idx = _META.unpack_from(buf, base + 8)
        if seq % 2:
            raise SlotCorruptionError(
                f"slot {slot}: seqlock odd ({seq}) — writer died inside")
        if idx != i:
            raise SlotCorruptionError(
                f"slot {slot}: holds view {idx}, expected {i}")
        if length > self._slot_bytes - _PAYLOAD_OFF:
            raise SlotCorruptionError(
                f"slot {slot}: length {length} exceeds capacity")
        payload = bytes(buf[base + _PAYLOAD_OFF:
                            base + _PAYLOAD_OFF + length])
        if _SEQ.unpack_from(buf, base)[0] != seq:
            raise SlotCorruptionError(f"slot {slot}: torn read "
                                      "(seq advanced during copy)")
        if zlib.crc32(payload) != crc:
            raise SlotCorruptionError(
                f"slot {slot}: crc mismatch for view {i} — corrupted "
                "or torn write")
        return _restore_view(self._stream.g, pickle.loads(payload))

    def _prepare_view(self, view, i: int):
        rt = self._runtime
        if rt is None:
            return self._prepare(view)
        return rt("view_build", lambda: self._prepare(view), key=i,
                  label=f"view[{i}]")

    def _handle_msg(self, msg) -> None:
        kind, wid = msg[0], msg[1]
        w = self._workers[wid]
        if kind == "ready":
            # pid-tagged: a stale ready from a crashed predecessor must
            # not mark its respawned replacement ready prematurely
            if w.proc is not None and w.proc.pid == msg[2]:
                w.ready = True
            return
        i, slot = msg[2], msg[3]
        claim = self._claims.get(wid)
        if claim is None or claim[0] != i or claim[1] != slot:
            return   # stale message from a claim the watchdog reassigned
        del self._claims[wid]
        if kind == "err":
            self._free.append(slot)
            if self._err is None:
                self._err = RuntimeError(
                    f"sampler process {wid} failed building view "
                    f"{i}:\n{msg[4]}")
            return
        try:
            view = self._read_slot(slot, i)
        except SlotCorruptionError as e:
            self._record_detected("slot_corrupt", i)
            self._event({"stage": "slot_corrupt", "worker": wid,
                         "view": i, "error": str(e)})
            self._requeue_index(i, slot)
            return
        self._free.append(slot)
        self._results[i] = self._prepare_view(view, i)

    def _poll_done(self, timeout: float) -> None:
        """Non-blocking sweep of every live worker's reply queue (see
        :class:`_Worker` for why the channel is per-worker). When the
        sweep comes up empty, a select-style ``connection.wait`` on the
        live reply pipes blocks until a message lands (or ``timeout``
        passes, so the supervision loop keeps its cadence) — read-side
        only, no locks shared with the children."""
        got = False
        alive = []
        for w in self._workers:
            # skip dead workers' queues: reading a pipe torn by a death
            # can block, and _supervise requeues their claims anyway
            if w.done is None or w.proc is None or not w.proc.is_alive():
                continue
            alive.append(w)
            while True:
                try:
                    msg = w.done.get_nowait()
                except _queue.Empty:
                    break
                got = True
                self._handle_msg(msg)
        if got:
            return
        if alive:
            _mpconn.wait([w.done._reader for w in alive], timeout)
        else:
            time.sleep(timeout)

    def _supervise(self) -> None:
        now = time.monotonic()
        hb_s = self._policy.worker_heartbeat_s
        for w in self._workers:
            if w.proc is None:
                continue
            if not w.proc.is_alive():
                claim = self._claims.get(w.wid)
                if claim is not None:
                    self._record_detected("proc_kill", claim[0])
                self._on_worker_death(w, "proc_kill")
                continue
            claim = self._claims.get(w.wid)
            if claim is None:
                continue
            i, _, t0 = claim
            if (now - t0 > hb_s and now - self._hb[w.wid] > hb_s):
                # claim-age watchdog: no heartbeat AND no progress on
                # the claim — terminate→kill, requeue, respawn
                self._record_detected("proc_hang", i)
                self._kill_proc(w.proc)
                self._on_worker_death(w, "proc_hang")

    # -- iterator ----------------------------------------------------------

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._limit is not None and self._emitted >= self._limit:
            raise StopIteration
        while self._emitted not in self._results:
            if self._err is not None:
                raise self._err
            if self._closed:
                raise PrefetchShutdownError(
                    "ProcessViewService used after close()")
            self._schedule()
            self._poll_done(timeout=0.05)
            self._supervise()
        item = self._results.pop(self._emitted)
        self._emitted += 1
        # cursor = views handed to the consumer, exact for checkpointing
        self._stream.seek(self._start + self._emitted)
        return item

    # -- shutdown ----------------------------------------------------------

    def close(self, timeout: float = 10.0) -> None:
        """Drain and retire every sampler: exit sentinels, join with a
        deadline, escalate terminate→kill, release the shared segments.
        After a clean close zero child processes remain."""
        if self._closed:
            return
        self._closed = True
        workers = getattr(self, "_workers", [])
        for w in workers:
            if w.proc is not None and w.proc.is_alive():
                try:
                    w.q.put_nowait(None)
                except (ValueError, OSError):
                    # queue already broken — escalation below handles it
                    pass  # lint: waive=src.silent-except
        deadline = time.monotonic() + timeout
        for w in workers:
            if w.proc is None:
                continue
            w.proc.join(timeout=max(0.0, deadline - time.monotonic()))
            if w.proc.is_alive():
                self._kill_proc(w.proc)
        stuck = [w.wid for w in workers
                 if w.proc is not None and w.proc.is_alive()]
        for w in workers:
            if w.proc is not None and not w.proc.is_alive():
                w.proc.join()       # reap
                w.proc = None
            self._retire_queue(w.q)
            self._retire_queue(w.done)
            w.q = w.done = None
        self._results.clear()
        for seg in (getattr(self, "_seg", None),
                    getattr(self, "_hbseg", None)):
            if seg is None:
                continue
            try:
                seg.close()
                seg.unlink()
            except OSError:
                # double-unlink on interpreter teardown paths is benign
                pass  # lint: waive=src.silent-except
        self._seg = self._hbseg = None
        self._hb = None
        if stuck:
            raise PrefetchShutdownError(
                f"sampler processes {stuck} survived terminate+kill "
                f"{timeout}s after close()")
