"""Fault policy, deterministic fault injection, and retry/backoff.

The paper trains over 1,024 small-memory docker workers (§5) — a regime
where sampler stalls, transient I/O failures, OOM-killed workers, and
numerically diverged steps are routine operating conditions, not
exceptional ones. This module is the vocabulary the runtime's
supervision layer speaks:

- :class:`FaultPolicy` — how hard to try: retry counts, exponential
  backoff with a cap and **deterministic** jitter (a pure function of
  ``(seed, stage, attempt)``, so two runs of the same config back off
  identically), per-stage timeouts, and what to do when a step diverges
  (``raise | skip_view | rollback``).
- :class:`FaultInjector` — seeded, deterministic chaos. Injection
  points (view build, device staging, step execution, checkpoint
  save/load, worker kill) are **no-ops in production** (no injector =
  zero overhead) and deterministic failures under test: whether
  occurrence *n* (or keyed occurrence *i*, e.g. a view index) fires is
  a pure function of ``(seed, point, n|i)`` — independent of thread
  scheduling, so chaos runs are exactly reproducible.
- :class:`Retrier` — the retry loop every supervised stage runs
  through: inject, call, catch *transient* errors only, back off,
  re-call. Retried units are pure functions of their inputs (view i of
  ``(seed, i)``, staging of its host arrays), which is what makes the
  recovered stream bit-identical to a fault-free run — the
  trajectory-invariance contract ``tests/test_faults.py`` asserts.

Everything here is host-side Python; nothing touches traced code, so
supervision can never cause a retrace.
"""
from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


class TransientError(RuntimeError):
    """An error worth retrying: the operation is a pure function of its
    inputs and the failure is environmental (I/O flake, injected)."""


class InjectedFault(TransientError):
    """A deterministic failure raised by a :class:`FaultInjector`."""

    def __init__(self, point: str, occurrence: int):
        super().__init__(f"injected fault at {point!r} "
                         f"(occurrence {occurrence})")
        self.point = point
        self.occurrence = occurrence


class WorkerKilled(BaseException):
    """A prefetch worker was killed (injected OOM-kill stand-in).

    Deliberately *not* a :class:`TransientError` — the unit of recovery
    is the worker (respawn + requeue its claimed index), not the call.
    Subclassing BaseException keeps it out of blanket ``except
    Exception`` handlers between the injection point and the worker
    loop's supervisor.
    """

    def __init__(self, occurrence: int = 0):
        super().__init__(f"worker killed (occurrence {occurrence})")
        self.occurrence = occurrence


class FaultRetriesExceeded(RuntimeError):
    """A supervised stage failed ``max_retries + 1`` consecutive times."""


class DivergenceError(RuntimeError):
    """A non-finite loss under ``on_divergence='raise'`` (or rollback
    with no checkpoint to roll back to)."""


class StepTimeoutError(RuntimeError):
    """The step watchdog: a device step failed to produce its loss
    within the policy's ``step`` timeout."""


class PrefetchShutdownError(RuntimeError):
    """``close()`` could not retire every prefetch thread — a producer
    is stuck in non-cancellable user code (leaking it silently hides a
    hung sampler and pins its staged buffers)."""


class SlotCorruptionError(TransientError):
    """A shared-memory view slot failed its crc32/seqlock check — a torn
    or corrupted cross-process handoff. Transient by design: views are
    pure in ``(seed, i)``, so the reaction is a bit-exact rebuild."""


class TrainingInterrupted(BaseException):
    """SIGINT/SIGTERM arrived mid-``fit``. Raised by the launch-CLI
    signal handlers so the fit loop unwinds through its ``finally`` (the
    prefetcher / process view service drains — no orphaned samplers) and
    :func:`repro.api.train` can save a final checkpoint on the way out.
    A BaseException so blanket ``except Exception`` recovery paths never
    swallow an operator's ctrl-C."""

    def __init__(self, signum: int):
        super().__init__(f"training interrupted by signal {signum}")
        self.signum = int(signum)


# retried by Retrier; everything else propagates immediately.
# OSError covers real transient I/O (checkpoint writes on flaky disks).
RETRYABLE = (TransientError, OSError)


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------


def _unit_hash(*parts) -> float:
    """Deterministic uniform-ish [0, 1) from arbitrary parts (crc32 —
    stable across processes, unlike ``hash``)."""
    key = ":".join(str(p) for p in parts).encode()
    return (zlib.crc32(key) % 2**31) / 2**31


@dataclass(frozen=True)
class FaultPolicy:
    """How the runtime reacts to faults. The default is production-lean:
    a few retries with sub-second capped backoff, no per-step finite
    check (it serializes the loss sync), divergence raises."""

    max_retries: int = 3            # per stage call, on RETRYABLE errors
    backoff_base: float = 0.05     # seconds before retry 1
    backoff_factor: float = 2.0    # exponential growth per attempt
    backoff_cap: float = 2.0       # seconds, growth ceiling
    jitter: float = 0.1            # +/- fraction, deterministic
    seed: int = 0                  # jitter stream seed
    # per-stage timeouts in seconds: {"view_build": ..., "step": ...};
    # absent stage = no watchdog for it
    timeouts: Mapping[str, float] = field(default_factory=dict)
    on_divergence: str = "raise"   # raise | skip_view | rollback
    check_finite: bool = False     # sync + guard every step's loss
    max_worker_respawns: int = 8   # dead prefetch workers respawned
    keep_checkpoints: int = 0      # retention (0 = keep all)
    # process-pool sampler supervision (repro.runtime.procpool): a
    # worker process whose heartbeat AND claimed build are both older
    # than worker_heartbeat_s is declared hung (terminate -> kill ->
    # respawn + requeue); max_proc_respawns caps total process respawns
    # before the pool aborts with FaultRetriesExceeded
    worker_heartbeat_s: float = 10.0
    max_proc_respawns: int = 8

    def __post_init__(self):
        if self.on_divergence not in ("raise", "skip_view", "rollback"):
            raise ValueError(
                f"on_divergence={self.on_divergence!r} — expected "
                "'raise', 'skip_view' or 'rollback'")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    def timeout(self, stage: str) -> Optional[float]:
        return self.timeouts.get(stage)

    def delay(self, stage: str, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-based): exponential with
        cap, +/- ``jitter`` fraction derived deterministically from
        ``(seed, stage, attempt)`` — reproducible, yet de-synchronized
        across stages/workers hammering one resource."""
        d = min(self.backoff_cap,
                self.backoff_base * self.backoff_factor ** attempt)
        u = _unit_hash(self.seed, stage, attempt)
        return max(0.0, d * (1.0 + self.jitter * (2.0 * u - 1.0)))


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------


class FaultInjector:
    """Seeded, deterministic chaos for the runtime's injection points.

    ``plan`` maps an injection point to *when it fires*:

    - a collection of occurrence indices: ``{"view_build": {2, 5}}``
      fires the 3rd and 6th invocation (or keyed occurrences 2 and 5
      when the call site passes ``key=``, e.g. the view index);
    - a float rate in (0, 1): occurrence *n* fires iff
      ``crc32(seed, point, n)`` maps under the rate — a pure function,
      so two runs (and any thread interleaving, for keyed sites) fire
      identically.

    Production code paths take ``injector=None`` and skip every check;
    a configured injector raises :class:`InjectedFault` (transient,
    retried) except at ``worker_kill``, which raises
    :class:`WorkerKilled` (supervised: respawn + requeue). ``fired``
    records every hit for test assertions ("the fault actually
    happened").
    """

    POINTS = ("view_build", "device_put", "step", "checkpoint_save",
              "checkpoint_load", "worker_kill", "diverge", "view_hang",
              # process-level points (repro.runtime.procpool): SIGKILL a
              # sampler process mid-build, stall one without heartbeats,
              # flip payload bytes in a shared-memory slot behind the
              # trainer's back. Thread-mode prefetch maps them to its
              # closest in-process analogs so one chaos plan covers both
              # prefetch modes.
              "proc_kill", "proc_hang", "slot_corrupt")

    def __init__(self, plan: Optional[Mapping] = None, seed: int = 0,
                 hang_seconds: float = 30.0):
        self.seed = int(seed)
        self.hang_seconds = float(hang_seconds)
        self.plan: Dict[str, object] = {}
        for point, spec in (plan or {}).items():
            if point not in self.POINTS:
                raise ValueError(
                    f"unknown injection point {point!r} "
                    f"(expected one of {self.POINTS})")
            if isinstance(spec, float):
                if not 0.0 < spec < 1.0:
                    raise ValueError(
                        f"rate for {point!r} must be in (0, 1)")
                self.plan[point] = spec
            else:
                self.plan[point] = frozenset(int(i) for i in spec)
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self.fired: Dict[str, List[int]] = {}

    def _occurrence(self, point: str, key: Optional[int]) -> int:
        if key is not None:
            return int(key)
        with self._lock:
            n = self._counts.get(point, 0)
            self._counts[point] = n + 1
        return n

    def fires(self, point: str, key: Optional[int] = None) -> bool:
        """Whether this occurrence of ``point`` fails. Pass ``key`` (a
        view index, step number, ...) wherever one exists: keyed
        decisions are independent of thread scheduling."""
        spec = self.plan.get(point)
        if spec is None:
            return False
        n = self._occurrence(point, key)
        if isinstance(spec, float):
            hit = _unit_hash(self.seed, point, n) < spec
        else:
            hit = n in spec
        if hit:
            with self._lock:
                self.fired.setdefault(point, []).append(n)
        return hit

    def maybe_fail(self, point: str, key: Optional[int] = None) -> None:
        """Raise at ``point`` if the plan says this occurrence fails."""
        if not self.plan:
            return
        if self.fires(point, key=key):
            n = int(key) if key is not None \
                else self._counts.get(point, 1) - 1
            if point in ("worker_kill", "proc_kill"):
                # thread-mode analog of SIGKILL: the supervised pool
                # requeues the claim and respawns the worker
                raise WorkerKilled(n)
            if point == "slot_corrupt":
                # thread-mode analog of a torn shm handoff: transient,
                # so the retrier rebuilds the (pure) view bit-exactly
                raise SlotCorruptionError(
                    f"injected slot corruption for view {n}")
            raise InjectedFault(point, n)

    def maybe_hang(self, point: str, key: Optional[int],
                   seconds: float, wait: Callable[[float], object]
                   ) -> bool:
        """Stall at ``point`` for ``seconds`` via ``wait`` (a
        *cancellable* waiter, e.g. ``Event.wait`` — an injected hang
        must never survive ``close()``). Returns whether it fired."""
        if self.fires(point, key=key):
            wait(seconds)
            return True
        return False

    def total_fired(self) -> int:
        return sum(len(v) for v in self.fired.values())


# ---------------------------------------------------------------------------
# retry loop
# ---------------------------------------------------------------------------


class Retrier:
    """``retrier(stage, fn)``: inject → call → retry transients with the
    policy's backoff. One instance is shared by the trainer and its
    prefetch workers (it is stateless apart from the event log, which is
    lock-guarded)."""

    def __init__(self, policy: FaultPolicy,
                 injector: Optional[FaultInjector] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.policy = policy
        self.injector = injector
        self._sleep = sleep
        self._lock = threading.Lock()
        self.events: List[dict] = []   # every retry, for observability

    def _record(self, stage: str, attempt: int, err: BaseException):
        with self._lock:
            self.events.append({"stage": stage, "attempt": attempt,
                                "error": f"{type(err).__name__}: {err}"})

    def __call__(self, stage: str, fn: Callable, key: Optional[int] = None,
                 label: str = ""):
        last: Optional[BaseException] = None
        for attempt in range(self.policy.max_retries + 1):
            try:
                if self.injector is not None:
                    # re-injecting on retries would loop keyed plans
                    # forever; a keyed occurrence fails exactly once
                    if attempt == 0 or key is None:
                        self.injector.maybe_fail(stage, key=key)
                return fn()
            except RETRYABLE as e:
                last = e
                self._record(stage, attempt, e)
                if attempt < self.policy.max_retries:
                    self._sleep(self.policy.delay(stage, attempt))
        raise FaultRetriesExceeded(
            f"stage {stage!r}{f' ({label})' if label else ''} failed "
            f"{self.policy.max_retries + 1} consecutive attempts; "
            f"last error: {type(last).__name__}: {last}") from last


def sync_with_timeout(pull: Callable[[], float],
                      timeout: Optional[float]) -> float:
    """The step watchdog: run ``pull`` (typically ``float(loss)``, which
    blocks on the device) and raise :class:`StepTimeoutError` if it does
    not complete within ``timeout`` seconds. A device computation cannot
    be cancelled from Python, so the puller runs on a daemon thread and
    is abandoned on timeout — the point is to fail the fit loudly with a
    diagnosable error instead of hanging the whole job."""
    if timeout is None:
        return pull()
    box: dict = {}
    done = threading.Event()

    def run():
        try:
            box["value"] = pull()
        except BaseException as e:  # noqa: BLE001 — re-raised below
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True, name="step-watchdog")
    t.start()
    if not done.wait(timeout):
        raise StepTimeoutError(
            f"device step did not produce its loss within {timeout}s "
            "(watchdog 'step' timeout) — the step is hung or the "
            "timeout is too tight for this graph/model")
    if "error" in box:
        raise box["error"]
    return box["value"]
