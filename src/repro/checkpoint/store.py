"""Checkpointing: flat-key npz of arbitrary pytrees (the paper's master
manages checkpoints; here the host driver plays the master role).

Layout: ``<dir>/step_<N>.npz`` with keys ``path/to/leaf`` and a JSON
manifest holding the treedef plus a **per-leaf crc32 checksum**.

Hardened for the fault-tolerant runtime (a checkpoint you cannot trust
is worse than none — rollback restores it blindly):

- writes go to an **open file handle** (so numpy cannot re-suffix the
  temp name), are **fsync'd**, then atomically renamed into place — a
  crash mid-save leaves only a ``.tmp`` orphan, never a half-written
  ``step_*.npz``;
- loads verify every leaf against the manifest checksums; truncated or
  corrupted files raise a typed :class:`CheckpointCorruptError` (never
  a bare ``zipfile``/``KeyError``), and :func:`latest_step` /
  :func:`load_checkpoint` skip them to the newest **valid** step;
- :func:`save_checkpoint` cleans up orphaned ``.tmp`` files and can
  retain only the last ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import zipfile
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


_SEP = "/"
_STEP_RE = re.compile(r"step_(\d+)\.npz$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file is truncated, unreadable, missing its manifest,
    or fails its per-leaf checksum."""


def _flatten(tree) -> dict:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}{_SEP}{i}" if prefix else str(i), v)
        else:
            flat[prefix] = np.asarray(node)

    rec("", tree)
    return flat


def _spec(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _spec(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__kind__": "tuple", "items": [_spec(v) for v in tree]}
    if isinstance(tree, list):
        return {"__kind__": "list", "items": [_spec(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(spec, flat, prefix=""):
    kind = spec["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}{_SEP}{k}" if prefix else k)
                for k, v in spec["items"].items()}
    if kind in ("tuple", "list"):
        seq = [_rebuild(v, flat, f"{prefix}{_SEP}{i}" if prefix else str(i))
               for i, v in enumerate(spec["items"])]
        return tuple(seq) if kind == "tuple" else seq
    return flat[prefix]


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _step_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}.npz")


def _clean_tmp(directory: str, keep_path: Optional[str] = None) -> int:
    """Remove orphaned ``*.tmp`` files (a crash mid-save leaves exactly
    one; single-writer, so any .tmp not being written right now is
    garbage). Returns how many were removed."""
    removed = 0
    for f in os.listdir(directory):
        if not f.endswith(".tmp"):
            continue
        full = os.path.join(directory, f)
        if full == keep_path:
            continue
        try:
            os.remove(full)
            removed += 1
        except OSError:
            continue   # racing cleanup loses harmlessly
    return removed


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 0) -> str:
    """Atomically write ``tree`` as ``step_<N>.npz``.

    The npz is written to an **open handle** on a ``.tmp`` path (numpy
    appends ``.npz`` to *names*, never to handles — the suffix is
    deterministic), flushed and fsync'd, then renamed over the final
    path. The manifest records a crc32 per leaf, verified on load.
    ``keep > 0`` retains only the newest ``keep`` checkpoints.
    """
    os.makedirs(directory, exist_ok=True)
    _clean_tmp(directory)
    host_tree = jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten(host_tree)
    manifest = {
        "spec": _spec(host_tree),
        "checksums": {k: _leaf_crc(v) for k, v in flat.items()},
    }
    path = _step_path(directory, step)
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=np.frombuffer(
                json.dumps(manifest).encode(), dtype=np.uint8), **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # never leave a half-written tmp masquerading as in-progress
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                # cleanup of a cleanup; the original error is what matters
                pass  # lint: waive=src.silent-except
        raise
    if keep > 0:
        for s in checkpoint_steps(directory)[:-keep]:
            try:
                os.remove(_step_path(directory, s))
            except OSError:
                continue   # retention is advisory; a locked file stays
    return path


def _load_verified(path: str) -> Any:
    """Read + checksum-verify one checkpoint file; every failure mode
    (truncated zip, unreadable member, missing manifest, bad crc) is a
    :class:`CheckpointCorruptError`."""
    try:
        with np.load(path) as data:
            if "__manifest__" not in data.files:
                raise CheckpointCorruptError(
                    f"{path}: no __manifest__ key — not a checkpoint "
                    "or header lost")
            manifest = json.loads(bytes(data["__manifest__"]).decode())
            flat = {k: data[k] for k in data.files if k != "__manifest__"}
    except CheckpointCorruptError:
        raise
    except (zipfile.BadZipFile, OSError, EOFError, ValueError, KeyError,
            json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"{path}: unreadable ({type(e).__name__}: {e})") from e
    if "spec" in manifest:            # hardened format: verify leaves
        spec = manifest["spec"]
        sums: Dict[str, int] = manifest.get("checksums", {})
        missing = set(sums) - set(flat)
        if missing:
            raise CheckpointCorruptError(
                f"{path}: leaves missing vs manifest: {sorted(missing)}")
        for k, want in sums.items():
            got = _leaf_crc(flat[k])
            if got != int(want):
                raise CheckpointCorruptError(
                    f"{path}: checksum mismatch on leaf {k!r} "
                    f"(manifest {int(want):#010x}, data {got:#010x})")
    else:                             # pre-hardening manifest = bare spec
        spec = manifest
    try:
        return _rebuild(spec, flat)
    except (KeyError, IndexError, TypeError) as e:
        raise CheckpointCorruptError(
            f"{path}: manifest/leaf structure mismatch "
            f"({type(e).__name__}: {e})") from e


def verify_checkpoint(path: str) -> bool:
    """True iff ``path`` loads and passes every checksum."""
    try:
        _load_verified(path)
        return True
    except (CheckpointCorruptError, FileNotFoundError):
        return False


def load_checkpoint(directory: str, step: Optional[int] = None) -> Any:
    """Load a checkpoint. ``step=None`` walks newest → oldest and
    returns the first that verifies, so resume after a crash (or a
    corrupted latest file) falls back to the previous valid step; an
    explicit ``step`` raises :class:`CheckpointCorruptError` if that
    file is bad."""
    if step is not None:
        return _load_verified(_step_path(directory, step))
    steps = checkpoint_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    last_err: Optional[CheckpointCorruptError] = None
    for s in reversed(steps):
        try:
            return _load_verified(_step_path(directory, s))
        except CheckpointCorruptError as e:
            last_err = e
    raise CheckpointCorruptError(
        f"no valid checkpoint in {directory} "
        f"({len(steps)} candidates, all corrupt; last: {last_err})")


def checkpoint_steps(directory: str) -> list:
    """All on-disk step numbers, ascending (no validation)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for f in os.listdir(directory):
        m = _STEP_RE.match(f)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str, validate: bool = True) -> Optional[int]:
    """Newest step number — by default the newest that actually
    **verifies** (corrupt/truncated files are skipped), so the resume
    path never points at a checkpoint the load would reject.
    ``validate=False`` is the old name-only scan."""
    steps = checkpoint_steps(directory)
    if not validate:
        return steps[-1] if steps else None
    for s in reversed(steps):
        if verify_checkpoint(_step_path(directory, s)):
            return s
    return None
