"""Checkpointing: flat-key npz of arbitrary pytrees (the paper's master
manages checkpoints; here the host driver plays the master role).

Layout: <dir>/step_<N>.npz  with keys "path/to/leaf" and a JSON manifest of
the treedef so structure round-trips exactly.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}{_SEP}{i}" if prefix else str(i), v)
        else:
            flat[prefix] = np.asarray(node)

    rec("", tree)
    return flat


def _spec(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _spec(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__kind__": "tuple", "items": [_spec(v) for v in tree]}
    if isinstance(tree, list):
        return {"__kind__": "list", "items": [_spec(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(spec, flat, prefix=""):
    kind = spec["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}{_SEP}{k}" if prefix else k)
                for k, v in spec["items"].items()}
    if kind in ("tuple", "list"):
        seq = [_rebuild(v, flat, f"{prefix}{_SEP}{i}" if prefix else str(i))
               for i, v in enumerate(spec["items"])]
        return tuple(seq) if kind == "tuple" else seq
    return flat[prefix]


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    host_tree = jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten(host_tree)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    np.savez(tmp, __manifest__=np.frombuffer(
        json.dumps(_spec(host_tree)).encode(), dtype=np.uint8), **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return path


def load_checkpoint(directory: str, step: Optional[int] = None) -> Any:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        manifest = json.loads(bytes(data["__manifest__"]).decode())
        flat = {k: data[k] for k in data.files if k != "__manifest__"}
    return _rebuild(manifest, flat)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.match(r"step_(\d+)\.npz$", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
