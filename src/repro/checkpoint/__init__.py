from repro.checkpoint.store import (CheckpointCorruptError,
                                    checkpoint_steps, latest_step,
                                    load_checkpoint, save_checkpoint,
                                    verify_checkpoint)

__all__ = ["CheckpointCorruptError", "checkpoint_steps", "latest_step",
           "load_checkpoint", "save_checkpoint", "verify_checkpoint"]
