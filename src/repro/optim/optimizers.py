"""Hand-rolled optimizers (the paper ships SGD, Adam, AdamW — §4).

Functional interface:
    opt = adam(lr=1e-3)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

Optimizer state mirrors the param pytree (sharding follows params), which
is what lets the launcher shard m/v the same way as weights (FSDP).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_global_norm

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1
                    ) -> Schedule:
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * (final_frac + (1 - final_frac) * cos),
                           jnp.float32)
    return f


def warmup_cosine_schedule(lr: float, warmup: int, total_steps: int,
                           final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        wu = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, wu, cos(step - warmup)).astype(
            jnp.float32)
    return f


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (params, state)
    name: str = "opt"


def _to_sched(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


def sgd(lr=1e-2, momentum: float = 0.0, weight_decay: float = 0.0,
        grad_clip: float = 0.0) -> Optimizer:
    sched = _to_sched(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    def update(grads, state, params):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        lr_t = sched(state["step"])
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            new_params = jax.tree_util.tree_map(
                lambda p, m: (p.astype(jnp.float32) - lr_t * m
                              ).astype(p.dtype), params, mu)
            return new_params, {"step": step, "mu": mu}
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, {"step": step}

    return Optimizer(init, update, "sgd")


def _adam_like(lr, b1, b2, eps, weight_decay, decoupled, grad_clip, name):
    sched = _to_sched(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        lr_t = sched(state["step"])
        if weight_decay and not decoupled:  # classic L2 (paper's Adam)
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state["v"], grads)
        stepf = step.astype(jnp.float32)
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and decoupled:  # AdamW
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, name)


def adam(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
         grad_clip: float = 0.0) -> Optimizer:
    return _adam_like(lr, b1, b2, eps, weight_decay, False, grad_clip, "adam")


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          grad_clip: float = 0.0) -> Optimizer:
    return _adam_like(lr, b1, b2, eps, weight_decay, True, grad_clip, "adamw")


def make_optimizer(name: str, lr, weight_decay: float = 0.0,
                   grad_clip: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(lr, momentum=0.9, weight_decay=weight_decay,
                   grad_clip=grad_clip)
    if name == "adam":
        return adam(lr, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay or 0.01,
                     grad_clip=grad_clip)
    raise ValueError(f"unknown optimizer {name!r}")
