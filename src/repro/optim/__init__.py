from repro.optim.optimizers import (
    Optimizer, sgd, adam, adamw, make_optimizer,
    cosine_schedule, constant_schedule, warmup_cosine_schedule,
    clip_by_global_norm,
)

__all__ = [
    "Optimizer", "sgd", "adam", "adamw", "make_optimizer",
    "cosine_schedule", "constant_schedule", "warmup_cosine_schedule",
    "clip_by_global_norm",
]
