from repro.graph.csr import Graph, GraphBlock, build_block
from repro.graph.datasets import (
    sbm_graph, powerlaw_graph, citation_graph, make_dataset,
)

__all__ = [
    "Graph", "GraphBlock", "build_block",
    "sbm_graph", "powerlaw_graph", "citation_graph", "make_dataset",
]
