"""Graph storage: host-side global graph (CSR + CSC) and device GraphBlock.

The paper (§4.1) organizes outgoing edges in CSR and incoming edges in CSC
and stores node/edge values separately; we mirror that. ``Graph`` is the
host/numpy global graph (the distributed store); ``GraphBlock`` is the
fixed-shape jnp view a JIT-compiled step consumes (whole graph, a k-hop
subgraph, or one partition's shard).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Graph:
    """Global directed graph. For undirected inputs both directions exist."""
    src: np.ndarray                  # (M,) int32
    dst: np.ndarray                  # (M,) int32
    num_nodes: int
    node_features: np.ndarray        # (N, F) float32
    labels: np.ndarray               # (N,)  int32
    edge_features: Optional[np.ndarray] = None   # (M, Fe) float32
    edge_weights: Optional[np.ndarray] = None    # (M,)  float32
    train_mask: Optional[np.ndarray] = None      # (N,) bool
    val_mask: Optional[np.ndarray] = None
    test_mask: Optional[np.ndarray] = None
    name: str = "graph"
    # CSR/CSC built lazily
    _csr: Optional[tuple] = field(default=None, repr=False)
    _csc: Optional[tuple] = field(default=None, repr=False)
    # cached CSCPlans for the blocked aggregation kernels, keyed by
    # (n_pad, e_pad, block_n, block_e) — built once, shared by every view
    _csc_plans: dict = field(default_factory=dict, repr=False)
    # cached per-edge GCN norm + strategy-invariant base blocks (views
    # stamp their masks onto a shallow copy — see base_block below)
    _gcn_norm: Optional[np.ndarray] = field(default=None, repr=False)
    _base_blocks: dict = field(default_factory=dict, repr=False)

    @property
    def num_edges(self) -> int:
        return int(len(self.src))

    # --- CSR (outgoing) / CSC (incoming) ------------------------------------

    def csr(self):
        """(indptr, order) such that edges order[indptr[u]:indptr[u+1]]
        have src == u. ``order`` indexes into the edge arrays."""
        if self._csr is None:
            order = np.argsort(self.src, kind="stable").astype(np.int32)
            counts = np.bincount(self.src, minlength=self.num_nodes)
            indptr = np.zeros(self.num_nodes + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self._csr = (indptr, order)
        return self._csr

    def csc(self):
        if self._csc is None:
            order = np.argsort(self.dst, kind="stable").astype(np.int32)
            counts = np.bincount(self.dst, minlength=self.num_nodes)
            indptr = np.zeros(self.num_nodes + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self._csc = (indptr, order)
        return self._csc

    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_nodes)

    def in_degree(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_nodes)

    def out_neighbors(self, u: int) -> np.ndarray:
        indptr, order = self.csr()
        return self.dst[order[indptr[u]:indptr[u + 1]]]

    def gcn_norm(self) -> np.ndarray:
        """Per-edge symmetric GCN normalization 1/sqrt(d_i d_j) with
        self-loop-augmented degrees (Kipf & Welling). Cached — the edge
        set never changes, so every view/block of this graph shares one
        (M,) array (compact views gather slices of it per batch)."""
        if self._gcn_norm is None:
            deg = self.in_degree().astype(np.float64) + 1.0
            self._gcn_norm = (
                1.0 / np.sqrt(deg[self.src] * deg[self.dst])).astype(
                np.float32)
        return self._gcn_norm

    def csc_plan(self, pad_nodes: int = 0, pad_edges: int = 0,
                 block_n: int = 128, block_e: int = 256):
        """Cached CSCPlan over the (padded) destination ids — the reused
        indexing of paper §4.2: every view/batch of this graph shares it
        (views change activity masks, never the edge layout)."""
        n_pad = max(pad_nodes, self.num_nodes)
        e_pad = max(pad_edges, self.num_edges)
        key = (n_pad, e_pad, block_n, block_e)
        if key not in self._csc_plans:
            from repro.kernels.ops import build_csc_plan
            ids = np.zeros(e_pad, np.int32)
            ids[: self.num_edges] = self.dst
            self._csc_plans[key] = build_csc_plan(ids, n_pad, block_n,
                                                  block_e)
        return self._csc_plans[key]

    def add_self_loops(self) -> "Graph":
        loops = np.arange(self.num_nodes, dtype=np.int32)
        src = np.concatenate([self.src, loops])
        dst = np.concatenate([self.dst, loops])
        ef = None
        if self.edge_features is not None:
            ef = np.concatenate(
                [self.edge_features,
                 np.zeros((self.num_nodes, self.edge_features.shape[1]),
                          self.edge_features.dtype)])
        ew = None
        if self.edge_weights is not None:
            ew = np.concatenate(
                [self.edge_weights, np.ones(self.num_nodes, np.float32)])
        return Graph(src.astype(np.int32), dst.astype(np.int32),
                     self.num_nodes, self.node_features, self.labels,
                     ef, ew, self.train_mask, self.val_mask, self.test_mask,
                     self.name + "+loops")


@dataclass
class GraphBlock:
    """Fixed-shape device view. All arrays are padded; masks mark validity.

    ``src``/``dst`` index into the node axis of ``x``. For a distributed
    shard the node axis is [masters ; mirrors] (see core/partition.py).
    Registered as a jax pytree (see bottom of file) so blocks pass through
    ``jit`` boundaries directly.
    """
    src: np.ndarray                 # (E_pad,) int32
    dst: np.ndarray                 # (E_pad,) int32
    edge_mask: np.ndarray           # (E_pad,) f32 1=valid
    node_mask: np.ndarray           # (N_pad,) f32 1=valid
    x: np.ndarray                   # (N_pad, F)
    y: np.ndarray                   # (N_pad,) int32
    loss_mask: np.ndarray           # (N_pad,) f32 — nodes contributing loss
    edge_weight: np.ndarray         # (E_pad,) f32 (e.g. GCN norm; 1s else)
    edge_attr: Optional[np.ndarray] = None     # (E_pad, Fe)
    # per-layer active sets (paper §4.2 "active status of nodes and edges");
    # shape (K, N_pad) / (K, E_pad); None = all valid entries active
    node_active: Optional[np.ndarray] = None
    edge_active: Optional[np.ndarray] = None
    # cached CSCPlan (repro.kernels.ops) for the "csc" aggregation backend;
    # None keeps the reference jnp segment ops
    csc_plan: Optional[object] = None

    @property
    def num_nodes_padded(self) -> int:
        return int(self.x.shape[0])

    @property
    def num_edges_padded(self) -> int:
        return int(self.src.shape[0])


def build_block(g: Graph, pad_nodes: int = 0, pad_edges: int = 0,
                loss_mask: Optional[np.ndarray] = None,
                gcn_norm: bool = True,
                csc_plan: bool = False) -> GraphBlock:
    """Whole-graph block (global-batch view). ``csc_plan=True`` attaches
    the graph's cached CSCPlan so the "csc" aggregation backend can run."""
    n, m = g.num_nodes, g.num_edges
    n_pad = max(pad_nodes, n)
    e_pad = max(pad_edges, m)
    src = np.zeros(e_pad, np.int32)
    dst = np.zeros(e_pad, np.int32)
    emask = np.zeros(e_pad, np.float32)
    src[:m], dst[:m], emask[:m] = g.src, g.dst, 1.0
    nmask = np.zeros(n_pad, np.float32)
    nmask[:n] = 1.0
    x = np.zeros((n_pad, g.node_features.shape[1]), np.float32)
    x[:n] = g.node_features
    y = np.zeros(n_pad, np.int32)
    y[:n] = g.labels
    lm = np.zeros(n_pad, np.float32)
    if loss_mask is None:
        loss_mask = (g.train_mask if g.train_mask is not None
                     else np.ones(n, bool))
    lm[:n] = loss_mask.astype(np.float32)
    ew = np.zeros(e_pad, np.float32)
    ew[:m] = g.gcn_norm() if gcn_norm else (
        g.edge_weights if g.edge_weights is not None else 1.0)
    ea = None
    if g.edge_features is not None:
        ea = np.zeros((e_pad, g.edge_features.shape[1]), np.float32)
        ea[:m] = g.edge_features
    plan = g.csc_plan(n_pad, e_pad) if csc_plan else None
    return GraphBlock(src, dst, emask, nmask, x, y, lm, ew, ea,
                      csc_plan=plan)


def base_block(g: Graph, gcn_norm: bool = True,
               csc_plan: bool = False) -> GraphBlock:
    """The strategy-invariant whole-graph block, cached per
    ``(gcn_norm, csc_plan)``: edge layout, features, labels and edge
    weights are identical across every view of one graph — only the loss
    mask and activity masks differ, and :meth:`GraphView.as_block` stamps
    those onto a shallow copy. Callers must treat the shared arrays as
    read-only."""
    key = (bool(gcn_norm), bool(csc_plan))
    if key not in g._base_blocks:
        g._base_blocks[key] = build_block(g, gcn_norm=gcn_norm,
                                          csc_plan=csc_plan)
    return g._base_blocks[key]


# ---------------------------------------------------------------------------
# pytree registration: GraphBlock flows through jit/grad as a container
# ---------------------------------------------------------------------------

_BLOCK_FIELDS = ("src", "dst", "edge_mask", "node_mask", "x", "y",
                 "loss_mask", "edge_weight", "edge_attr", "node_active",
                 "edge_active", "csc_plan")


def _block_flatten(b: GraphBlock):
    return tuple(getattr(b, f) for f in _BLOCK_FIELDS), None


def _block_unflatten(aux, children):
    return GraphBlock(*children)


try:
    import jax as _jax
    _jax.tree_util.register_pytree_node(GraphBlock, _block_flatten,
                                        _block_unflatten)
except ImportError:
    # numpy-only contexts: graph I/O works without jax, blocks just
    # aren't pytrees there
    pass  # lint: waive=src.silent-except
