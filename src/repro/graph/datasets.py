"""Synthetic graph generators standing in for the paper's datasets.

No public datasets are available offline, so each paper dataset is replaced
by a generator with matched *structure*:

- citation networks (Cora/Citeseer/Pubmed) -> ``citation_graph``: SBM with
  strong intra-class linking + sparse bag-of-words-like features.
- Reddit/Amazon (dense co-comment/co-purchase) -> ``sbm_graph`` with high
  density and planted communities (cluster-batch friendly).
- Alipay (1.4B nodes, power-law, edge attributes) -> ``powerlaw_graph``:
  preferential attachment, skewed degrees, edge features + binary risk
  labels (scaled down to fit one host).

All generators are deterministic in ``seed`` and return ``Graph`` with both
edge directions materialized (undirected semantics, as GCN assumes).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import Graph


def _bidirect(src, dst):
    s = np.concatenate([src, dst]).astype(np.int32)
    d = np.concatenate([dst, src]).astype(np.int32)
    # dedupe
    key = s.astype(np.int64) * (max(int(s.max()), int(d.max())) + 1) + d
    _, idx = np.unique(key, return_index=True)
    return s[idx], d[idx], idx


def _masks(n, rng, train=0.6, val=0.2):
    order = rng.permutation(n)
    tr = np.zeros(n, bool)
    va = np.zeros(n, bool)
    te = np.zeros(n, bool)
    n_tr, n_va = int(n * train), int(n * val)
    tr[order[:n_tr]] = True
    va[order[n_tr:n_tr + n_va]] = True
    te[order[n_tr + n_va:]] = True
    return tr, va, te


def sbm_graph(num_nodes=1000, num_classes=4, feature_dim=64,
              p_in=0.02, p_out=0.002, feature_noise=1.0, seed=0,
              name="sbm") -> Graph:
    """Stochastic block model with class-prototype features."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, num_nodes).astype(np.int32)
    # expected edges per pair-class; sample by blocks to keep it O(M)
    srcs, dsts = [], []
    for a in range(num_classes):
        ia = np.where(labels == a)[0]
        for b in range(a, num_classes):
            ib = np.where(labels == b)[0]
            p = p_in if a == b else p_out
            n_pairs = len(ia) * len(ib)
            n_edges = rng.binomial(n_pairs, p)
            if n_edges == 0:
                continue
            s = ia[rng.integers(0, len(ia), n_edges)]
            d = ib[rng.integers(0, len(ib), n_edges)]
            keep = s != d
            srcs.append(s[keep])
            dsts.append(d[keep])
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    src, dst, _ = _bidirect(src, dst)
    protos = rng.normal(size=(num_classes, feature_dim)).astype(np.float32)
    feats = (protos[labels]
             + feature_noise * rng.normal(
                 size=(num_nodes, feature_dim)).astype(np.float32))
    tr, va, te = _masks(num_nodes, rng)
    return Graph(src, dst, num_nodes, feats.astype(np.float32), labels,
                 train_mask=tr, val_mask=va, test_mask=te, name=name)


def citation_graph(which: str = "cora", seed: int = 0) -> Graph:
    """Scaled synthetic stand-ins for the three citation networks."""
    spec = {
        # nodes, classes, feat_dim, p_in, p_out (sparser, like citations)
        "cora": (1354, 7, 128, 0.008, 0.0004),
        "citeseer": (1650, 6, 128, 0.005, 0.0004),
        "pubmed": (2500, 3, 100, 0.004, 0.0004),
    }[which]
    n, c, f, p_in, p_out = spec
    # NOT hash(which): str hashes are salted per process (PYTHONHASHSEED),
    # which made "deterministic in seed" silently false across runs
    g = sbm_graph(n, c, f, p_in, p_out, feature_noise=1.5,
                  seed=seed + sum(which.encode()) % 1000, name=which)
    # bag-of-words flavour: sparsify + binarize features
    rng = np.random.default_rng(seed + 7)
    keep = rng.random(g.node_features.shape) < 0.3
    g.node_features = (np.where(g.node_features > 0.5, 1.0, 0.0)
                       * keep).astype(np.float32)
    # low label rate like planetoid splits
    tr, va, te = _masks(n, rng, train=0.15, val=0.25)
    g.train_mask, g.val_mask, g.test_mask = tr, va, te
    return g


def powerlaw_graph(num_nodes=20000, avg_degree=6, feature_dim=32,
                   edge_feature_dim=8, num_classes=2, seed=0,
                   name="alipay_like") -> Graph:
    """Preferential-attachment graph with skewed degrees + edge attributes.

    Labels are planted from a 2-hop structural signal (risk propagates from
    seed nodes along edges) so that an edge-attributed GNN (GAT-E) has real
    signal to learn — mirroring the Alipay risk task shape.
    """
    rng = np.random.default_rng(seed)
    m = max(1, avg_degree // 2)
    # Barabási–Albert via repeated-endpoint trick (degree-proportional)
    targets = list(range(m))
    repeated = []
    src_l, dst_l = [], []
    for v in range(m, num_nodes):
        # choose m targets from repeated endpoints (degree-proportional)
        if repeated:
            idx = rng.integers(0, len(repeated), m)
            chosen = {repeated[i] for i in idx}
        else:
            chosen = set(targets[:m])
        for t in chosen:
            src_l.append(v)
            dst_l.append(t)
            repeated.extend((v, t))
    src = np.array(src_l, np.int64)
    dst = np.array(dst_l, np.int64)
    src, dst, keep_idx = _bidirect(src, dst)
    M = len(src)
    # edge attributes: relation-type one-hot-ish + strength
    ef = rng.normal(size=(M, edge_feature_dim)).astype(np.float32)
    rel = rng.integers(0, edge_feature_dim // 2, M)
    ef[np.arange(M), rel] += 2.0
    # plant labels: seeds are "risky"; risk spreads along strong edges
    risk = np.zeros(num_nodes, np.float32)
    seeds = rng.choice(num_nodes, max(2, num_nodes // 100), replace=False)
    risk[seeds] = 1.0
    strength = 1.0 / (1.0 + np.exp(-ef[:, 0]))
    for _ in range(2):
        spread = np.zeros(num_nodes, np.float32)
        np.add.at(spread, dst, risk[src] * strength)
        risk = np.clip(risk + 0.5 * spread, 0, 4)
    labels = (risk > np.quantile(risk, 0.85)).astype(np.int32)
    feats = rng.normal(size=(num_nodes, feature_dim)).astype(np.float32)
    feats[:, 0] += risk * 0.5          # weak node-level signal
    tr, va, te = _masks(num_nodes, rng, train=0.5, val=0.0)
    return Graph(src, dst, num_nodes, feats, labels, edge_features=ef,
                 train_mask=tr, val_mask=va, test_mask=te, name=name)


def make_dataset(name: str, seed: int = 0, **kw) -> Graph:
    if name in ("cora", "citeseer", "pubmed"):
        return citation_graph(name, seed)
    if name == "reddit_like":
        return sbm_graph(kw.pop("num_nodes", 4000), kw.pop("num_classes", 8),
                         kw.pop("feature_dim", 64), p_in=0.02, p_out=0.001,
                         seed=seed, name="reddit_like", **kw)
    if name == "amazon_like":
        return sbm_graph(kw.pop("num_nodes", 6000), kw.pop("num_classes", 10),
                         kw.pop("feature_dim", 64), p_in=0.012, p_out=0.0006,
                         seed=seed, name="amazon_like", **kw)
    if name == "alipay_like":
        return powerlaw_graph(seed=seed, **kw)
    raise ValueError(f"unknown dataset {name!r}")
