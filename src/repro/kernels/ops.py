"""jit'd public wrappers around the Pallas kernels (+ host-side planning).

Each op takes ``interpret=`` so the TPU kernels validate on CPU; the pure
jnp oracles live in ref.py. On this container everything runs in interpret
mode; on a real TPU pod the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_sum import segment_sum_csc
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel


# ---------------------------------------------------------------------------
# segment sum: host plan + device op
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CSCPlan:
    """Per-graph padded edge layout for the blocked aggregation kernel.

    Built once per graph (the paper's reused CSC indexing); all views and
    batches reuse it — only the per-edge messages change between steps.
    """
    gather_idx: np.ndarray    # (nb, L_pad) int32 into edge axis (E = pad row)
    local_ids: np.ndarray     # (nb, L_pad) int32 in [0, BN]; BN = padding
    num_blocks: int
    block_n: int
    block_e: int
    num_segments: int
    num_edges: int


def build_csc_plan(segment_ids: np.ndarray, num_segments: int,
                   block_n: int = 128, block_e: int = 256) -> CSCPlan:
    ids = np.asarray(segment_ids)
    E = len(ids)
    order = np.argsort(ids, kind="stable").astype(np.int64)
    sorted_ids = ids[order]
    nb = (num_segments + block_n - 1) // block_n
    starts = np.searchsorted(sorted_ids, np.arange(nb) * block_n)
    ends = np.searchsorted(sorted_ids, np.minimum((np.arange(nb) + 1)
                                                  * block_n, num_segments))
    lens = ends - starts
    l_max = int(lens.max()) if nb else 0
    l_pad = max(block_e, ((l_max + block_e - 1) // block_e) * block_e)
    gather = np.full((nb, l_pad), E, np.int32)          # E = zero pad row
    local = np.full((nb, l_pad), block_n, np.int32)     # BN = dead row
    for b in range(nb):
        sl = order[starts[b]:ends[b]]
        gather[b, :lens[b]] = sl
        local[b, :lens[b]] = ids[sl] - b * block_n
    return CSCPlan(gather, local, nb, block_n, block_e, num_segments, E)


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "block_n", "block_e", "interpret"))
def _segment_sum_planned(data, gather_idx, local_ids, num_segments: int,
                         block_n: int, block_e: int, interpret: bool):
    D = data.shape[1]
    padded = jnp.concatenate([data, jnp.zeros((1, D), data.dtype)], axis=0)
    gathered = padded[gather_idx]                         # (nb, L_pad, D)
    out = segment_sum_csc(gathered, local_ids, gather_idx.shape[0],
                          block_n, block_e, interpret=interpret)
    return out[:num_segments]


def segment_sum_op(data: jax.Array, plan: CSCPlan,
                   interpret: bool = True) -> jax.Array:
    """data (E, D) float -> (num_segments, D), via the Pallas kernel."""
    assert data.shape[0] == plan.num_edges
    return _segment_sum_planned(
        data, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_segments, plan.block_n, plan.block_e, interpret)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_op(r, k, v, w, u, chunk: int = 64, interpret: bool = True):
    """Chunked WKV6; pads T up to a chunk multiple and slices back."""
    B, T, H, K = r.shape
    pad = (-T) % chunk
    if pad:
        zk = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zk(r), zk(k), zk(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    out = _wkv6_kernel(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :T]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, causal: bool = True, sliding_window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True):
    """GQA-aware wrapper: repeats kv heads to q heads, pads T to blocks."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq = min(block_q, T)
    bk = min(block_k, T)
    pad = (-T) % max(bq, bk)
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zp(q), zp(k), zp(v)
    out = _flash_kernel(q, k, v, causal=causal,
                        sliding_window=sliding_window,
                        block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :T]


# ---------------------------------------------------------------------------
# edge softmax (GAT aggregation)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "block_n", "block_e", "interpret"))
def _edge_softmax_planned(logits, values, gather_idx, local_ids,
                          num_segments: int, block_n: int, block_e: int,
                          interpret: bool):
    from repro.kernels.edge_softmax import edge_softmax_csc
    D = values.shape[1]
    pl_ = jnp.concatenate([logits, jnp.full((1,), -1e30, logits.dtype)])
    pv = jnp.concatenate([values, jnp.zeros((1, D), values.dtype)], axis=0)
    gl = pl_[gather_idx]
    gv = pv[gather_idx]
    out = edge_softmax_csc(gl, gv, local_ids, gather_idx.shape[0],
                           block_n, block_e, interpret=interpret)
    return out[:num_segments]


def edge_softmax_op(logits: jax.Array, values: jax.Array, plan: CSCPlan,
                    interpret: bool = True) -> jax.Array:
    """Fused GAT aggregation: logits (E,), values (E, D) ->
    (num_segments, D) of softmax-weighted neighbor sums."""
    assert logits.shape[0] == plan.num_edges
    return _edge_softmax_planned(
        logits, values, jnp.asarray(plan.gather_idx),
        jnp.asarray(plan.local_ids), plan.num_segments, plan.block_n,
        plan.block_e, interpret)
