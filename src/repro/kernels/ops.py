"""jit'd public wrappers around the Pallas kernels (+ host-side planning).

Each op takes ``interpret=`` so the TPU kernels validate on CPU; the pure
jnp oracles live in ref.py. On this container everything runs in interpret
mode; on a real TPU pod the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_sum import segment_sum_csc, segment_max_csc
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel


# ---------------------------------------------------------------------------
# segment sum / max: host plan + device ops
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CSCPlan:
    """Per-graph padded edge layout for the blocked aggregation kernels.

    Built once per graph (the paper's reused CSC indexing); all views and
    batches reuse it — only the per-edge messages change between steps.
    Registered as a jax pytree (index arrays are leaves, the block geometry
    is static aux data) so plans ride along GraphBlocks and engine shards
    through ``jit`` / ``shard_map`` / ``grad``.
    """
    gather_idx: np.ndarray    # (nb, L_pad) int32 into edge axis (E = pad
    #                           lane; the fused kernels clip it and the
    #                           local_ids masking nulls its contribution)
    local_ids: np.ndarray     # (nb, L_pad) int32 in [0, BN]; BN = padding
    num_blocks: int
    block_n: int
    block_e: int
    num_segments: int
    num_edges: int


def _plan_flatten(p: CSCPlan):
    return ((p.gather_idx, p.local_ids),
            (p.num_blocks, p.block_n, p.block_e, p.num_segments,
             p.num_edges))


def _plan_unflatten(aux, children):
    return CSCPlan(children[0], children[1], *aux)


jax.tree_util.register_pytree_node(CSCPlan, _plan_flatten, _plan_unflatten)


def build_csc_plan(segment_ids: np.ndarray, num_segments: int,
                   block_n: int = 128, block_e: int = 256,
                   l_pad: int = 0) -> CSCPlan:
    """``l_pad`` > 0 forces the padded edge-slice length (so plans built for
    different shards of one graph stack into a single (P, nb, L) array)."""
    ids = np.asarray(segment_ids)
    E = len(ids)
    order = np.argsort(ids, kind="stable").astype(np.int64)
    sorted_ids = ids[order]
    nb = (num_segments + block_n - 1) // block_n
    starts = np.searchsorted(sorted_ids, np.arange(nb) * block_n)
    ends = np.searchsorted(sorted_ids, np.minimum((np.arange(nb) + 1)
                                                  * block_n, num_segments))
    lens = ends - starts
    l_max = int(lens.max()) if nb else 0
    l_min = max(block_e, ((l_max + block_e - 1) // block_e) * block_e)
    if l_pad:
        assert l_pad >= l_min and l_pad % block_e == 0, (l_pad, l_min)
    else:
        l_pad = l_min
    gather = np.full((nb, l_pad), E, np.int32)          # E = pad lane
    local = np.full((nb, l_pad), block_n, np.int32)     # BN = dead row
    for b in range(nb):
        sl = order[starts[b]:ends[b]]
        gather[b, :lens[b]] = sl
        local[b, :lens[b]] = ids[sl] - b * block_n
    return CSCPlan(gather, local, nb, block_n, block_e, num_segments, E)


def build_csc_plans_stacked(segment_ids_rows, num_segments: int,
                            block_n: int = 128, block_e: int = 256):
    """One plan per row of ``segment_ids_rows`` (P, E), all with identical
    padded shapes — the per-shard reused plans of the distributed engine."""
    rows = [np.asarray(r) for r in segment_ids_rows]
    plans = [build_csc_plan(r, num_segments, block_n, block_e) for r in rows]
    l_pad = max(p.gather_idx.shape[1] for p in plans)

    def widen(p: CSCPlan) -> CSCPlan:
        extra = l_pad - p.gather_idx.shape[1]
        if not extra:
            return p
        gather = np.pad(p.gather_idx, ((0, 0), (0, extra)),
                        constant_values=p.num_edges)     # pad lane
        local = np.pad(p.local_ids, ((0, 0), (0, extra)),
                       constant_values=p.block_n)        # dead lane
        return CSCPlan(gather, local, p.num_blocks, p.block_n, p.block_e,
                       p.num_segments, p.num_edges)

    return [widen(p) for p in plans]


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "block_n", "block_e", "interpret", "op"))
def _segment_reduce_planned(data, gather_idx, local_ids, num_segments: int,
                            block_n: int, block_e: int, interpret: bool,
                            op: str = "sum"):
    # the gather is fused into the kernels (scalar-prefetched plan indices)
    # — no (nb, L_pad, D) pre-gathered tensor is materialized here anymore
    kern = segment_sum_csc if op == "sum" else segment_max_csc
    out = kern(data, gather_idx, local_ids, gather_idx.shape[0],
               block_n, block_e, interpret=interpret)
    return out[:num_segments]


def _reshape_to_2d(data):
    """(E,) / (E, D) / (E, H, D) -> ((E, prod(rest)), trailing_shape)."""
    trailing = data.shape[1:]
    return data.reshape(data.shape[0], -1), trailing


def segment_sum_op(data: jax.Array, plan: CSCPlan,
                   interpret: bool = True) -> jax.Array:
    """data (E,)/(E, D)/(E, H, D) float -> (num_segments, ...trailing), via
    the Pallas CSC kernel (multi-head messages fold into the lane axis)."""
    assert data.shape[0] == plan.num_edges
    flat, trailing = _reshape_to_2d(data)
    out = _segment_reduce_planned(
        flat, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_segments, plan.block_n, plan.block_e, interpret, "sum")
    return out.reshape((plan.num_segments,) + trailing)


def segment_max_op(data: jax.Array, plan: CSCPlan,
                   interpret: bool = True) -> jax.Array:
    """Masked segment max; empty segments come back as NEG (callers clamp,
    matching the -inf identity of ``jax.ops.segment_max``)."""
    assert data.shape[0] == plan.num_edges
    flat, trailing = _reshape_to_2d(data)
    out = _segment_reduce_planned(
        flat, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_segments, plan.block_n, plan.block_e, interpret, "max")
    return out.reshape((plan.num_segments,) + trailing)


def jaxpr_avals(closed_jaxpr):
    """Yield the output aval of every equation, recursing into sub-jaxprs
    (pjit bodies, custom_vjp calls, scans ...).

    Verification hook for the fused-gather contract: the bench and the
    kernel tests walk the csc path's jaxpr and assert that no equation
    materializes a ``(nb, L_pad, D)`` pre-gathered message tensor.
    """
    import jax.core as jcore
    stack = [closed_jaxpr.jaxpr]
    while stack:
        jaxpr = stack.pop()
        for eqn in jaxpr.eqns:
            for var in eqn.outvars:
                yield var.aval
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (tuple, list))
                            else (val,)):
                    if isinstance(sub, jcore.ClosedJaxpr):
                        stack.append(sub.jaxpr)
                    elif isinstance(sub, jcore.Jaxpr):
                        stack.append(sub)


def assert_pregather_free(closed_jaxpr, plan: CSCPlan):
    """Assert the traced computation never allocates a tensor shaped like
    the pre-gathered (nb, L_pad, ...) message layout the fused kernels
    eliminated — including the 2-D *float* (nb, L_pad) layout the old
    edge-softmax path used for gathered logits. The integer 2-D plan
    index arrays (gather_idx/local_ids) are expected and allowed."""
    nb, l_pad = plan.gather_idx.shape
    for aval in jaxpr_avals(closed_jaxpr):
        shape = tuple(getattr(aval, "shape", ()))
        if len(shape) < 2 or shape[:2] != (nb, l_pad):
            continue
        pregather = len(shape) >= 3 or jnp.issubdtype(
            getattr(aval, "dtype", jnp.int32), jnp.floating)
        assert not pregather, (
            f"pre-gathered message tensor {shape} found in jaxpr "
            f"(plan: nb={nb}, L_pad={l_pad})")


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_op(r, k, v, w, u, chunk: int = 64, interpret: bool = True):
    """Chunked WKV6; pads T up to a chunk multiple and slices back."""
    B, T, H, K = r.shape
    pad = (-T) % chunk
    if pad:
        zk = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zk(r), zk(k), zk(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    out = _wkv6_kernel(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :T]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, causal: bool = True, sliding_window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True):
    """GQA-aware wrapper: repeats kv heads to q heads, pads T to blocks."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq = min(block_q, T)
    bk = min(block_k, T)
    # after clamping, round the larger block down to a multiple of the
    # smaller: then max(bq, bk) is a common multiple of both (the
    # kernel's divisibility contract) and padding stays under one block
    # (an lcm of coprime-ish clamped blocks could inflate T several-fold)
    if bq >= bk:
        bq = max(bk, bq // bk * bk)
    else:
        bk = max(bq, bk // bq * bq)
    pad = (-T) % max(bq, bk)
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zp(q), zp(k), zp(v)
    # seq_len=T (the *unpadded* length) so the kernel masks the padded
    # keys — without it, non-causal attention leaks zero-logit pad keys
    # into the softmax denominator
    out = _flash_kernel(q, k, v, causal=causal,
                        sliding_window=sliding_window,
                        block_q=bq, block_k=bk, seq_len=T,
                        interpret=interpret)
    return out[:, :T]


# ---------------------------------------------------------------------------
# edge softmax (GAT aggregation)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "block_n", "block_e", "interpret"))
def _edge_softmax_planned(logits, values, gather_idx, local_ids,
                          num_segments: int, block_n: int, block_e: int,
                          interpret: bool):
    from repro.kernels.edge_softmax import edge_softmax_csc
    # raw (E, H) / (E, H, D) operands go straight to the fused-gather
    # kernel; heads run on the kernel grid in a single launch
    out = edge_softmax_csc(logits, values, gather_idx, local_ids,
                           gather_idx.shape[0], block_n, block_e,
                           interpret=interpret)
    return out[:num_segments]


def edge_softmax_op(logits: jax.Array, values: jax.Array, plan: CSCPlan,
                    interpret: bool = True) -> jax.Array:
    """Fused GAT aggregation: softmax-weighted neighbor sums.

    Single-head: logits (E,), values (E, D) -> (num_segments, D).
    Multi-head:  logits (E, H), values (E, H, D) -> (num_segments, H, D);
    heads share the CSC plan (the gather layout depends only on the
    destination ids, not the head) and run as one kernel launch with the
    head axis on the grid.
    """
    assert logits.shape[0] == plan.num_edges
    g_idx = jnp.asarray(plan.gather_idx)
    l_ids = jnp.asarray(plan.local_ids)
    if logits.ndim == 1:
        out = _edge_softmax_planned(
            logits[:, None], values[:, None, :], g_idx, l_ids,
            plan.num_segments, plan.block_n, plan.block_e, interpret)
        return out[:, 0, :]
    assert logits.ndim == 2 and values.ndim == 3, (logits.shape,
                                                   values.shape)
    return _edge_softmax_planned(
        logits, values, g_idx, l_ids, plan.num_segments, plan.block_n,
        plan.block_e, interpret)
