"""jit'd public wrappers around the Pallas kernels (+ host-side planning).

Each op takes ``interpret=`` so the TPU kernels validate on CPU; the pure
jnp oracles live in ref.py. On this container everything runs in interpret
mode; on a real TPU pod the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr import (ContractError, JaxprContext,  # noqa: F401
                                  check_or_raise,
                                  count_segment_scatters,  # noqa: F401
                                  jaxpr_avals, jaxpr_eqns,  # noqa: F401
                                  run_rules)
from repro.kernels.backward import (edge_softmax_bwd_csc,
                                    segment_max_bwd_csc,
                                    segment_sum_bwd_csc)
from repro.kernels.segment_sum import segment_sum_csc, segment_max_csc
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel


# ---------------------------------------------------------------------------
# segment sum / max: host plan + device ops
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CSCPlan:
    """Per-graph padded edge layout for the blocked aggregation kernels.

    Built once per graph (the paper's reused CSC indexing); all views and
    batches reuse it — only the per-edge messages change between steps.
    Registered as a jax pytree (index arrays are leaves, the block geometry
    is static aux data) so plans ride along GraphBlocks and engine shards
    through ``jit`` / ``shard_map`` / ``grad``.
    """
    gather_idx: np.ndarray    # (nb, L_pad) int32 into edge axis (E = pad
    #                           lane; the fused kernels clip it and the
    #                           local_ids masking nulls its contribution)
    local_ids: np.ndarray     # (nb, L_pad) int32 in [0, BN]; BN = padding
    edge_dst: np.ndarray      # (E_pad,) int32: the plan's inverse map,
    #                           lane e = destination row of edge e (pad
    #                           lanes hold num_segments) — drives the
    #                           backward kernels' per-edge gather
    num_blocks: int
    block_n: int
    block_e: int
    num_segments: int
    num_edges: int


def _plan_flatten(p: CSCPlan):
    return ((p.gather_idx, p.local_ids, p.edge_dst),
            (p.num_blocks, p.block_n, p.block_e, p.num_segments,
             p.num_edges))


def _plan_unflatten(aux, children):
    return CSCPlan(children[0], children[1], children[2], *aux)


jax.tree_util.register_pytree_node(CSCPlan, _plan_flatten, _plan_unflatten)


def build_csc_plan(segment_ids: np.ndarray, num_segments: int,
                   block_n: int = 128, block_e: int = 256,
                   l_pad: int = 0) -> CSCPlan:
    """``l_pad`` > 0 forces the padded edge-slice length (so plans built for
    different shards of one graph stack into a single (P, nb, L) array)."""
    ids = np.asarray(segment_ids)
    E = len(ids)
    order = np.argsort(ids, kind="stable").astype(np.int64)
    sorted_ids = ids[order]
    nb = (num_segments + block_n - 1) // block_n
    starts = np.searchsorted(sorted_ids, np.arange(nb) * block_n)
    ends = np.searchsorted(sorted_ids, np.minimum((np.arange(nb) + 1)
                                                  * block_n, num_segments))
    lens = ends - starts
    l_max = int(lens.max()) if nb else 0
    l_min = max(block_e, ((l_max + block_e - 1) // block_e) * block_e)
    if l_pad:
        if l_pad < l_min or l_pad % block_e != 0:
            raise ValueError(
                f"forced l_pad={l_pad} must be a block_e={block_e} "
                f"multiple covering the widest block slice (>= {l_min})")
    else:
        l_pad = l_min
    gather = np.full((nb, l_pad), E, np.int32)          # E = pad lane
    local = np.full((nb, l_pad), block_n, np.int32)     # BN = dead row
    for b in range(nb):
        sl = order[starts[b]:ends[b]]
        gather[b, :lens[b]] = sl
        local[b, :lens[b]] = ids[sl] - b * block_n
    # the inverse map the backward kernels scalar-prefetch: lane (b, l)
    # holds edge gather[b, l] destined for row b*block_n + local[b, l],
    # so inverting the plan gives each edge its destination row. Padded
    # to a block_e multiple (pad lanes = num_segments, clip-gathered).
    e_pad = max(block_e, ((E + block_e - 1) // block_e) * block_e)
    edge_dst = np.full(e_pad, num_segments, np.int32)
    valid = local < block_n
    rows = np.arange(nb, dtype=np.int32)[:, None] * block_n + local
    edge_dst[gather[valid]] = rows[valid]
    return CSCPlan(gather, local, edge_dst, nb, block_n, block_e,
                   num_segments, E)


def build_bucket_csc_plan(dst_local: np.ndarray, n_pad: int, e_pad: int,
                          block_n: int = 128,
                          block_e: int = 256) -> CSCPlan:
    """Bucket-shape-stable plan over a compact view's local destination
    ids: every plan built for one ``(n_pad, e_pad)`` bucket has identical
    leaf shapes AND identical static geometry (``num_blocks``/``l_pad``/
    ``num_edges`` derive from the bucket, not the view), so a jitted step
    taking the plan as a pytree caches exactly one executable per bucket.

    Pad lanes carry segment id ``n_pad`` — outside every block's range, so
    pad edges join no gather block; their values are additionally nulled
    by the block's ``edge_mask`` like any padded edge."""
    e = len(dst_local)
    if e > e_pad:
        raise ValueError(
            f"{e} edges do not fit the bucket's e_pad={e_pad}")
    if e and int(dst_local.max()) >= n_pad:
        raise ValueError(
            f"destination id {int(dst_local.max())} outside the "
            f"bucket's n_pad={n_pad}")
    ids = np.full(e_pad, n_pad, np.int32)
    ids[:e] = dst_local
    # worst case all e_pad edges land in one node block: forcing l_pad to
    # that bound makes the lane-axis shape a pure function of the bucket
    l_pad = max(block_e, ((e_pad + block_e - 1) // block_e) * block_e)
    return build_csc_plan(ids, n_pad, block_n, block_e, l_pad=l_pad)


def build_csc_plans_stacked(segment_ids_rows, num_segments: int,
                            block_n: int = 128, block_e: int = 256):
    """One plan per row of ``segment_ids_rows`` (P, E), all with identical
    padded shapes — the per-shard reused plans of the distributed engine."""
    rows = [np.asarray(r) for r in segment_ids_rows]
    plans = [build_csc_plan(r, num_segments, block_n, block_e) for r in rows]
    l_pad = max(p.gather_idx.shape[1] for p in plans)

    def widen(p: CSCPlan) -> CSCPlan:
        extra = l_pad - p.gather_idx.shape[1]
        if not extra:
            return p
        gather = np.pad(p.gather_idx, ((0, 0), (0, extra)),
                        constant_values=p.num_edges)     # pad lane
        local = np.pad(p.local_ids, ((0, 0), (0, extra)),
                       constant_values=p.block_n)        # dead lane
        return CSCPlan(gather, local, p.edge_dst, p.num_blocks, p.block_n,
                       p.block_e, p.num_segments, p.num_edges)

    return [widen(p) for p in plans]


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "block_n", "block_e", "interpret", "op"))
def _segment_reduce_planned(data, gather_idx, local_ids, num_segments: int,
                            block_n: int, block_e: int, interpret: bool,
                            op: str = "sum"):
    # the gather is fused into the kernels (scalar-prefetched plan indices)
    # — no (nb, L_pad, D) pre-gathered tensor is materialized here anymore
    kern = segment_sum_csc if op == "sum" else segment_max_csc
    out = kern(data, gather_idx, local_ids, gather_idx.shape[0],
               block_n, block_e, interpret=interpret)
    return out[:num_segments]


def _reshape_to_2d(data):
    """(E,) / (E, D) / (E, H, D) -> ((E, prod(rest)), trailing_shape)."""
    trailing = data.shape[1:]
    return data.reshape(data.shape[0], -1), trailing


def segment_sum_op(data: jax.Array, plan: CSCPlan,
                   interpret: bool = True) -> jax.Array:
    """data (E,)/(E, D)/(E, H, D) float -> (num_segments, ...trailing), via
    the Pallas CSC kernel (multi-head messages fold into the lane axis)."""
    if data.shape[0] != plan.num_edges:
        raise ValueError(f"data edge axis {data.shape[0]} != plan "
                         f"num_edges {plan.num_edges}")
    flat, trailing = _reshape_to_2d(data)
    out = _segment_reduce_planned(
        flat, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_segments, plan.block_n, plan.block_e, interpret, "sum")
    return out.reshape((plan.num_segments,) + trailing)


def segment_max_op(data: jax.Array, plan: CSCPlan,
                   interpret: bool = True) -> jax.Array:
    """Masked segment max; empty segments come back as NEG (callers clamp,
    matching the -inf identity of ``jax.ops.segment_max``)."""
    if data.shape[0] != plan.num_edges:
        raise ValueError(f"data edge axis {data.shape[0]} != plan "
                         f"num_edges {plan.num_edges}")
    flat, trailing = _reshape_to_2d(data)
    out = _segment_reduce_planned(
        flat, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_segments, plan.block_n, plan.block_e, interpret, "max")
    return out.reshape((plan.num_segments,) + trailing)


# -- fused backward wrappers (the custom_vjp bodies in core/aggregate) ------


@functools.partial(jax.jit, static_argnames=("num_edges", "block_e",
                                             "interpret"))
def _segment_sum_bwd_planned(g, edge_dst, num_edges: int, block_e: int,
                             interpret: bool):
    return segment_sum_bwd_csc(g, edge_dst, num_edges, block_e,
                               interpret=interpret)


def segment_sum_bwd_op(g: jax.Array, plan: CSCPlan,
                       interpret: bool = True) -> jax.Array:
    """Backward of :func:`segment_sum_op`: g (num_segments, ...trailing)
    -> (E, ...trailing) via the plan-driven gather kernel (segment-sum is
    linear, so d_data[e] = g[dst[e]])."""
    if g.shape[0] != plan.num_segments:
        raise ValueError(f"cotangent segment axis {g.shape[0]} != plan "
                         f"num_segments {plan.num_segments}")
    flat, trailing = _reshape_to_2d(g)
    out = _segment_sum_bwd_planned(flat, jnp.asarray(plan.edge_dst),
                                   plan.num_edges, plan.block_e, interpret)
    return out.reshape((plan.num_edges,) + trailing)


@functools.partial(jax.jit, static_argnames=("num_edges", "block_e",
                                             "interpret"))
def _segment_max_bwd_planned(g, fwd_out, data, edge_dst, num_edges: int,
                             block_e: int, interpret: bool):
    return segment_max_bwd_csc(g, fwd_out, data, edge_dst, num_edges,
                               block_e, interpret=interpret)


def segment_max_bwd_op(g: jax.Array, fwd_out: jax.Array, data: jax.Array,
                       plan: CSCPlan, interpret: bool = True) -> jax.Array:
    """Backward of :func:`segment_max_op`: the gather kernel plus the
    in-kernel argmax-hit mask against the saved forward output."""
    if g.shape[0] != plan.num_segments:
        raise ValueError(f"cotangent segment axis {g.shape[0]} != plan "
                         f"num_segments {plan.num_segments}")
    if data.shape[0] != plan.num_edges:
        raise ValueError(f"data edge axis {data.shape[0]} != plan "
                         f"num_edges {plan.num_edges}")
    gf, trailing = _reshape_to_2d(g)
    ff, _ = _reshape_to_2d(fwd_out)
    df, _ = _reshape_to_2d(data)
    out = _segment_max_bwd_planned(gf, ff, df, jnp.asarray(plan.edge_dst),
                                   plan.num_edges, plan.block_e, interpret)
    return out.reshape((plan.num_edges,) + trailing)


# ---------------------------------------------------------------------------
# contract shims — the jaxpr walkers and Sum-stage asserts moved to the
# repro.analysis rule registry (version-robust jaxpr_eqns, Finding
# records, the ``python -m repro.analysis`` CI gate). These delegating
# shims keep the historical ops-level API; the assert_* helpers raise
# ContractError (an AssertionError subclass), so existing
# ``pytest.raises(AssertionError)`` callers keep passing.
# ---------------------------------------------------------------------------


def assert_pregather_free(closed_jaxpr, plan: CSCPlan):
    """Shim over the ``jaxpr.pregather`` registry rule: the traced
    computation never allocates a tensor shaped like the pre-gathered
    (nb, L_pad, ...) message layout the fused kernels eliminated —
    including the 2-D *float* (nb, L_pad) layout the old edge-softmax
    path used for gathered logits. The integer 2-D plan index arrays
    (gather_idx/local_ids) are expected and allowed."""
    check_or_raise(run_rules(JaxprContext(closed_jaxpr, plan=plan),
                             ids=["jaxpr.pregather"]))


def assert_sum_stage_fused(closed_jaxpr, plan: CSCPlan):
    """Shim over the full Sum-stage ruleset on the csc path, forward AND
    backward:

    1. ``jaxpr.pregather`` — no ``(nb, L_pad, ...)`` float tensor;
    2. ``jaxpr.segment-scatter`` — no scatter primitive whose updates
       carry the edge axis (the forward fallback's ``.at[ids].add/max``
       and the softmax recompute's segment passes);
    3. ``jaxpr.backward-gather`` — no gather primitive mapping the
       segment axis onto the edge axis outside the kernels (the old
       ``g[segment_ids]`` backward); the fused backward reads cotangents
       through the kernels' on-chip gather from the scalar-prefetched
       ``edge_dst`` plan instead.

    Apply to ``jax.value_and_grad`` jaxprs of combine-level losses: there
    the only segment-shaped traffic *is* the Sum stage, so the assertion
    is exact. (Model-level jaxprs legitimately gather/scatter the edge
    axis in NN-Gather — use :func:`count_segment_scatters` across
    backends there, plus the pre-gather walk which stays exact.)
    """
    check_or_raise(run_rules(
        JaxprContext(closed_jaxpr, plan=plan),
        ids=["jaxpr.pregather", "jaxpr.segment-scatter",
             "jaxpr.backward-gather"]))


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_op(r, k, v, w, u, chunk: int = 64, interpret: bool = True):
    """Chunked WKV6; pads T up to a chunk multiple and slices back."""
    B, T, H, K = r.shape
    pad = (-T) % chunk
    if pad:
        zk = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zk(r), zk(k), zk(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    out = _wkv6_kernel(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :T]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, causal: bool = True, sliding_window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True):
    """GQA-aware wrapper: repeats kv heads to q heads, pads T to blocks."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq = min(block_q, T)
    bk = min(block_k, T)
    # after clamping, round the larger block down to a multiple of the
    # smaller: then max(bq, bk) is a common multiple of both (the
    # kernel's divisibility contract) and padding stays under one block
    # (an lcm of coprime-ish clamped blocks could inflate T several-fold)
    if bq >= bk:
        bq = max(bk, bq // bk * bk)
    else:
        bk = max(bq, bk // bq * bq)
    pad = (-T) % max(bq, bk)
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zp(q), zp(k), zp(v)
    # seq_len=T (the *unpadded* length) so the kernel masks the padded
    # keys — without it, non-causal attention leaks zero-logit pad keys
    # into the softmax denominator
    out = _flash_kernel(q, k, v, causal=causal,
                        sliding_window=sliding_window,
                        block_q=bq, block_k=bk, seq_len=T,
                        interpret=interpret)
    return out[:, :T]


# ---------------------------------------------------------------------------
# edge softmax (GAT aggregation)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "block_n", "block_e", "interpret"))
def _edge_softmax_planned(logits, values, gather_idx, local_ids,
                          num_segments: int, block_n: int, block_e: int,
                          interpret: bool):
    from repro.kernels.edge_softmax import edge_softmax_csc
    # raw (E, H) / (E, H, D) operands go straight to the fused-gather
    # kernel; heads run on the kernel grid in a single launch. The
    # launch also yields the per-destination softmax stats (m, den) the
    # recompute-in-kernel backward rebuilds p_e from.
    out, m, den = edge_softmax_csc(logits, values, gather_idx, local_ids,
                                   gather_idx.shape[0], block_n, block_e,
                                   interpret=interpret)
    return out[:num_segments], m[:num_segments], den[:num_segments]


def _lift_single_head(logits, values):
    if logits.ndim == 1:
        return logits[:, None], values[:, None, :], True
    if logits.ndim != 2 or values.ndim != 3:
        raise ValueError(
            f"expected (E, H) logits with (E, H, D) values, got "
            f"{logits.shape} / {values.shape}")
    return logits, values, False


def edge_softmax_op(logits: jax.Array, values: jax.Array, plan: CSCPlan,
                    interpret: bool = True) -> jax.Array:
    """Fused GAT aggregation: softmax-weighted neighbor sums.

    Single-head: logits (E,), values (E, D) -> (num_segments, D).
    Multi-head:  logits (E, H), values (E, H, D) -> (num_segments, H, D);
    heads share the CSC plan (the gather layout depends only on the
    destination ids, not the head) and run as one kernel launch with the
    head axis on the grid.
    """
    out, _, _ = edge_softmax_fwd_op(logits, values, plan, interpret)
    return out


def edge_softmax_fwd_op(logits: jax.Array, values: jax.Array,
                        plan: CSCPlan, interpret: bool = True):
    """:func:`edge_softmax_op` plus the kernel's per-destination softmax
    stats: returns (out, m (num_segments, H), den (num_segments, H)) —
    the residuals the fused backward needs to rebuild p_e in-kernel."""
    if logits.shape[0] != plan.num_edges:
        raise ValueError(f"logits edge axis {logits.shape[0]} != plan "
                         f"num_edges {plan.num_edges}")
    g_idx = jnp.asarray(plan.gather_idx)
    l_ids = jnp.asarray(plan.local_ids)
    lg, vals, single = _lift_single_head(logits, values)
    out, m, den = _edge_softmax_planned(
        lg, vals, g_idx, l_ids, plan.num_segments, plan.block_n,
        plan.block_e, interpret)
    if single:
        return out[:, 0, :], m, den
    return out, m, den


@functools.partial(jax.jit, static_argnames=("num_edges", "block_e",
                                             "interpret"))
def _edge_softmax_bwd_planned(g, logits, values, m, den, og, edge_dst,
                              num_edges: int, block_e: int,
                              interpret: bool):
    return edge_softmax_bwd_csc(g, logits, values, m, den, og, edge_dst,
                                num_edges, block_e, interpret=interpret)


def edge_softmax_bwd_op(g: jax.Array, logits: jax.Array, values: jax.Array,
                        out: jax.Array, m: jax.Array, den: jax.Array,
                        plan: CSCPlan, interpret: bool = True):
    """Backward of :func:`edge_softmax_op` — the recompute-in-kernel pass.

    g / out (num_segments, H, D) cotangent and saved forward output;
    logits / values the saved forward operands; m / den the forward
    launch's softmax stats. Returns (d_logits, d_values) from one launch
    with heads on the grid; the edge probabilities are rebuilt inside the
    kernel (never an (E, H) tensor in HBM) and no reference segment pass
    runs.
    """
    if logits.shape[0] != plan.num_edges:
        raise ValueError(f"logits edge axis {logits.shape[0]} != plan "
                         f"num_edges {plan.num_edges}")
    lg, vals, single = _lift_single_head(logits, values)
    if single:
        g, out = g[:, None, :], out[:, None, :]
    # og_i = out_i . g_i: the node-proportional contraction of d_logit
    # (elementwise jnp, no segment op, no edge-axis materialization)
    og = jnp.sum(out.astype(jnp.float32) * g.astype(jnp.float32), axis=-1)
    d_logits, d_values = _edge_softmax_bwd_planned(
        g, lg, vals, m, den, og, jnp.asarray(plan.edge_dst),
        plan.num_edges, plan.block_e, interpret)
    if single:
        return d_logits[:, 0], d_values[:, 0, :]
    return d_logits, d_values
