"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_sum import NEG   # the one masking sentinel


# ---------------------------------------------------------------------------
# segment_sum
# ---------------------------------------------------------------------------


def segment_sum_ref(data, segment_ids, num_segments):
    """data (E, D), ids (E,) -> (num_segments, D)."""
    return jax.ops.segment_sum(data, segment_ids, num_segments)


# ---------------------------------------------------------------------------
# wkv6 (RWKV-6 "Finch" recurrence)
# ---------------------------------------------------------------------------


def wkv6_ref(r, k, v, w, u):
    """Sequential oracle of the WKV6 recurrence.

    r,k,w: (B, T, H, K)   v: (B, T, H, V)   u: (H, K) bonus
    state S: (B, H, K, V);  per step:
        o_t = (r_t ⊙ 1)·(S + diag(u)·k_t v_t^T)
        S  <- diag(w_t)·S + k_t v_t^T
    Returns (o (B,T,H,V), S_final).
    All math in f32.
    """
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = w.astype(jnp.float32)
    u = u.astype(jnp.float32)
    B, T, H, K = r.shape
    V = v.shape[-1]
    S0 = jnp.zeros((B, H, K, V), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0))
    S, out = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(out, 0, 1), S


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window)
# ---------------------------------------------------------------------------


def mha_ref(q, k, v, causal=True, sliding_window=0):
    """q,k,v: (B, T, H, D) -> (B, T, H, D); f32 softmax oracle."""
    B, T, H, D = q.shape
    scale = 1.0 / np.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(T)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((T, T), bool)
    if causal:
        ok &= ki <= qi
    if sliding_window:
        ok &= ki > qi - sliding_window
    s = jnp.where(ok[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


# ---------------------------------------------------------------------------
# edge softmax (GAT aggregation)
# ---------------------------------------------------------------------------


def edge_softmax_ref(logits, values, segment_ids, num_segments):
    """logits (E,), values (E, D) -> (num_segments, D)."""
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments)
    seg_max = jnp.maximum(seg_max, NEG)
    ex = jnp.exp(logits - seg_max[segment_ids])
    den = jax.ops.segment_sum(ex, segment_ids, num_segments)
    num = jax.ops.segment_sum(ex[:, None] * values, segment_ids,
                              num_segments)
    return num / jnp.maximum(den, 1e-20)[:, None]
