"""Pallas TPU kernel: blockwise (flash) attention, causal + sliding window.

Online-softmax accumulation over key blocks with running (max, denom, acc)
in VMEM scratch; key blocks wholly outside the causal/sliding-window band
are skipped. Block shapes are MXU-aligned (multiples of 128 in production;
tests sweep smaller shapes in interpret mode).

This is the serving-path hot spot for prefill_32k; the sliding-window mode
is what lets dense assigned archs run long_500k (DESIGN.md §skips).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum import NEG   # the one masking sentinel


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, sm_scale: float,
                  causal: bool, sliding_window: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # ---- band check: does this (q, k) block intersect the mask band? -------
    # (seq_len is the TRUE unpadded length: key blocks entirely past it
    # hold only padding and are skipped)
    run = k_start < seq_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if sliding_window:
        # newest key needed for oldest query: q_start - window + 1
        run_w = k_start + block_k - 1 >= q_start - sliding_window + 1
    else:
        run_w = True

    @pl.when(jnp.logical_and(jnp.asarray(run), jnp.asarray(run_w)))
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # (BQ, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (BK, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)        # (BK, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = k_pos < seq_len
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if sliding_window:
            ok = jnp.logical_and(ok, k_pos > q_pos - sliding_window)
        s = jnp.where(ok, s, NEG)

        m_prev = m_ref[...]                               # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    seq_len: int = 0, interpret: bool = False):
    """q,k,v: (B, T, H, D) (same H — apply GQA repeat outside).

    Returns (B, T, H, Dv). T must divide by the block sizes. ``seq_len``
    (0 = T) is the TRUE unpadded sequence length: when the caller padded T
    up to a block multiple, passing the original length here masks the
    padded keys out of the softmax (they carry zero logits, not -inf, and
    would otherwise inflate every non-causal denominator).
    """
    B, T, H, D = q.shape
    Dv = v.shape[-1]
    if T % block_q != 0 or T % block_k != 0:
        raise ValueError(f"padded length {T} must be a multiple of "
                         f"block_q={block_q} and block_k={block_k}")
    seq_len = seq_len or T
    if seq_len > T:
        raise ValueError(f"seq_len {seq_len} exceeds padded length {T}")
    sm_scale = 1.0 / np.sqrt(D)
    grid = (B, H, T // block_q, T // block_k)
    spec_q = pl.BlockSpec((1, block_q, 1, D), lambda b, h, q_, k_: (b, q_, h, 0))
    spec_k = pl.BlockSpec((1, block_k, 1, D), lambda b, h, q_, k_: (b, k_, h, 0))
    spec_v = pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, q_, k_: (b, k_, h, 0))
    spec_o = pl.BlockSpec((1, block_q, 1, Dv), lambda b, h, q_, k_: (b, q_, h, 0))
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          sm_scale=sm_scale, causal=causal,
                          sliding_window=sliding_window, seq_len=seq_len),
        grid=grid,
        in_specs=[spec_q, spec_k, spec_v],
        out_specs=spec_o,
        out_shape=jax.ShapeDtypeStruct((B, T, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
