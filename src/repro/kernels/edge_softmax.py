"""Pallas TPU kernel: fused edge-softmax aggregation (GAT/GAT-E Sum stage).

Computes, per destination node i:  out_i = Σ_j softmax_j(logit_{j→i}) v_{j→i}
— the attention-weighted neighbor aggregation that dominates GAT layers.
Unfused, this is 3 segment passes (max, exp-sum, weighted sum) with HBM
round-trips between them; the kernel fuses them with an **online softmax**
over edge chunks (the flash-attention trick applied to graph edges):
running (max m, denom l, accumulator acc) per destination row live in VMEM
scratch, each chunk rescales by exp(m_prev − m_new).

Same CSC-blocked layout as segment_sum.py: destinations tiled into BN-row
blocks, each owning a contiguous padded edge slice (built once per graph by
ops.build_csc_plan — the paper's reused CSC indexing). Reached from the
forward paths through the ``"csc"`` backend of :mod:`repro.core.aggregate`
(GAT/GAT-E ``softmax`` combine on a single shard); multi-head (E, H, D)
messages run one launch per head via ``ops.edge_softmax_op``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum import NEG


def _edge_softmax_kernel(ids_ref, logit_ref, val_ref, out_ref,
                         m_ref, l_ref, acc_ref, *, block_n: int):
    chunk = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(chunk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[0]                                   # (BE,) in [0, BN]
    logit = logit_ref[0]                               # (BE,)
    vals = val_ref[0]                                  # (BE, D)
    valid = ids < block_n
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1))        # (BE, BN) bool

    # chunk-local max per destination row
    masked = jnp.where(onehot, logit[:, None], NEG)
    m_cur = jnp.max(masked, axis=0)[:, None]           # (BN, 1)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                    # (BN, 1)

    safe_ids = jnp.minimum(ids, block_n - 1)
    p = jnp.exp(logit - m_new[safe_ids, 0]) * valid.astype(jnp.float32)
    oh = onehot.astype(jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jax.lax.dot_general(
        oh, p[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        oh, p[:, None] * vals.astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(chunk == nc - 1)
    def _finish():
        out_ref[...] = (acc_ref[...]
                        / jnp.maximum(l_ref[...], 1e-20)).astype(
                            out_ref.dtype)


def edge_softmax_csc(gathered_logits, gathered_vals, local_ids,
                     num_blocks: int, block_n: int, block_e: int = 256,
                     interpret: bool = False):
    """gathered_logits (nb, L_pad), gathered_vals (nb, L_pad, D),
    local_ids (nb, L_pad) -> (nb*block_n, D)."""
    nb, l_pad = gathered_logits.shape
    d = gathered_vals.shape[-1]
    assert l_pad % block_e == 0
    return pl.pallas_call(
        functools.partial(_edge_softmax_kernel, block_n=block_n),
        grid=(num_blocks, l_pad // block_e),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda b, c: (b, c)),
            pl.BlockSpec((1, block_e), lambda b, c: (b, c)),
            pl.BlockSpec((1, block_e, d), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda b, c: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_n, d),
                                       gathered_vals.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, d), jnp.float32),
        ],
        interpret=interpret,
    )(local_ids, gathered_logits, gathered_vals)
