"""Pallas TPU kernel: fused edge-softmax aggregation (GAT/GAT-E Sum stage).

Computes, per destination node i:  out_i = Σ_j softmax_j(logit_{j→i}) v_{j→i}
— the attention-weighted neighbor aggregation that dominates GAT layers.
Unfused, this is 3 segment passes (max, exp-sum, weighted sum) with HBM
round-trips between them; the kernel fuses them with an **online softmax**
over edge chunks (the flash-attention trick applied to graph edges):
running (max m, denom l, accumulator acc) per destination row live in VMEM
scratch, each chunk rescales by exp(m_prev − m_new).

Same CSC-blocked layout as segment_sum.py: destinations tiled into BN-row
blocks, each owning a contiguous padded edge slice (built once per graph by
ops.build_csc_plan — the paper's reused CSC indexing). Like the sum/max
kernels, the per-edge gather is **fused**: raw ``(E, H)`` logits and
``(E, H, D)`` values are the operands and the plan's ``gather_idx`` arrives
as a scalar-prefetch argument — no pre-gathered ``(nb, L_pad, ·)`` tensors.
The head axis is the OUTERMOST grid dimension (``(H, nb, n_chunks)``, so
each per-head value block is fetched once), making multi-head attention
**one** kernel launch: each (head, block) pair streams its edge chunks
with the chunk axis innermost, accumulating into its own (BN, D) output
tile. Reached from the forward paths through the ``"csc"``
backend of :mod:`repro.core.aggregate` (GAT/GAT-E ``softmax`` combine on a
single shard).

The launch also emits the per-destination softmax stats (running max
``m`` and denominator ``l``) as two node-proportional outputs: the
recompute-in-kernel backward (backward.py) rebuilds the edge
probabilities from them instead of re-running reference segment passes,
so no ``(E, H)`` probability tensor ever exists in HBM in either
direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum import NEG


def _edge_softmax_kernel(idx_ref, ids_ref, logit_ref, val_ref, out_ref,
                         mstat_ref, lstat_ref, m_ref, l_ref, acc_ref, *,
                         block_n: int, block_e: int):
    b = pl.program_id(1)
    chunk = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(chunk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[0]                                   # (BE,) in [0, BN]
    idx = idx_ref[b, pl.ds(chunk * block_e, block_e)]  # (BE,)
    # fused gather of this chunk's logits/values for the current head
    logit = jnp.take(logit_ref[:, 0], idx, axis=0, mode="clip")  # (BE,)
    vals = jnp.take(val_ref[:, 0, :], idx, axis=0, mode="clip")  # (BE, D)
    valid = ids < block_n
    logit = jnp.where(valid, logit, NEG)               # null pad lanes
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1))        # (BE, BN) bool

    # chunk-local max per destination row
    masked = jnp.where(onehot, logit[:, None], NEG)
    m_cur = jnp.max(masked, axis=0)[:, None]           # (BN, 1)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                    # (BN, 1)

    safe_ids = jnp.minimum(ids, block_n - 1)
    p = jnp.exp(logit - m_new[safe_ids, 0]) * valid.astype(jnp.float32)
    oh = onehot.astype(jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jax.lax.dot_general(
        oh, p[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        oh, p[:, None] * vals.astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(chunk == nc - 1)
    def _finish():
        out_ref[...] = (acc_ref[...]
                        / jnp.maximum(l_ref[...], 1e-20))[:, None, :].astype(
                            out_ref.dtype)
        # the per-destination softmax stats (running max, denominator)
        # ride out of the launch: the recompute-in-kernel backward
        # (backward.py) rebuilds p_e from them instead of re-running
        # reference segment passes — two node-proportional extra outputs
        mstat_ref[...] = m_ref[...].astype(mstat_ref.dtype)
        lstat_ref[...] = l_ref[...].astype(lstat_ref.dtype)


def edge_softmax_csc(logits, values, gather_idx, local_ids,
                     num_blocks: int, block_n: int, block_e: int = 256,
                     interpret: bool = False):
    """Fused-gather multi-head edge softmax.

    logits (E, H), values (E, H, D), gather_idx/local_ids (nb, L_pad)
    -> (out (nb*block_n, H, D), m (nb*block_n, H), l (nb*block_n, H)):
    the aggregation plus the per-destination softmax stats (running max
    and denominator) the fused backward rebuilds p_e from; one launch,
    heads on the grid.
    """
    e, h = logits.shape
    d = values.shape[-1]
    nb, l_pad = gather_idx.shape
    if nb != num_blocks or l_pad % block_e != 0:
        raise ValueError(
            f"plan shape ({nb}, {l_pad}) inconsistent with "
            f"num_blocks={num_blocks}, block_e={block_e}")
    if values.shape != (e, h, d):
        raise ValueError(f"values {values.shape} do not match logits "
                         f"{logits.shape}: expected ({e}, {h}, {d})")
    if e == 0:
        return (jnp.zeros((num_blocks * block_n, h, d), values.dtype),
                jnp.full((num_blocks * block_n, h), NEG, jnp.float32),
                jnp.zeros((num_blocks * block_n, h), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # head axis OUTERMOST so the per-head (E, 1, D) value block is
        # fetched once per head (its index map ignores b/c); chunk axis
        # innermost: each (head, block) tile accumulates its
        # online-softmax state across its edge chunks before moving on
        grid=(h, num_blocks, l_pad // block_e),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda hd, b, c, idx: (b, c)),
            pl.BlockSpec((e, 1), lambda hd, b, c, idx: (0, hd)),
            pl.BlockSpec((e, 1, d), lambda hd, b, c, idx: (0, hd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1, d), lambda hd, b, c, idx: (b, hd, 0)),
            pl.BlockSpec((block_n, 1), lambda hd, b, c, idx: (b, hd)),
            pl.BlockSpec((block_n, 1), lambda hd, b, c, idx: (b, hd)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_edge_softmax_kernel, block_n=block_n,
                          block_e=block_e),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_blocks * block_n, h, d),
                                 values.dtype),
            jax.ShapeDtypeStruct((num_blocks * block_n, h), jnp.float32),
            jax.ShapeDtypeStruct((num_blocks * block_n, h), jnp.float32),
        ],
        interpret=interpret,
    )(gather_idx, local_ids, logits, values)
