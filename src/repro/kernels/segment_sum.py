"""Pallas TPU kernel: CSC-blocked neighbor aggregation (the Sum stage).

The paper's stage breakdown (Fig. A3) shows graph convolution — dominated
by the per-edge gather + per-destination aggregation — at 76% of runtime.
On GPU this is a scatter-add; the TPU adaptation (DESIGN.md) reshapes it
into MXU work: edges are sorted by destination (the CSC order GraphTheta
already maintains, §4.1), destinations are tiled into blocks of ``BN``
rows, each destination block owns a contiguous padded slice of edges, and
the partial sum for a block is a **one-hot matmul**::

    out[BN, D] += onehot(local_dst)[BE, BN]^T @ messages[BE, D]

which runs on the systolic array instead of a serialized scatter. The edge
slice of a destination block is processed in ``BE``-sized chunks by a
sequential grid axis revisiting the same output tile (accumulation in
VMEM).

Host-side planning (``build_csc_plan`` in ops.py) computes the padded
edge gather indices once per graph — the paper's "reused CSR/CSC indexing"
(§4.2): views/batches reuse the plan, only messages change.

These kernels are wired into the forward paths through the Sum-stage
backend registry in :mod:`repro.core.aggregate`: selecting the ``"csc"``
:class:`~repro.core.aggregate.AggregationBackend` routes the combine of
both ``layer_forward_block`` and the distributed engine through
``segment_sum_csc`` / ``segment_max_csc`` / ``edge_softmax_csc`` (the
``"reference"`` backend keeps the portable jnp segment ops). A ``max``
combine (kernel below) covers max-pooling aggregators; multi-head
``(E, H, D)`` messages are handled by the wrappers in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _segment_sum_kernel(ids_ref, data_ref, out_ref, *, block_n: int):
    """One (node_block, edge_chunk) grid step.

    ids_ref:  (1, BE) int32 — local destination row in [0, BN]; BN = pad.
    data_ref: (1, BE, D) f32 — gathered edge messages for this chunk.
    out_ref:  (BN, D) f32 — destination tile (revisited across chunks).
    """
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[0]                                    # (BE,)
    data = data_ref[0]                                  # (BE, D)
    # one-hot on the MXU: (BE, BN) — padding rows (id == BN) hit no row
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1)).astype(data.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, data, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


def segment_sum_csc(gathered: jax.Array, local_ids: jax.Array,
                    num_blocks: int, block_n: int,
                    block_e: int = 256, interpret: bool = False):
    """Blocked segment-sum.

    gathered:  (num_blocks, L_pad, D) — edge messages pre-gathered into the
               per-destination-block padded layout (L_pad % block_e == 0).
    local_ids: (num_blocks, L_pad) int32 — destination row within block,
               block_n for padding lanes.
    returns    (num_blocks * block_n, D).
    """
    nb, l_pad, d = gathered.shape
    assert nb == num_blocks and l_pad % block_e == 0
    n_chunks = l_pad // block_e
    out = pl.pallas_call(
        functools.partial(_segment_sum_kernel, block_n=block_n),
        grid=(num_blocks, n_chunks),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda b, c: (b, c)),
            pl.BlockSpec((1, block_e, d), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda b, c: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_n, d),
                                       gathered.dtype),
        interpret=interpret,
    )(local_ids, gathered)
    return out


def _segment_max_kernel(ids_ref, data_ref, out_ref, *, block_n: int):
    """Masked per-destination max over one (node_block, edge_chunk) step.

    No one-hot matmul here — max has no MXU form — so the chunk expands to
    a (BE, BN, D) masked candidate tensor on the VPU. Padding lanes
    (id == BN) match no destination row and empty rows stay at NEG.
    """
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEG)

    ids = ids_ref[0]                                    # (BE,)
    data = data_ref[0]                                  # (BE, D)
    onehot = ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1)          # (BE, BN) bool
    cand = jnp.where(onehot[:, :, None], data[:, None, :],
                     jnp.asarray(NEG, data.dtype))      # (BE, BN, D)
    out_ref[...] = jnp.maximum(out_ref[...], jnp.max(cand, axis=0))


def segment_max_csc(gathered: jax.Array, local_ids: jax.Array,
                    num_blocks: int, block_n: int,
                    block_e: int = 256, interpret: bool = False):
    """Blocked segment-max; same layout contract as :func:`segment_sum_csc`.

    Empty destination rows come back as ``NEG`` (callers clamp). Note the
    (BE, BN, D) candidate tensor: for TPU VMEM keep block_e * block_n * D
    modest (e.g. 256·128 rows at D<=64) or shrink ``block_e``.
    """
    nb, l_pad, d = gathered.shape
    assert nb == num_blocks and l_pad % block_e == 0
    n_chunks = l_pad // block_e
    out = pl.pallas_call(
        functools.partial(_segment_max_kernel, block_n=block_n),
        grid=(num_blocks, n_chunks),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda b, c: (b, c)),
            pl.BlockSpec((1, block_e, d), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda b, c: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_n, d),
                                       gathered.dtype),
        interpret=interpret,
    )(local_ids, gathered)
    return out
