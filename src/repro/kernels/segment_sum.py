"""Pallas TPU kernel: CSC-blocked neighbor aggregation (the Sum stage).

The paper's stage breakdown (Fig. A3) shows graph convolution — dominated
by the per-edge gather + per-destination aggregation — at 76% of runtime.
On GPU this is a scatter-add; the TPU adaptation (DESIGN.md) reshapes it
into MXU work: edges are sorted by destination (the CSC order GraphTheta
already maintains, §4.1), destinations are tiled into blocks of ``BN``
rows, each destination block owns a contiguous padded slice of edges, and
the partial sum for a block is a **one-hot matmul**::

    out[BN, D] += onehot(local_dst)[BE, BN]^T @ messages[BE, D]

which runs on the systolic array instead of a serialized scatter. The edge
slice of a destination block is processed in ``BE``-sized chunks by a
sequential grid axis revisiting the same output tile (accumulation in
VMEM).

Fused gather
------------
The per-edge gather happens **inside** the kernel: the raw ``(E, D)`` edge
messages are the kernel operand and the plan's ``gather_idx`` rides in as a
``PrefetchScalarGridSpec`` scalar-prefetch argument. Each grid step reads
its ``BE`` indices and gathers the matching message rows on-chip — there is
no ``(nb, L_pad, D)`` pre-gathered tensor in HBM anymore (that tensor
duplicated every message byte and dominated Sum-stage memory traffic; see
``benchmarks/kernels_bench.py aggregate`` for the bytes-moved comparison).
Padding lanes (``local_id == BN``) contribute nothing — the one-hot matmul
and the masked max both null them — so no sentinel pad row is appended to
the messages either; their (clipped) gather target is irrelevant.

Block geometry & VMEM budget
----------------------------
Per grid step the kernel holds, in f32:

=====================  =======================  =========================
buffer                 shape                    bytes (defaults)
=====================  =======================  =========================
messages (resident)    (E, D)                   4·E·D   (fetched once; the
                                                constant index map keeps
                                                the block in VMEM across
                                                grid steps)
gather indices (SMEM)  (nb, L_pad) int32        4·nb·L_pad
local ids              (1, BE)                  4·BE
one-hot (sum)          (BE, BN)                 4·BE·BN      (256·128 → 128 KiB)
candidates (max)       (BE, BN, BD)             4·BE·BN·BD   (256·128·64 → 8 MiB)
output tile            (BN, D) / (BN, BD)       4·BN·D
=====================  =======================  =========================

The max kernel's candidate expansion is the binding constraint: with the
default ``block_e=256, block_n=128`` the feature tile ``BD`` is capped at
**64** to stay within half of a ~16 MiB VMEM core; wider features are
handled by the D-tiling grid axis (``_pick_block_d`` chooses the largest
divisor of D within the cap), so D is no longer limited by VMEM. The
message residency 4·E·D is the other budget line — for edge counts beyond
VMEM on real hardware the messages move to ``pltpu.ANY``/HBM with
per-chunk DMA (same kernel structure); interpret mode (this container)
validates the arithmetic either way.

Backward geometry (kernels in backward.py)
------------------------------------------
The backward kernels run over the **edge axis** (grid ``(d_tiles,
E_pad/BE)``; softmax: ``(H, E_pad/BE)``) with the node-indexed arrays
resident, per grid step in f32:

=====================  =======================  =========================
buffer                 shape                    bytes (defaults)
=====================  =======================  =========================
edge_dst (SMEM)        (E_pad,) int32           4·E_pad
cotangent g (resident) (N, BD) / (N, 1, D)      4·N·BD
fwd out / stats        (N, BD) (max) or         4·N·BD / 3·4·N
                       3×(N, 1) (softmax)
edge tiles             (BE, BD) in + out        2·4·BE·BD
=====================  =======================  =========================

No ``(BE, BN, BD)`` candidate expansion exists in any backward kernel
(the gather direction needs no one-hot), so the backward d-tile cap is
looser (**128**) than the forward max kernel's 64; the binding line is
the 4·N·D cotangent residency, which moves to HBM + per-chunk DMA at the
same threshold as the forward's message residency. The softmax backward
additionally keeps the per-edge probability entirely in registers/VMEM —
it is rebuilt per tile from the saved logits and the forward-emitted
(m, den) stats, never written to HBM.

Host-side planning (``build_csc_plan`` in ops.py) computes the padded
edge-slice layout once per graph — the paper's "reused CSR/CSC indexing"
(§4.2): views/batches reuse the plan, only messages change.

The budget arithmetic above is not only documentation: the static
analyzer in :mod:`repro.analysis.vmem` recomputes per-``pallas_call``
block residency + peak temporary bytes from a traced jaxpr and flags any
kernel whose footprint exceeds the budget (``vmem.budget`` rule; CLI
``python -m repro.analysis --strict``). Changing a block geometry here
without re-checking the tables trips that gate in CI.

These kernels are wired into the forward paths through the Sum-stage
backend registry in :mod:`repro.core.aggregate`: selecting the ``"csc"``
:class:`~repro.core.aggregate.AggregationBackend` routes the combine of
both ``layer_forward_block`` and the distributed engine through
``segment_sum_csc`` / ``segment_max_csc`` / ``edge_softmax_csc`` (the
``"reference"`` backend keeps the portable jnp segment ops). Multi-head
``(E, H, D)`` messages fold into the lane axis for sum/max (ops.py
wrappers); the edge-softmax kernel carries the head axis in its grid.

``NEG`` below is *the* masking sentinel of the repo — kernels, reference
oracles, and attention masks all import it from here so empty-segment
thresholds (``> NEG / 2`` in aggregate.py) can never drift out of sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _pick_block_d(d: int, cap: int = 64) -> int:
    """Largest divisor of ``d`` within the VMEM cap (see module docstring).

    Falls back to 1 only for pathological prime widths; the common power-
    of-two feature dims tile exactly.
    """
    if d <= cap:
        return d
    for bd in range(cap, 0, -1):
        if d % bd == 0:
            return bd
    return 1


def _segment_sum_kernel(idx_ref, ids_ref, msg_ref, out_ref, *,
                        block_n: int, block_e: int):
    """One (node_block, edge_chunk) grid step, gather fused in.

    idx_ref: (nb, L_pad) int32 scalar-prefetch — rows of ``msg`` feeding
             each lane (pad lanes point past E; clipped, then nulled by
             the one-hot).
    ids_ref: (1, BE) int32 — local destination row in [0, BN]; BN = pad.
    msg_ref: (E, D) f32 — raw edge messages (constant block, VMEM
             resident across the whole grid).
    out_ref: (BN, D) f32 — destination tile (revisited across chunks).
    """
    b = pl.program_id(0)
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[0]                                    # (BE,)
    idx = idx_ref[b, pl.ds(chunk * block_e, block_e)]   # (BE,)
    data = jnp.take(msg_ref[...], idx, axis=0, mode="clip")  # fused gather
    # one-hot on the MXU: (BE, BN) — padding lanes (id == BN) hit no row
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1)).astype(data.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, data, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


def segment_sum_csc(data: jax.Array, gather_idx: jax.Array,
                    local_ids: jax.Array, num_blocks: int, block_n: int,
                    block_e: int = 256, interpret: bool = False):
    """Blocked segment-sum with the per-edge gather fused into the kernel.

    data:       (E, D) raw edge messages (no pre-gathered layout).
    gather_idx: (num_blocks, L_pad) int32 plan indices into the edge axis
                (pad lanes hold E; L_pad % block_e == 0).
    local_ids:  (num_blocks, L_pad) int32 — destination row within block,
                block_n for padding lanes.
    returns     (num_blocks * block_n, D).
    """
    e, d = data.shape
    nb, l_pad = gather_idx.shape
    if nb != num_blocks or l_pad % block_e != 0:
        raise ValueError(
            f"plan shape ({nb}, {l_pad}) inconsistent with "
            f"num_blocks={num_blocks}, block_e={block_e}")
    if e == 0:
        return jnp.zeros((num_blocks * block_n, d), data.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_blocks, l_pad // block_e),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda b, c, idx: (b, c)),
            pl.BlockSpec((e, d), lambda b, c, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda b, c, idx: (b, 0)),
    )
    return pl.pallas_call(
        functools.partial(_segment_sum_kernel, block_n=block_n,
                          block_e=block_e),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_n, d),
                                       data.dtype),
        interpret=interpret,
    )(gather_idx, local_ids, data)


def _segment_max_kernel(idx_ref, ids_ref, msg_ref, out_ref, *,
                        block_n: int, block_e: int):
    """Masked per-destination max over one (node_block, d_tile, edge_chunk)
    step, gather fused in.

    No one-hot matmul here — max has no MXU form — so the chunk expands to
    a (BE, BN, BD) masked candidate tensor on the VPU; the d_tile grid axis
    keeps BD within the VMEM cap (module docstring). Padding lanes
    (id == BN) match no destination row and empty rows stay at NEG.
    """
    b = pl.program_id(1)
    chunk = pl.program_id(2)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEG)

    ids = ids_ref[0]                                    # (BE,)
    idx = idx_ref[b, pl.ds(chunk * block_e, block_e)]   # (BE,)
    data = jnp.take(msg_ref[...], idx, axis=0, mode="clip")  # (BE, BD)
    onehot = ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1)          # (BE, BN) bool
    cand = jnp.where(onehot[:, :, None], data[:, None, :],
                     jnp.asarray(NEG, data.dtype))      # (BE, BN, BD)
    out_ref[...] = jnp.maximum(out_ref[...], jnp.max(cand, axis=0))


def segment_max_csc(data: jax.Array, gather_idx: jax.Array,
                    local_ids: jax.Array, num_blocks: int, block_n: int,
                    block_e: int = 256, block_d: int = 0,
                    interpret: bool = False):
    """Blocked segment-max; same fused-gather contract as
    :func:`segment_sum_csc`, plus a feature-tiling grid axis.

    ``block_d`` (0 = auto) tiles the feature axis so the (BE, BN, BD)
    candidate tensor fits VMEM at any D — the auto pick is the largest
    divisor of D within the documented cap. Empty destination rows come
    back as ``NEG`` (callers clamp).
    """
    e, d = data.shape
    nb, l_pad = gather_idx.shape
    if nb != num_blocks or l_pad % block_e != 0:
        raise ValueError(
            f"plan shape ({nb}, {l_pad}) inconsistent with "
            f"num_blocks={num_blocks}, block_e={block_e}")
    if e == 0:
        return jnp.full((num_blocks * block_n, d), NEG, data.dtype)
    bd = block_d or _pick_block_d(d)
    if d % bd != 0:
        raise ValueError(f"feature dim {d} not divisible by block_d={bd}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # d-tiles OUTERMOST so the (E, BD) message block is fetched once
        # per tile (its index map ignores b/c); chunks innermost so each
        # (dt, b) output tile accumulates in VMEM
        grid=(d // bd, num_blocks, l_pad // block_e),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda dt, b, c, idx: (b, c)),
            pl.BlockSpec((e, bd), lambda dt, b, c, idx: (0, dt)),
        ],
        out_specs=pl.BlockSpec((block_n, bd),
                               lambda dt, b, c, idx: (b, dt)),
    )
    return pl.pallas_call(
        functools.partial(_segment_max_kernel, block_n=block_n,
                          block_e=block_e),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_n, d),
                                       data.dtype),
        interpret=interpret,
    )(gather_idx, local_ids, data)
