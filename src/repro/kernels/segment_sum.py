"""Pallas TPU kernel: CSC-blocked neighbor aggregation (the Sum stage).

The paper's stage breakdown (Fig. A3) shows graph convolution — dominated
by the per-edge gather + per-destination aggregation — at 76% of runtime.
On GPU this is a scatter-add; the TPU adaptation (DESIGN.md) reshapes it
into MXU work: edges are sorted by destination (the CSC order GraphTheta
already maintains, §4.1), destinations are tiled into blocks of ``BN``
rows, each destination block owns a contiguous padded slice of edges, and
the partial sum for a block is a **one-hot matmul**::

    out[BN, D] += onehot(local_dst)[BE, BN]^T @ messages[BE, D]

which runs on the systolic array instead of a serialized scatter. The edge
slice of a destination block is processed in ``BE``-sized chunks by a
sequential grid axis revisiting the same output tile (accumulation in
VMEM).

Host-side planning (``build_csc_plan`` in ops.py) computes the padded
edge gather indices once per graph — the paper's "reused CSR/CSC indexing"
(§4.2): views/batches reuse the plan, only messages change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_sum_kernel(ids_ref, data_ref, out_ref, *, block_n: int):
    """One (node_block, edge_chunk) grid step.

    ids_ref:  (1, BE) int32 — local destination row in [0, BN]; BN = pad.
    data_ref: (1, BE, D) f32 — gathered edge messages for this chunk.
    out_ref:  (BN, D) f32 — destination tile (revisited across chunks).
    """
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[0]                                    # (BE,)
    data = data_ref[0]                                  # (BE, D)
    # one-hot on the MXU: (BE, BN) — padding rows (id == BN) hit no row
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_n), 1)).astype(data.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, data, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


def segment_sum_csc(gathered: jax.Array, local_ids: jax.Array,
                    num_blocks: int, block_n: int,
                    block_e: int = 256, interpret: bool = False):
    """Blocked segment-sum.

    gathered:  (num_blocks, L_pad, D) — edge messages pre-gathered into the
               per-destination-block padded layout (L_pad % block_e == 0).
    local_ids: (num_blocks, L_pad) int32 — destination row within block,
               block_n for padding lanes.
    returns    (num_blocks * block_n, D).
    """
    nb, l_pad, d = gathered.shape
    assert nb == num_blocks and l_pad % block_e == 0
    n_chunks = l_pad // block_e
    out = pl.pallas_call(
        functools.partial(_segment_sum_kernel, block_n=block_n),
        grid=(num_blocks, n_chunks),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda b, c: (b, c)),
            pl.BlockSpec((1, block_e, d), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda b, c: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_n, d),
                                       gathered.dtype),
        interpret=interpret,
    )(local_ids, gathered)
    return out
