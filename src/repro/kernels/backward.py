"""Pallas TPU kernels: fused Sum-stage backward passes.

The forward CSC kernels (segment_sum.py / edge_softmax.py) aggregate raw
``(E, ...)`` edge messages into per-destination rows with the per-edge
gather fused on-chip. Their cotangents flow the other way — every edge
needs a value read from its destination row — and until this module the
``custom_vjp`` backwards were reference math: ``g[segment_ids]`` jnp
gathers plus a full ``jax.ops.segment_*`` softmax recompute, i.e. under
``jax.grad`` roughly two thirds of a train step's memory traffic bypassed
the planned layout entirely (the "message bombing" the forward
eliminated). These kernels close that gap: the whole train step stays
pre-gather-free (see ``ops.assert_sum_stage_fused``).

Layout
------
Backward is a *scatter-free* pass when organized over the **edge axis**:
``d_data[e] = f(g[dst[e]])`` touches each output row exactly once. The
grid therefore tiles the (padded) edge axis in ``block_e`` chunks; the
node-indexed arrays (cotangent ``g``, saved forward output, softmax
stats) stay resident as constant blocks, and the per-edge destination
comes from the plan's **inverse map** ``edge_dst`` — built host-side in
``build_csc_plan`` by inverting ``gather_idx``/``local_ids`` (lane
``(b, l)`` holds edge ``gather_idx[b, l]`` destined for row
``b*block_n + local_ids[b, l]``) and scalar-prefetched like the forward
plan indices. Pad lanes carry ``num_segments`` (clip-gathered; the
outputs are allocated at the true edge count, so the final partial
block is an ordinary masked boundary block — no pad copies, no slices).

Three kernels:

- :func:`segment_sum_bwd_csc` — the linear backward, a pure plan-driven
  gather: ``d_data[e] = g[dst[e]]``; d-tiled.
- :func:`segment_max_bwd_csc` — the same gather plus an in-kernel
  argmax-hit mask against the saved forward output (ties share the
  cotangent, matching ``jax.ops.segment_max``).
- :func:`edge_softmax_bwd_csc` — recompute-in-kernel: rebuilds the edge
  probability ``p_e = exp(logit_e - m_i) / den_i`` inside each edge block
  from the saved logits and the forward kernel's per-destination softmax
  stats (``m``/``den`` ride out of the fused forward launch as two tiny
  node-proportional outputs). No ``(E, H)`` probability tensor is ever
  materialized in HBM and no reference ``segment_max``/``segment_sum``
  recompute runs; ``d_logits`` and ``d_values`` come out of **one**
  launch with heads on the grid, mirroring the forward.

VMEM geometry mirrors the forward budget (documented in
segment_sum.py): per grid step the gather kernels hold the resident
``(N, BD)`` cotangent block plus a ``(BE, BD)`` output tile; the softmax
backward holds per-head residents ``(N, D)`` cotangent + four ``(N,)``
stat columns and ``(BE, D)`` tiles — no ``(BE, BN, BD)`` candidate
expansion anywhere, so the d-tile cap is looser (128) than the forward
max kernel's (64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum import NEG, _pick_block_d


# ---------------------------------------------------------------------------
# segment-sum backward: plan-driven per-edge gather
# ---------------------------------------------------------------------------


def _gather_bwd_kernel(dst_ref, g_ref, out_ref, *, block_e: int):
    """One (d_tile, edge_chunk) grid step of ``d_data[e] = g[dst[e]]``.

    dst_ref: (E_pad,) int32 scalar-prefetch — the plan's inverse map
             (pad lanes hold num_segments; clipped, masked by the
             boundary write).
    g_ref:   (N, BD) f32 resident cotangent block.
    out_ref: (BE, BD) f32 edge tile of the message cotangent.
    """
    c = pl.program_id(1)
    idx = dst_ref[pl.ds(c * block_e, block_e)]           # (BE,)
    out_ref[...] = jnp.take(g_ref[...], idx, axis=0, mode="clip")


def segment_sum_bwd_csc(g: jax.Array, edge_dst: jax.Array, num_edges: int,
                        block_e: int = 256, block_d: int = 0,
                        interpret: bool = False):
    """Backward of the fused segment-sum: gather the output cotangent onto
    the edge axis through the plan's inverse map.

    g:        (N, D) cotangent of the (sliced) kernel output.
    edge_dst: (E_pad,) int32, E_pad % block_e == 0; lane e holds dst[e],
              pad lanes hold N (clip-gathered, boundary-masked).
    returns   (num_edges, D).
    """
    n, d = g.shape
    e_pad = edge_dst.shape[0]
    if e_pad % block_e != 0 or e_pad < num_edges:
        raise ValueError(
            f"edge_dst pad {e_pad} must be a block_e={block_e} multiple "
            f"covering num_edges={num_edges}")
    if num_edges == 0:
        return jnp.zeros((0, d), g.dtype)
    bd = block_d or _pick_block_d(d, cap=128)
    if d % bd != 0:
        raise ValueError(f"feature dim {d} not divisible by block_d={bd}")
    # the output is allocated at the true edge count: the final partial
    # block is a masked boundary write (no (E_pad, d) intermediate, no
    # slice, and — as every lane is independent — no pad copies of the
    # operands either)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d // bd, e_pad // block_e),
        in_specs=[pl.BlockSpec((n, bd), lambda dt, c, dst: (0, dt))],
        out_specs=pl.BlockSpec((block_e, bd), lambda dt, c, dst: (c, dt)),
    )
    return pl.pallas_call(
        functools.partial(_gather_bwd_kernel, block_e=block_e),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_edges, d), g.dtype),
        interpret=interpret,
    )(edge_dst, g)


# ---------------------------------------------------------------------------
# segment-max backward: the gather + an in-kernel argmax-hit mask
# ---------------------------------------------------------------------------


def _gather_max_bwd_kernel(dst_ref, g_ref, fwd_ref, data_ref, out_ref, *,
                           block_e: int):
    """Gather backward masked by ``data == forward_max`` (subgradient:
    ties share the cotangent, matching ``jax.ops.segment_max``)."""
    c = pl.program_id(1)
    idx = dst_ref[pl.ds(c * block_e, block_e)]           # (BE,)
    ge = jnp.take(g_ref[...], idx, axis=0, mode="clip")
    fe = jnp.take(fwd_ref[...], idx, axis=0, mode="clip")
    out_ref[...] = ge * (data_ref[...] == fe).astype(ge.dtype)


def segment_max_bwd_csc(g: jax.Array, fwd_out: jax.Array, data: jax.Array,
                        edge_dst: jax.Array, num_edges: int,
                        block_e: int = 256, block_d: int = 0,
                        interpret: bool = False):
    """Backward of the fused segment-max.

    g / fwd_out: (N, D) cotangent and saved forward output.
    data:        (E, D) the forward's edge operand (for the hit mask).
    returns      (num_edges, D).
    """
    n, d = g.shape
    e_pad = edge_dst.shape[0]
    if fwd_out.shape != (n, d) or data.shape != (num_edges, d):
        raise ValueError(
            f"fwd_out {fwd_out.shape} / data {data.shape} do not match "
            f"the expected ({n}, {d}) / ({num_edges}, {d})")
    if e_pad % block_e != 0 or e_pad < num_edges:
        raise ValueError(
            f"edge_dst pad {e_pad} must be a block_e={block_e} multiple "
            f"covering num_edges={num_edges}")
    if num_edges == 0:
        return jnp.zeros((0, d), g.dtype)
    bd = block_d or _pick_block_d(d, cap=128)
    if d % bd != 0:
        raise ValueError(f"feature dim {d} not divisible by block_d={bd}")
    # edge arrays stay at their true length: the final partial block is
    # a boundary block (masked write, padded read) — no pad copy of the
    # saved forward operand per backward call
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d // bd, e_pad // block_e),
        in_specs=[
            pl.BlockSpec((n, bd), lambda dt, c, dst: (0, dt)),
            pl.BlockSpec((n, bd), lambda dt, c, dst: (0, dt)),
            pl.BlockSpec((block_e, bd), lambda dt, c, dst: (c, dt)),
        ],
        out_specs=pl.BlockSpec((block_e, bd), lambda dt, c, dst: (c, dt)),
    )
    return pl.pallas_call(
        functools.partial(_gather_max_bwd_kernel, block_e=block_e),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_edges, d), g.dtype),
        interpret=interpret,
    )(edge_dst, g, fwd_out, data)


# ---------------------------------------------------------------------------
# edge-softmax backward: recompute p_e in-kernel, one launch, heads on grid
# ---------------------------------------------------------------------------


def _edge_softmax_bwd_kernel(dst_ref, logit_ref, val_ref, g_ref, m_ref,
                             den_ref, og_ref, dlogit_ref, dval_ref, *,
                             block_e: int):
    """One (head, edge_chunk) grid step.

    With p_e = softmax_e(logit) over destination i's in-edges:
        d_value_e = p_e * g_i
        d_logit_e = p_e * (v_e . g_i  -  out_i . g_i)
    p_e is rebuilt here from the saved logits and the forward's softmax
    stats (running max m_i, denominator den_i) — never materialized as an
    (E, H) tensor; ``og = out . g`` is the node-proportional contraction
    precomputed by the wrapper.
    """
    c = pl.program_id(1)
    idx = dst_ref[pl.ds(c * block_e, block_e)]           # (BE,)
    logit = logit_ref[:, 0]                              # (BE,)
    m_e = jnp.take(m_ref[:, 0], idx, mode="clip")
    den_e = jnp.take(den_ref[:, 0], idx, mode="clip")
    # recompute-in-kernel; masked edges (logit == NEG) and pad lanes get
    # p = 0 exactly, matching the reference math's masked exponentials
    p = jnp.exp(logit - m_e) / jnp.maximum(den_e, 1e-20)
    p = jnp.where(logit > NEG / 2, p, 0.0)
    gi = jnp.take(g_ref[:, 0, :], idx, axis=0, mode="clip")   # (BE, D)
    dval_ref[...] = (p[:, None] * gi)[:, None, :].astype(dval_ref.dtype)
    vg = jnp.sum(val_ref[:, 0, :] * gi, axis=-1)              # (BE,)
    oge = jnp.take(og_ref[:, 0], idx, mode="clip")
    dlogit_ref[...] = (p * (vg - oge))[:, None].astype(dlogit_ref.dtype)


def edge_softmax_bwd_csc(g: jax.Array, logits: jax.Array, values: jax.Array,
                         m: jax.Array, den: jax.Array, og: jax.Array,
                         edge_dst: jax.Array, num_edges: int,
                         block_e: int = 256, interpret: bool = False):
    """Backward of the fused edge-softmax aggregation — one launch, heads
    on the grid (mirroring the forward).

    g (N, H, D) output cotangent; logits (E, H) / values (E, H, D) saved
    forward operands; m / den (N, H) the forward kernel's softmax stats;
    og (N, H) = sum(out * g, -1). Returns (d_logits (E, H),
    d_values (E, H, D)).
    """
    n, h, d = g.shape
    e_pad = edge_dst.shape[0]
    if logits.shape != (num_edges, h):
        raise ValueError(f"logits {logits.shape} do not match the "
                         f"expected ({num_edges}, {h})")
    if values.shape != (num_edges, h, d):
        raise ValueError(f"values {values.shape} do not match the "
                         f"expected ({num_edges}, {h}, {d})")
    if m.shape != (n, h) or den.shape != (n, h) or og.shape != (n, h):
        raise ValueError(
            f"softmax stats m {m.shape} / den {den.shape} / og {og.shape}"
            f" do not match the expected ({n}, {h})")
    if e_pad % block_e != 0 or e_pad < num_edges:
        raise ValueError(
            f"edge_dst pad {e_pad} must be a block_e={block_e} multiple "
            f"covering num_edges={num_edges}")
    if num_edges == 0:
        return (jnp.zeros((0, h), logits.dtype),
                jnp.zeros((0, h, d), values.dtype))
    # saved edge operands stay at their true length — the final partial
    # block is a boundary block, so no per-call pad copies of the (E, H)
    # logits / (E, H, D) values residuals
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # head axis OUTERMOST (as in the forward): the per-head residents
        # (cotangent block, stat columns) are fetched once per head
        grid=(h, e_pad // block_e),
        in_specs=[
            pl.BlockSpec((block_e, 1), lambda hd, c, dst: (c, hd)),
            pl.BlockSpec((block_e, 1, d), lambda hd, c, dst: (c, hd, 0)),
            pl.BlockSpec((n, 1, d), lambda hd, c, dst: (0, hd, 0)),
            pl.BlockSpec((n, 1), lambda hd, c, dst: (0, hd)),
            pl.BlockSpec((n, 1), lambda hd, c, dst: (0, hd)),
            pl.BlockSpec((n, 1), lambda hd, c, dst: (0, hd)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, 1), lambda hd, c, dst: (c, hd)),
            pl.BlockSpec((block_e, 1, d), lambda hd, c, dst: (c, hd, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_edge_softmax_bwd_kernel, block_e=block_e),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_edges, h), logits.dtype),
            jax.ShapeDtypeStruct((num_edges, h, d), values.dtype),
        ],
        interpret=interpret,
    )(edge_dst, logits, values, g, m, den, og)
