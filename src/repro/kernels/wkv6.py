"""Pallas TPU kernel: chunked WKV6 recurrence (RWKV-6 "Finch").

The recurrence  S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ,  o_t = r_tᵀ(S_{t-1} +
diag(u)·k_t v_tᵀ)  is sequential per step on GPU implementations; the TPU
adaptation processes the sequence in chunks of ``C`` tokens so that the
dominant work is three MXU matmuls per chunk:

  inter-chunk:  o += (r ⊙ e^{Λ_{t-1}}) @ S                (C,K)@(K,V)
  intra-chunk:  o += tril(scores) @ v                     (C,C)@(C,V)
  state update: S = e^{Λ_C} ⊙ S + (k ⊙ e^{Λ_C-Λ})ᵀ @ v    (K,C)@(C,V)

with Λ = cumsum(log w) inside the chunk. All decay exponents are ≤ 0, so
the log-domain form is overflow-free by construction. The carried state
lives in a VMEM scratch across the sequential chunk grid axis.

The intra-chunk scores need per-channel decay between every (t, u) pair —
a (C, C, K) tensor. ``C`` is chosen so this fits VMEM (C=64, K=64 → 1 MB
f32); that is the VMEM-driven block-shape decision recorded in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
                 chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)       # (C, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)       # (C, V)
    w = w_ref[0, :, 0, :].astype(jnp.float32)       # (C, K), in (0, 1)
    u = u_ref[0].astype(jnp.float32)                # (K,)
    S = state_ref[...]                               # (K, V) f32

    lw = jnp.log(jnp.maximum(w, 1e-12))
    la = jnp.cumsum(lw, axis=0)                      # Λ_t (inclusive)
    la_ex = la - lw                                  # Λ_{t-1} (exclusive)

    # ---- inter-chunk: state contribution -----------------------------------
    r_dec = r * jnp.exp(la_ex)                       # exponents ≤ 0
    o = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- intra-chunk: pairwise decayed attention ----------------------------
    # decay[t, u, d] = exp(Λ_{t-1,d} - Λ_{u,d})  for u < t  (≤ 0 exponent)
    ldiff = la_ex[:, None, :] - la[None, :, :]       # (C, C, K)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = u_i < t_i
    decay = jnp.where(strict[..., None], jnp.exp(ldiff), 0.0)
    scores = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)
    # diagonal "bonus" term: current token weighted by u instead of w
    diag = jnp.sum(r * k * u[None, :], axis=-1)      # (C,)
    scores = scores + jnp.where(t_i == u_i, diag[:, None], 0.0)
    o = o + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # ---- state update --------------------------------------------------------
    la_last = la[-1]                                 # (K,)
    k_dec = k * jnp.exp(la_last[None, :] - la)       # ≤ 0 exponent
    outer = jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(la_last)[:, None] * S + outer

    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """Chunked WKV6. r,k,w: (B,T,H,K); v: (B,T,H,V); u: (H,K).

    Returns o: (B,T,H,V). T must be divisible by ``chunk``.
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    if T % chunk != 0:
        raise ValueError(f"sequence length {T} must be a multiple of "
                         f"chunk={chunk}")
    nc = T // chunk
    spec_k = pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0))
    spec_v = pl.BlockSpec((1, chunk, 1, V), lambda b, h, c: (b, c, h, 0))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[spec_k, spec_k, spec_v, spec_k,
                  pl.BlockSpec((1, K), lambda b, h, c: (h, 0))],
        out_specs=spec_v,
        out_shape=jax.ShapeDtypeStruct((B, T, H, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
