"""Unified Sum-stage aggregation backend (paper §3.1 / §4.2, Fig. A3).

The Sum stage — per-edge gather + per-destination aggregation — is 76% of
GNN runtime in the paper's stage breakdown, and both forward paths used to
reimplement it: ``combine_messages`` (single block) and the combine branch
of ``_layer_forward_sharded`` (distributed) each hand-rolled sum/mean/
softmax over ``jax.ops.segment_*``. This module is the single combine
engine both consume:

- :data:`COMBINE_SPECS` — the registry of combine modes (``sum`` / ``mean``
  / ``max`` / ``softmax``) with their algebraic properties.
- :class:`AggregationBackend` — pluggable segment primitives. Two
  implementations ship: ``"reference"`` (portable jnp segment ops) and
  ``"csc"`` (the Pallas CSC-blocked kernels of :mod:`repro.kernels`,
  interpret-mode on CPU, Mosaic on TPU), selected by name from config.
- :func:`combine` — the one Sum-stage implementation. Locally it is the
  full aggregation; under the hybrid-parallel engine the same code runs on
  shard-local partials and finalizes through a :class:`ShardContext`
  (mirror→master reduce + master→mirror broadcast hooks), which is exactly
  the paper's reduce/broadcast halo phases.

The ``"csc"`` backend needs a precomputed :class:`~repro.kernels.ops.
CSCPlan` (built once per graph/shard — the paper's reused CSC indexing);
when no plan is threaded through it falls back to the reference primitives
so exotic callers (e.g. the explicit-autodiff reference schedule) keep
working. The plan's index arrays ride into the kernels as scalar-prefetch
operands and the per-edge gather happens on-chip — the kernel path
consumes the raw ``(E, H, D)`` messages directly, with no pre-gathered
``(nb, L_pad, D)`` intermediate (and multi-head softmax is one launch,
heads on the kernel grid). Kernel forwards are paired with fused Pallas
``custom_vjp`` backwards (:mod:`repro.kernels.backward`): a plan-driven
gather kernel for sum, the same gather plus an in-kernel argmax-hit mask
for max, and a recompute-in-kernel softmax jacobian — so ``jax.grad`` of
both the block and distributed paths never leaves the planned layout
(certified by ``ops.assert_sum_stage_fused``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (CSCPlan, edge_softmax_bwd_op,
                               edge_softmax_fwd_op, edge_softmax_op,
                               segment_max_bwd_op, segment_max_op,
                               segment_sum_bwd_op, segment_sum_op)
from repro.kernels.segment_sum import NEG   # the one masking sentinel


# ---------------------------------------------------------------------------
# combine-mode registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CombineSpec:
    """Static description of a Sum-stage combine mode.

    ``needs_logits``  — gather must emit a per-edge ``"logit"`` field.
    ``reduce_ops``    — halo reduce phases the distributed finalize needs
                        (paper §4.1: sum-reduce; softmax adds a max pass).
    """
    name: str
    needs_logits: bool
    reduce_ops: tuple


COMBINE_SPECS: Dict[str, CombineSpec] = {
    "sum": CombineSpec("sum", False, ("sum",)),
    "mean": CombineSpec("mean", False, ("sum",)),
    "max": CombineSpec("max", False, ("max",)),
    "softmax": CombineSpec("softmax", True, ("max", "sum")),
}


def combine_spec(mode: str) -> CombineSpec:
    try:
        return COMBINE_SPECS[mode]
    except KeyError:
        raise ValueError(
            f"unknown combine mode {mode!r}; "
            f"registered: {sorted(COMBINE_SPECS)}") from None


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class AggregationBackend:
    """Segment primitives the combine algorithms are written against.

    ``data`` may be (E,), (E, H) or (E, H, D); outputs keep the trailing
    shape with the edge axis replaced by ``num_segments``. ``plan`` is an
    optional precomputed CSCPlan; backends that don't use one ignore it.
    """

    name = "abstract"

    def segment_sum(self, data, segment_ids, num_segments: int,
                    plan: Optional[CSCPlan] = None):
        raise NotImplementedError

    def segment_max(self, data, segment_ids, num_segments: int,
                    plan: Optional[CSCPlan] = None):
        raise NotImplementedError

    def edge_softmax(self, logits, values, segment_ids, num_segments: int,
                     plan: Optional[CSCPlan] = None):
        """Fused local softmax-weighted sum. ``logits`` are already masked
        to NEG and ``values`` zeroed on inactive edges."""
        seg_max = self.segment_max(logits, segment_ids, num_segments, plan)
        seg_max = jnp.maximum(seg_max, NEG)            # empty segments
        ex = jnp.exp(logits - seg_max[segment_ids])
        ex = jnp.where(logits > NEG / 2, ex, 0.0)
        den = self.segment_sum(ex, segment_ids, num_segments, plan)
        num = self.segment_sum(ex[..., None] * values, segment_ids,
                               num_segments, plan)
        return num / jnp.maximum(den, 1e-9)[..., None]


class ReferenceBackend(AggregationBackend):
    """The portable jnp segment ops (CPU / dry-run / oracle)."""

    name = "reference"

    def segment_sum(self, data, segment_ids, num_segments, plan=None):
        return jax.ops.segment_sum(data, segment_ids, num_segments)

    def segment_max(self, data, segment_ids, num_segments, plan=None):
        return jax.ops.segment_max(data, segment_ids, num_segments)


# -- csc backend: Pallas kernels + reference-math custom VJPs ---------------


def _int_zeros(x):
    """float0 cotangent for integer primals (plan indices, segment ids)."""
    return np.zeros(np.shape(x), dtype=jax.dtypes.float0)


def _plan_from_children(plan_children, meta, num_segments, num_edges):
    """Rebuild the CSCPlan from its traced index arrays (the pytree
    children ride through the custom_vjp as regular operands so the
    backward kernels can scalar-prefetch them)."""
    bn, be, _ = meta
    return CSCPlan(plan_children[0], plan_children[1], plan_children[2],
                   plan_children[0].shape[0], bn, be, num_segments,
                   num_edges)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _csc_segment_sum(num_segments, meta, data, plan_children, segment_ids):
    plan = _plan_from_children(plan_children, meta, num_segments,
                               data.shape[0])
    return segment_sum_op(data, plan, interpret=meta[2])


def _csc_segment_sum_fwd(num_segments, meta, data, plan_children,
                         segment_ids):
    out = _csc_segment_sum(num_segments, meta, data, plan_children,
                           segment_ids)
    return out, (segment_ids, plan_children)


def _csc_segment_sum_bwd(num_segments, meta, res, g):
    segment_ids, plan_children = res
    # segment-sum is linear: d(data) = gather of the output cotangent —
    # the plan-driven Pallas gather kernel (d_data[e] = g[dst[e]], dst
    # scalar-prefetched from the plan's inverse map), not a g[ids] jnp
    # gather: the backward stays in the planned layout
    plan = _plan_from_children(plan_children, meta, num_segments,
                               segment_ids.shape[0])
    return (segment_sum_bwd_op(g, plan, interpret=meta[2]),
            tuple(_int_zeros(c) for c in plan_children),
            _int_zeros(segment_ids))


_csc_segment_sum.defvjp(_csc_segment_sum_fwd, _csc_segment_sum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _csc_segment_max(num_segments, meta, data, plan_children, segment_ids):
    plan = _plan_from_children(plan_children, meta, num_segments,
                               data.shape[0])
    return segment_max_op(data, plan, interpret=meta[2])


def _csc_segment_max_fwd(num_segments, meta, data, plan_children,
                         segment_ids):
    out = _csc_segment_max(num_segments, meta, data, plan_children,
                           segment_ids)
    return out, (data, out, segment_ids, plan_children)


def _csc_segment_max_bwd(num_segments, meta, res, g):
    data, out, segment_ids, plan_children = res
    # subgradient: cotangent flows to entries attaining the segment max
    # (ties share it, matching jax.ops.segment_max); the argmax-hit mask
    # against the saved forward output is fused into the gather kernel
    plan = _plan_from_children(plan_children, meta, num_segments,
                               data.shape[0])
    return (segment_max_bwd_op(g, out, data, plan, interpret=meta[2]),
            tuple(_int_zeros(c) for c in plan_children),
            _int_zeros(segment_ids))


_csc_segment_max.defvjp(_csc_segment_max_fwd, _csc_segment_max_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _csc_edge_softmax(num_segments, meta, logits, values, plan_children,
                      segment_ids):
    plan = _plan_from_children(plan_children, meta, num_segments,
                               logits.shape[0])
    return edge_softmax_op(logits, values, plan, interpret=meta[2])


def _csc_edge_softmax_fwd(num_segments, meta, logits, values, plan_children,
                          segment_ids):
    plan = _plan_from_children(plan_children, meta, num_segments,
                               logits.shape[0])
    # the fused forward launch also emits the per-destination softmax
    # stats (running max m, denominator den) — node-proportional
    # residuals the backward rebuilds p_e from in-kernel, replacing the
    # old full reference segment_max/segment_sum recompute
    out, m, den = edge_softmax_fwd_op(logits, values, plan,
                                      interpret=meta[2])
    return out, (logits, values, out, m, den, segment_ids, plan_children)


def _csc_edge_softmax_bwd(num_segments, meta, res, g):
    logits, values, out, m, den, segment_ids, plan_children = res
    # recompute-in-kernel softmax jacobian. With p_e = softmax(logit_e)
    # over each destination's in-edges:
    #   d v_e     = p_e * g_i
    #   d logit_e = p_e * (v_e . g_i  -  out_i . g_i)
    # p_e is rebuilt inside the kernel from the saved logits + stats; no
    # (E, H) probability tensor, no reference segment passes, one launch
    # with heads on the grid (see kernels/backward.py).
    plan = _plan_from_children(plan_children, meta, num_segments,
                               logits.shape[0])
    d_logits, d_values = edge_softmax_bwd_op(g, logits, values, out, m,
                                             den, plan, interpret=meta[2])
    return (d_logits, d_values,
            tuple(_int_zeros(c) for c in plan_children),
            _int_zeros(segment_ids))


_csc_edge_softmax.defvjp(_csc_edge_softmax_fwd, _csc_edge_softmax_bwd)


def reference_edge_softmax_bwd(g, logits, values, out, segment_ids,
                               num_segments):
    """The pre-fusion reference-math softmax backward, kept verbatim as
    (a) the documented oracle for the kernel backward and (b) the
    reconstruction the benchmark times the fused backward against:
    a full segment_max/segment_sum recompute plus three ``x[segment_ids]``
    edge gathers, all through HBM."""
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments)
    seg_max = jnp.maximum(seg_max, NEG)
    ex = jnp.exp(logits - seg_max[segment_ids])
    ex = jnp.where(logits > NEG / 2, ex, 0.0)
    den = jax.ops.segment_sum(ex, segment_ids, num_segments)
    p = ex / jnp.maximum(den, 1e-9)[segment_ids]
    g_e = g[segment_ids]                                   # (E, H, D)
    d_values = p[..., None] * g_e
    vg = jnp.sum(values * g_e, axis=-1)                    # (E, H)
    og = jnp.sum(out[segment_ids] * g_e, axis=-1)          # (E, H)
    d_logits = p * (vg - og)
    return d_logits, d_values


class CSCBackend(AggregationBackend):
    """The Pallas CSC-blocked kernels behind the backend interface.

    Requires a precomputed CSCPlan for the kernel path (build once per
    graph/shard via ``GraphBlock``/``PartitionPlan`` caches); without one
    it degrades to the reference primitives. ``interpret=None`` resolves
    per call: interpret-mode off TPU, Mosaic compilation on TPU.
    """

    name = "csc"

    def __init__(self, interpret: Optional[bool] = None):
        self.interpret = interpret

    def _interp(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def _meta(self, plan: CSCPlan):
        return (plan.block_n, plan.block_e, self._interp())

    @staticmethod
    def _children(plan: CSCPlan):
        return (jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
                jnp.asarray(plan.edge_dst))

    def segment_sum(self, data, segment_ids, num_segments, plan=None):
        if plan is None:
            return jax.ops.segment_sum(data, segment_ids, num_segments)
        return _csc_segment_sum(num_segments, self._meta(plan), data,
                                self._children(plan), segment_ids)

    def segment_max(self, data, segment_ids, num_segments, plan=None):
        if plan is None:
            return jax.ops.segment_max(data, segment_ids, num_segments)
        return _csc_segment_max(num_segments, self._meta(plan), data,
                                self._children(plan), segment_ids)

    def edge_softmax(self, logits, values, segment_ids, num_segments,
                     plan=None):
        if plan is None:
            return super().edge_softmax(logits, values, segment_ids,
                                        num_segments, plan)
        return _csc_edge_softmax(num_segments, self._meta(plan), logits,
                                 values, self._children(plan), segment_ids)


_BACKENDS: Dict[str, Callable[[], AggregationBackend]] = {}
_INSTANCES: Dict[str, AggregationBackend] = {}


def register_backend(name: str, factory: Callable[[], AggregationBackend]):
    _BACKENDS[name] = factory
    _INSTANCES.pop(name, None)


register_backend("reference", ReferenceBackend)
register_backend("csc", CSCBackend)


def get_backend(backend: Union[None, str, AggregationBackend]
                ) -> AggregationBackend:
    """Resolve a backend name (or pass an instance through)."""
    if backend is None:
        backend = "reference"
    if isinstance(backend, AggregationBackend):
        return backend
    if backend not in _BACKENDS:
        raise ValueError(f"unknown aggregation backend {backend!r}; "
                         f"registered: {sorted(_BACKENDS)}")
    if backend not in _INSTANCES:
        _INSTANCES[backend] = _BACKENDS[backend]()
    return _INSTANCES[backend]


# ---------------------------------------------------------------------------
# the one combine implementation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardContext:
    """Halo hooks for finalizing shard-local partial aggregates.

    ``reduce(arr, op)`` maps mirror-slot partials (n_mirror, ...) to
    master-aligned values (n_master, ...); ``bcast(arr)`` maps master
    values back onto mirror slots. Together they are the paper's
    mirror→master reduce and master→mirror broadcast phases.
    """
    n_master: int
    reduce: Callable[[Any, str], Any]
    bcast: Callable[[Any], Any]


def _finalize(partial, shard: Optional[ShardContext], op: str):
    """Local partials over [masters ; mirrors] -> per-master totals."""
    if shard is None:
        return partial
    local, mirrored = partial[:shard.n_master], partial[shard.n_master:]
    if op == "sum":
        return local + shard.reduce(mirrored, "sum")
    return jnp.maximum(local, shard.reduce(mirrored, "max"))


def combine(mode: str, msg, dst, num_segments: int, edge_mask,
            backend: Union[None, str, AggregationBackend] = None,
            plan: Optional[CSCPlan] = None,
            shard: Optional[ShardContext] = None):
    """The Sum stage: per-destination aggregation of edge messages.

    msg["value"]: (E, H, D); msg["logit"]: (E, H) when the mode needs it;
    dst (E,) int; edge_mask (E,) float. Returns (num_segments, H, D) —
    or per-master totals (n_master, H, D) when ``shard`` is given and the
    arrays are shard-local (num_segments = n_master_pad + n_mirror_pad).
    """
    spec = combine_spec(mode)
    be = get_backend(backend)
    value = msg["value"]

    if spec.name == "softmax":
        logit = jnp.where(edge_mask[:, None] > 0, msg["logit"], NEG)
        masked_value = value * edge_mask[:, None, None]
        if shard is None:
            return be.edge_softmax(logit, masked_value, dst, num_segments,
                                   plan)
        # distributed segment-softmax: global max pass, then sum passes on
        # the shifted exponentials (both finalized through the halo)
        lmax = be.segment_max(logit, dst, num_segments, plan)
        lmax = jnp.maximum(lmax, NEG)                 # clamp empty (-inf)
        gmax_m = _finalize(lmax, shard, "max")
        gmax_all = jnp.concatenate([gmax_m, shard.bcast(gmax_m)], axis=0)
        ex = jnp.exp(logit - gmax_all[dst]) * edge_mask[:, None]
        den = _finalize(be.segment_sum(ex, dst, num_segments, plan),
                        shard, "sum")
        num = _finalize(be.segment_sum(ex[..., None] * masked_value, dst,
                                       num_segments, plan), shard, "sum")
        return num / jnp.maximum(den, 1e-9)[..., None]

    if spec.name == "max":
        masked = jnp.where(edge_mask[:, None, None] > 0, value, NEG)
        agg = _finalize(be.segment_max(masked, dst, num_segments, plan),
                        shard, "max")
        # empty destinations aggregate to the identity (0), not -inf/NEG
        return jnp.where(agg > NEG / 2, agg, 0.0)

    total = _finalize(
        be.segment_sum(value * edge_mask[:, None, None], dst, num_segments,
                       plan), shard, "sum")
    if spec.name == "mean":
        deg = _finalize(be.segment_sum(edge_mask, dst, num_segments, plan),
                        shard, "sum")
        total = total / jnp.maximum(deg, 1e-9)[:, None, None]
    return total
