"""Compiled-once strategy Trainer with a host-side view prefetch pipeline.

The paper's training strategies (global-, mini-, cluster-batch, §2.3/§4.3)
all reduce to streams of :class:`GraphView` masks over one partitioned
graph, so a single jitted train step — whose shapes are fixed by the
:class:`PartitionPlan`, not by the view — serves every strategy. At scale
the bottleneck is not the device math but the host-side batch preparation
(DistDGL's observation); the Trainer attacks it on three fronts:

1. **Vectorized sharding** — views are mapped onto the plan with the
   ``np.take``-based :func:`repro.core.strategies.shard_view` (O(1) Python
   per step instead of a per-partition loop).
2. **Multi-stream prefetch** — for an indexable
   :class:`repro.core.views.ViewStream` (what ``strategy_views`` returns),
   a pool of ``prefetch_workers`` threads builds + shards + stages views
   ahead of the consumer, each worker owning a private
   :class:`~repro.core.views.ViewBuilder` (reused mask buffers). Because
   view i is a pure function of ``(seed, i)`` and the pool emits in index
   order, the loss trajectory is **bit-identical** for any worker count
   and for prefetch disabled — parallelism never costs reproducibility.
   Plain iterators fall back to the single-thread double-buffered
   pipeline.
3. **Compiled-once contract** — the jitted step donates its view buffers
   and carries a compile counter; :meth:`Trainer.assert_compiled_once`
   turns a silent retrace (a 10x regression in disguise) into a hard
   failure. CI asserts it across all three strategies
   (``benchmarks/strategies_bench.py --smoke``).

Periodic evaluation runs through the engine's (equally compiled-once)
distributed ``infer``; checkpoints go through
:mod:`repro.checkpoint.store` and restores resume mid-stream without
triggering a retrace.

**Fault tolerance** (:mod:`repro.runtime`): both trainers take a
``fault_policy`` (retry/backoff, per-stage timeouts, divergence action)
and an optional ``injector`` (deterministic chaos for tests). View
builds, device staging, step dispatch and checkpoint saves become
retryable units; prefetch workers are supervised (killed workers
respawn, their claimed view indices requeue, emit order is preserved);
``check_finite`` guards each step's loss and ``on_divergence`` picks
``raise | skip_view | rollback`` (rollback restores the last valid
checkpoint and continues past the poison view — no retrace, because the
restored leaves match the compiled step's signature).
``fit(..., resume=True)`` auto-resumes from the newest *valid*
checkpoint in ``checkpoint_dir``. Because every retried unit is a pure
function of its inputs, the loss trajectory under injected faults is
bit-identical to a fault-free run — the chaos contract
``tests/test_faults.py`` asserts.

Usage::

    engine = HybridParallelEngine(model, build_partitions(g, P))
    trainer = Trainer(engine, adam(1e-2), seed=0)
    trainer.fit(strategy_views(g, "cluster", K=2), steps=200,
                eval_every=50, eval_view=global_batch_view(g, 2))
    trainer.assert_compiled_once()
"""
from __future__ import annotations

import itertools
import math
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.strategies import GraphView, shard_view
from repro.core.views import CompactBlockBuilder, ViewStream
from repro.runtime.faults import (DivergenceError, FaultInjector,
                                  FaultPolicy, Retrier, sync_with_timeout)
from repro.runtime.prefetch import StreamPrefetcher, ViewPrefetcher
from repro.runtime.procpool import (ProcessViewService,
                                    ProcPoolUnavailable,
                                    warn_unavailable_once)

# the pipelines moved to repro.runtime.prefetch (where supervision
# lives); these aliases keep the long-standing private import paths of
# tests/benches working
_ViewPrefetcher = ViewPrefetcher
_MultiStreamPrefetcher = StreamPrefetcher


class RetraceError(AssertionError):
    """The compiled-once contract was broken (or never exercised)."""


def _make_runtime(fault_policy: Optional[FaultPolicy],
                  injector: Optional[FaultInjector]) -> Optional[Retrier]:
    """A Retrier when any fault handling is configured, else None (the
    zero-overhead production default)."""
    if fault_policy is None and injector is None:
        return None
    return Retrier(fault_policy or FaultPolicy(), injector)


def _handle_divergence(tr, prev, loss_val: float,
                       checkpoint_dir: Optional[str],
                       events: list) -> None:
    """Apply ``tr.runtime.policy.on_divergence`` to a non-finite step.
    ``prev`` is the pre-step (params, opt_state, step_num) — the poison
    update is always discarded first (jax arrays are immutable, so the
    held refs ARE the pre-step state). Shared by both trainers; ``tr``
    needs params/opt_state/step_num/view_cursor/restore/_resume_cursor.
    """
    tr.params, tr.opt_state, tr.step_num = prev
    action = tr.runtime.policy.on_divergence
    events.append({"stage": "diverge", "step": prev[2] + 1,
                   "loss": loss_val, "action": action,
                   "view_cursor": tr.view_cursor})
    if action == "skip_view":
        return   # poison view consumed, update discarded — move on
    if action == "rollback":
        if checkpoint_dir:
            try:
                # load_checkpoint(None) already falls back past any
                # corrupt file to the newest valid step
                tr.restore(checkpoint_dir)
            except FileNotFoundError:
                # no checkpoint yet — fall through to the raise below
                pass  # lint: waive=src.silent-except
            else:
                # mid-fit: the stream already stands past the poison
                # view; the armed resume cursor must not rewind a
                # LATER fit to the checkpoint's older position
                tr._resume_cursor = None
                return
        raise DivergenceError(
            f"non-finite loss {loss_val} at step {prev[2] + 1} with "
            "on_divergence='rollback' but no valid checkpoint to "
            "roll back to (pass checkpoint_dir and checkpoint_every)")
    raise DivergenceError(
        f"non-finite loss {loss_val} at step {prev[2] + 1} "
        f"(view cursor {tr.view_cursor})")


def _assert_once_per_bucket(traces: int, touched: int, what: str) -> None:
    """The bucketed trace-count contract, shared by the train step
    (:meth:`CompactTrainer.assert_compiled_per_bucket`) and the serving
    infer steps (:class:`BucketedFn`): exactly one trace per touched
    bucket shape."""
    if touched == 0:
        raise RetraceError(
            f"{what} never ran — exercise it before asserting the "
            "once-per-bucket contract")
    if traces != touched:
        raise RetraceError(
            f"{what} was traced {traces} times over {touched} touched "
            f"bucket shapes (expected exactly one trace per bucket): "
            "an input was staged with a shape or plan geometry not "
            "determined by its bucket")


class BucketedFn:
    """One jitted ``fn(params, block)`` over bucket-padded compact blocks
    with once-per-bucket trace accounting — the infer-path extraction of
    :class:`CompactTrainer`'s train-step contract, which
    :mod:`repro.serving` programs against. ``jit``'s signature cache keys
    on leaf shapes (pure functions of the bucket), so the callable holds
    exactly one executable per touched ``(n_pad, e_pad)`` shape;
    :meth:`assert_compiled_per_bucket` certifies it."""

    def __init__(self, fn, name: str = "infer"):
        self.name = name
        self.traces = 0
        self.buckets_touched: set = set()

        def counted(params, block):
            # runs only while tracing: one increment per (bucket) compile
            self.traces += 1
            return fn(params, block)

        self.jitted = jax.jit(counted)

    def __call__(self, params, block):
        self.buckets_touched.add((int(block.x.shape[0]),
                                  int(block.src.shape[0])))
        return self.jitted(params, block)

    def assert_compiled_per_bucket(self) -> None:
        _assert_once_per_bucket(self.traces, len(self.buckets_touched),
                                f"{self.name} step")

    def jaxpr(self, params, block):
        """Jaxpr over ``block`` for :mod:`repro.analysis` rules; tracing
        runs the counted body, so the counters are saved/restored (the
        certificate must survive analysis)."""
        saved, saved_b = self.traces, set(self.buckets_touched)
        try:
            return jax.make_jaxpr(self.jitted)(params, block)
        finally:
            self.traces, self.buckets_touched = saved, saved_b


class BaseTrainer:
    """The shared trainer surface: one ``fit`` loop (prefetch pipelines,
    loss sync policy, divergence handling, eval/checkpoint cadence), plus
    ``save``/``restore``/``reset`` — everything that is identical between
    the partition-plan :class:`Trainer` and the bucketed
    :class:`CompactTrainer`. ``repro.runtime``, ``repro.serving`` and the
    :mod:`repro.api` facade program against this type instead of
    ``isinstance`` forks.

    Subclasses provide four hooks:

    - ``_init_params(seed)`` — fresh model params;
    - ``_make_prepare()`` — a ``view -> staged`` callable for one fit
      (prefetch workers call it concurrently);
    - ``_dispatch(staged)`` — one raw step call, returning
      ``(params, opt_state, loss)``;
    - ``assert_trace_contract()`` — the subclass's compile-count
      certificate (compiled-once vs once-per-bucket).
    """

    # subclasses set in __init__: opt, runtime, params, opt_state,
    # step_num, history, prefetch_depth, view_cursor, _resume_cursor

    def _init_common(self, opt, prefetch_depth: int,
                     fault_policy: Optional[FaultPolicy],
                     injector: Optional[FaultInjector]) -> None:
        self.opt = opt
        # fault-tolerance runtime: None = production fast path (no retry
        # wrappers, no per-step loss sync). The injector only ever fires
        # on host-side supervision points — traced code never sees it.
        self.runtime = _make_runtime(fault_policy, injector)
        self.step_num = 0
        self.history: list = []
        self.prefetch_depth = prefetch_depth
        # view-stream position (checkpointed so restore() can fast-forward
        # the stream itself instead of asking the caller to)
        self.view_cursor = 0
        self._resume_cursor: Optional[int] = None

    # -- subclass hooks --------------------------------------------------------

    def _init_params(self, seed: int):
        raise NotImplementedError

    def _make_prepare(self):
        raise NotImplementedError

    def _dispatch(self, staged):
        raise NotImplementedError

    def _on_reset(self) -> None:
        """Subclass-specific reset extras (e.g. eval caches)."""

    def evaluate(self, view, mask: Optional[np.ndarray] = None) -> float:
        raise NotImplementedError

    def assert_trace_contract(self) -> None:
        raise NotImplementedError

    # -- the training loop ----------------------------------------------------

    def fit(self, views, steps: Optional[int] = None,
            prefetch: bool = True, prefetch_workers: Optional[int] = None,
            prefetch_mode: str = "thread",
            eval_every: int = 0, eval_view=None,
            eval_mask: Optional[np.ndarray] = None,
            checkpoint_every: int = 0,
            checkpoint_dir: Optional[str] = None,
            max_in_flight: int = 2,
            log_every: int = 0, log=print,
            resume: bool = False) -> dict:
        """Run ``steps`` views (all of ``views`` if None) through the
        compiled step. Returns ``{"losses", "evals", "steps", "events"}``;
        losses are synced once at the end so per-step host/device overlap
        is never serialized by a blocking ``float()``.

        ``resume=True`` restores the newest *valid* checkpoint in
        ``checkpoint_dir`` before training (fresh start if there is
        none) and fast-forwards a ViewStream to its recorded cursor.
        With a ``fault_policy`` whose ``check_finite`` is on (or whose
        ``on_divergence`` is not ``"raise"``), each step's loss is
        synced and guarded: a non-finite loss triggers the policy's
        divergence action — ``skip_view`` discards the poison update,
        ``rollback`` restores the last valid checkpoint and continues
        past the poison view (no retrace: restored leaves match the
        compiled signature). A ``step`` timeout in the policy arms a
        watchdog around the loss sync.

        When ``views`` is an indexable :class:`ViewStream` (what
        ``strategy_views`` returns) and ``prefetch`` is on, view
        construction fans out over ``prefetch_workers`` builder threads —
        deterministically: the loss trajectory is bit-identical for any
        worker count and for ``prefetch=False``, because view i only
        depends on ``(seed, i)`` and views are emitted in index order.
        The default (None) leaves one core for the device executor and
        caps at 4 — ``min(4, cpu_count - 1)`` — so builder threads never
        oversubscribe the box the step runs on. Plain iterators use the
        single-thread double-buffered pipeline.

        ``prefetch_mode`` picks the pool implementation for stream
        views: ``"thread"`` (default) is the in-process
        :class:`~repro.runtime.prefetch.StreamPrefetcher`;
        ``"process"`` fans view construction out to supervised sampler
        *processes* over shared-memory slots
        (:class:`~repro.runtime.procpool.ProcessViewService`) —
        GIL-free builds, same bit-identical trajectory. When shared
        memory is unavailable the process mode degrades to threads with
        a one-time warning; plain (non-stream) iterators always use the
        in-process pipeline (their builds are not pure in an index, so
        they cannot be farmed out).

        ``max_in_flight`` bounds the async-dispatch run-ahead: before
        dispatching step *i* the loop blocks on step *i - max_in_flight*,
        so at most that many steps' view/activation buffers are live at
        once — deep run-ahead piles up device memory and (on CPU) slows
        the executor more than the overlap buys.
        """
        rt = self.runtime
        if resume and checkpoint_dir:
            from repro.checkpoint import latest_step
            if latest_step(checkpoint_dir) is not None:
                self.restore(checkpoint_dir)
        prepare = self._make_prepare()
        stream = views if isinstance(views, ViewStream) else None
        # any fit consumes a pending restore cursor — a plain-iterator fit
        # must not leave it armed to silently fast-forward a later,
        # unrelated stream
        resume_cur, self._resume_cursor = self._resume_cursor, None
        if stream is not None and resume_cur is not None \
                and stream.cursor < resume_cur:
            # a checkpoint restore recorded where the view stream stood —
            # fast-forward the stream itself (per-index RNG makes the
            # cursor the entire stream state)
            stream.seek(resume_cur)
        # non-prefetch paths run prepare inline; with a runtime it is
        # still a retryable view_build stage (the prefetchers wrap their
        # own build+prepare internally)
        prep = prepare if rt is None else (
            lambda v: rt("view_build", lambda: prepare(v)))
        if prefetch_mode not in ("thread", "process"):
            raise ValueError(
                f"prefetch_mode={prefetch_mode!r} — expected 'thread' "
                "or 'process'")
        if stream is not None:
            # indexable stream: the worker pool path (workers=1 is the
            # double-buffered pipeline with exact cursor accounting)
            if prefetch:
                if prefetch_workers is None:
                    prefetch_workers = max(
                        1, min(4, (os.cpu_count() or 2) - 1))
                staged_iter = None
                if prefetch_mode == "process":
                    try:
                        staged_iter = ProcessViewService(
                            stream, prepare, steps,
                            workers=prefetch_workers,
                            depth=self.prefetch_depth, runtime=rt)
                    except ProcPoolUnavailable as e:
                        warn_unavailable_once(str(e))
                if staged_iter is None:
                    staged_iter = _MultiStreamPrefetcher(
                        stream, prepare, steps, workers=prefetch_workers,
                        depth=self.prefetch_depth, runtime=rt)
            else:
                bounded = (itertools.islice(stream, steps)
                           if steps is not None else stream)
                staged_iter = (prep(v) for v in bounded)
        else:
            if steps is not None:
                views = itertools.islice(views, steps)
            staged_iter = (_ViewPrefetcher(views, prepare,
                                           self.prefetch_depth,
                                           runtime=rt)
                           if prefetch else (prep(v) for v in views))

        policy = rt.policy if rt is not None else None
        inj = rt.injector if rt is not None else None
        # the finite guard syncs every loss (serializes the pipeline) —
        # on only when asked for, or when a non-raise divergence action
        # implies it must observe the loss to act
        guard = policy is not None and (policy.check_finite
                                        or policy.on_divergence != "raise")
        watchdog = policy.timeout("step") if policy is not None else None
        sync_now = guard or watchdog is not None
        events = rt.events if rt is not None else []
        losses, pending, evals = [], [], []
        try:
            # idx counts views consumed THIS fit — monotonic even across
            # a rollback (which rewinds step_num), so a keyed "diverge"
            # injection fires exactly once per poison view
            for idx, staged in enumerate(staged_iter):
                if max_in_flight > 0 and len(pending) >= max_in_flight:
                    # backpressure: wait on the oldest in-flight step (one
                    # scalar readiness wait, not a pipeline-wide sync) and
                    # retire its loss to a host float so live device
                    # arrays stay O(max_in_flight), not O(steps)
                    losses.append(float(pending.pop(0)))
                # pre-step refs: jax arrays are immutable, so holding the
                # old (params, opt_state) costs nothing and is the whole
                # skip_view recovery
                prev = (self.params, self.opt_state, self.step_num)
                if rt is None:
                    self.params, self.opt_state, loss = \
                        self._dispatch(staged)
                else:
                    # step dispatch is a retryable stage too: a transient
                    # failure re-dispatches the same (params, staged) —
                    # deterministic by construction
                    self.params, self.opt_state, loss = rt(
                        "step", lambda: self._dispatch(staged),
                        key=self.step_num)
                self.step_num += 1
                self.view_cursor = (stream.cursor if stream is not None
                                    else self.step_num)
                if sync_now:
                    loss_val = sync_with_timeout(
                        lambda: float(loss), watchdog)
                    if inj is not None and inj.fires(
                            "diverge", key=idx):
                        loss_val = float("nan")   # simulated divergence
                    if guard and not math.isfinite(loss_val):
                        self._diverged(prev, loss_val, checkpoint_dir,
                                       events)
                        continue
                    losses.append(loss_val)
                else:
                    pending.append(loss)
                if (eval_every and eval_view is not None
                        and self.step_num % eval_every == 0):
                    rec = {"step": self.step_num, "loss": float(loss),
                           "eval_acc": self.evaluate(eval_view, eval_mask)}
                    evals.append(rec)
                    if log_every:
                        log(f"step {rec['step']:5d}  "
                            f"loss {rec['loss']:.4f}  "
                            f"eval_acc {rec['eval_acc']:.4f}")
                if (checkpoint_every and checkpoint_dir
                        and self.step_num % checkpoint_every == 0):
                    self.save(checkpoint_dir)
        finally:
            if isinstance(staged_iter,
                          (_ViewPrefetcher, _MultiStreamPrefetcher,
                           ProcessViewService)):
                staged_iter.close()
            if isinstance(staged_iter, ProcessViewService) and rt is None:
                # with a runtime the service already appended its
                # supervision events into rt.events
                events.extend(staged_iter.events)
        losses.extend(float(l) for l in pending)
        self.history.extend(evals)
        return {"losses": losses, "evals": evals, "steps": self.step_num,
                "events": list(events)}

    def _diverged(self, prev, loss_val: float,
                  checkpoint_dir: Optional[str], events: list) -> None:
        _handle_divergence(self, prev, loss_val, checkpoint_dir, events)

    # -- checkpointing ---------------------------------------------------------

    def save(self, directory: str) -> str:
        # view_cursor is the entire state of a per-index ViewStream (the
        # RNG stream of view i is derived from (seed, i)), so storing it
        # lets restore() fast-forward the stream itself
        rt = self.runtime
        keep = rt.policy.keep_checkpoints if rt is not None else 0

        def do():
            return save_checkpoint(directory, self.step_num, {
                "params": self.params,
                "opt_state": self.opt_state,
                "step": np.asarray(self.step_num, np.int64),
                "view_cursor": np.asarray(self.view_cursor, np.int64),
            }, keep=keep)

        if rt is None:
            return do()
        # a failed save never poisons disk (atomic rename) — retry it.
        # Saves are sequential host calls, so the injector's occurrence
        # counter is already deterministic (no key needed)
        return rt("checkpoint_save", do)

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        """Load params/opt state/step from a checkpoint. The restored
        leaves match the compiled step's signature (per bucket, for the
        bucketed trainer), so resuming does not retrace. If the
        checkpoint recorded a view-stream cursor, the next ``fit`` over a
        :class:`ViewStream` fast-forwards the stream to it automatically;
        for plain iterators the returned step lets the caller
        fast-forward by hand (legacy behavior)."""
        rt = self.runtime
        if rt is None:
            ck = load_checkpoint(directory, step)
        else:
            ck = rt("checkpoint_load",
                    lambda: load_checkpoint(directory, step))
        self.params = ck["params"]
        self.opt_state = ck["opt_state"]
        self.step_num = int(ck["step"])
        if "view_cursor" in ck:      # older checkpoints predate the key
            self.view_cursor = int(ck["view_cursor"])
            self._resume_cursor = self.view_cursor
        return self.step_num

    # -- lifecycle -------------------------------------------------------------

    def reset(self, params: Optional[Any] = None, seed: int = 0):
        """Fresh params/opt state **keeping the compiled step(s)**, so one
        compile serves many runs (strategy comparisons reset between
        strategies and still certify the trace contract)."""
        if params is None:
            params = self._init_params(seed)
        self.params = params
        self.opt_state = self.opt.init(params)
        self.step_num = 0
        self.history = []
        self.view_cursor = 0
        self._resume_cursor = None
        self._on_reset()


class Trainer(BaseTrainer):
    """Drives any GraphView iterator through a :class:`HybridParallelEngine`
    with one shape-stable, compiled-once train step.

    The step's shapes are fixed by the partition plan — ``(P, K, n_m_pad)``
    node masks, ``(P, K, e_pad)`` edge masks — so global-, mini- and
    cluster-batch views all hit the same executable. View buffers are
    donated to XLA (every step stages a fresh view, so the device-side
    mask buffers are reused in place). ``trace_counts`` records how often
    the step (and the eval ``infer``) were actually traced.
    """

    def __init__(self, engine, opt, params: Optional[Any] = None,
                 seed: int = 0, prefetch_depth: int = 2,
                 fault_policy: Optional[FaultPolicy] = None,
                 injector: Optional[FaultInjector] = None):
        self.engine = engine
        self.plan = engine.plan
        self._init_common(opt, prefetch_depth, fault_policy, injector)
        if params is None:
            params = self._init_params(seed)
        self.params = params
        self.opt_state = opt.init(params)
        self.trace_counts = {"train_step": 0, "infer": 0}

        lg = engine.make_loss_and_grad()

        def _step(params, opt_state, data, view):
            # runs only while tracing — this is the compile counter the
            # compiled-once contract is certified against
            self.trace_counts["train_step"] += 1
            loss, grads = lg(params, data, view)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        # view buffers are donated so XLA reuses the device-side mask
        # buffers in place step over step (donation is a no-op warning on
        # the CPU backend, so only ask for it where it exists)
        self._donate_views = jax.default_backend() != "cpu"
        donate = (3,) if self._donate_views else ()
        self._step = jax.jit(_step, donate_argnums=donate)
        self._infer = engine.make_infer(on_trace=self._count_infer_trace)
        # single-slot (view, staged-arrays) cache; holding the view object
        # itself both bounds the cache and keeps the identity check sound
        # (an id() key could be reused by a garbage-collected view)
        self._eval_cache: Optional[tuple] = None

    def _count_infer_trace(self):
        self.trace_counts["infer"] += 1

    # -- BaseTrainer hooks -----------------------------------------------------

    def _init_params(self, seed: int):
        return self.engine.model.init(jax.random.PRNGKey(seed),
                                      self.engine.sg.feature_dim)

    def _make_prepare(self):
        # shard staging retries transient device_put failures when a
        # runtime is configured (engine-side hook)
        rt = self.runtime
        stage = lambda v: self.engine.stage_view(  # noqa: E731
            shard_view(self.plan, v), retry=rt)
        if self._donate_views:
            # donated buffers are consumed by the step — always restage
            return stage
        # static streams (global batch yields one GraphView object)
        # are staged exactly once and the device buffers reused; the
        # cache holds the view itself so the identity check can't be
        # fooled by a freed view's id being reused. Multiple prefetch
        # workers may race here: staged is written BEFORE the view key
        # and misses return their locally staged value, so a racing
        # reader can at worst duplicate work, never observe a
        # half-written entry
        cache = {"view": None, "staged": None}

        def prepare(v):
            if cache["view"] is v:
                return cache["staged"]
            staged = stage(v)
            cache["staged"] = staged
            cache["view"] = v
            return staged

        return prepare

    def _dispatch(self, staged):
        return self._step(self.params, self.opt_state,
                          self.engine._device_data, staged)

    def _on_reset(self) -> None:
        self._eval_cache = None

    def assert_trace_contract(self) -> None:
        self.assert_compiled_once()

    # -- eval / infer -----------------------------------------------------------

    def evaluate(self, view: GraphView,
                 mask: Optional[np.ndarray] = None) -> float:
        """Distributed inference over ``view`` (compiled once, shared with
        every later eval); accuracy on ``mask`` (default: the graph's test
        mask, falling back to the view's loss mask)."""
        if self._eval_cache is None or self._eval_cache[0] is not view:
            self._eval_cache = (view, shard_view(self.plan, view))
        logits = self._infer(self.params, dict(self._eval_cache[1]))
        preds = self.engine.gather_predictions(np.asarray(logits)).argmax(-1)
        g = view.graph
        if mask is None:
            mask = (g.test_mask if g.test_mask is not None
                    else view.loss_mask > 0)
        mask = np.asarray(mask) > 0
        if not mask.any():
            return 0.0
        return float((preds[mask] == g.labels[mask]).mean())

    # -- contracts ---------------------------------------------------------------

    def assert_compiled_once(self):
        """The trace-count contract: after any number of steps across any
        mix of strategies, the train step must have been traced exactly
        once (and the eval infer at most once). A retrace is a silent
        ~10x slowdown — fail loudly instead."""
        n = self.trace_counts["train_step"]
        if n == 0:
            raise RetraceError(
                "assert_compiled_once: the train step never ran — call "
                "fit() before asserting the contract")
        if n != 1:
            raise RetraceError(
                f"train step was traced {n} times (expected exactly 1): "
                "some input changed shape/dtype between steps — view "
                "arrays must come from shard_view over one PartitionPlan")
        if self.trace_counts["infer"] > 1:
            raise RetraceError(
                f"eval infer was traced {self.trace_counts['infer']} "
                "times (expected at most 1)")

    # -- static analysis hooks ---------------------------------------------------

    @property
    def expected_donated(self) -> int:
        """How many step invars must carry donation flags: the three view
        leaves (node_active/edge_active/loss_mask) on accelerator
        backends, none on cpu (where donation is a no-op warning)."""
        return 3 if self._donate_views else 0

    def traced_step_jaxpr(self, view: GraphView):
        """Jaxpr of the jitted train step over ``view`` — what
        ``repro.analysis`` rules walk. Tracing runs the step's Python
        body (the compile counter), so the counters are saved/restored:
        analysis must not break the compiled-once certificate."""
        staged = self.engine.stage_view(shard_view(self.plan, view))
        saved = dict(self.trace_counts)
        try:
            return jax.make_jaxpr(self._step)(
                self.params, self.opt_state, self.engine._device_data,
                staged)
        finally:
            self.trace_counts = saved

    def traced_infer_jaxpr(self, view: GraphView):
        """Jaxpr of the jitted eval/infer computation over ``view``."""
        staged = self.engine.stage_view(shard_view(self.plan, view))
        saved = dict(self.trace_counts)
        try:
            return jax.make_jaxpr(self._infer.jitted)(
                self.params, self.engine._device_data, staged)
        finally:
            self.trace_counts = saved


class CompactTrainer(BaseTrainer):
    """Single-process trainer over size-bucketed compact blocks.

    Where :class:`Trainer` fixes the step's shapes with a PartitionPlan,
    this trainer fixes them with a :class:`~repro.core.views.BucketSpec`:
    every :class:`~repro.core.views.CompactView` is staged by a
    :class:`~repro.core.views.CompactBlockBuilder` into one of a small
    fixed menu of padded ``(n_pad, e_pad)`` shapes, so device compute and
    memory scale with the *view* while the jitted step still compiles at
    most once per bucket — the bucketed analog of the compiled-once
    contract, certified by :meth:`assert_compiled_per_bucket`.

    Dense GraphViews pass straight through (full-graph shape = its own
    bucket), so the same loop drives the dense parity oracle.
    """

    def __init__(self, model, g, opt, params: Optional[Any] = None,
                 seed: int = 0, buckets=None, slots: int = 2,
                 gcn_norm: bool = True, prefetch_depth: int = 2,
                 fault_policy: Optional[FaultPolicy] = None,
                 injector: Optional[FaultInjector] = None):
        from repro.core.mpgnn import accuracy_block, loss_block
        self.model = model
        self.g = g
        self._init_common(opt, prefetch_depth, fault_policy, injector)
        backend = getattr(model, "aggregate_backend", "reference")
        self.stager = CompactBlockBuilder(
            g, model.K, buckets=buckets, slots=slots, gcn_norm=gcn_norm,
            csc_plan=(backend == "csc"))
        self.buckets = self.stager.buckets
        if params is None:
            params = self._init_params(seed)
        self.params = params
        self.opt_state = opt.init(params)
        self.trace_counts = {"train_step": 0}
        # (n_pad, e_pad) shapes actually staged — the denominator of the
        # once-per-bucket contract
        self.buckets_touched: set = set()
        # staging mutates per-bucket ring buffers; prefetch workers must
        # not interleave fills (device_put copies on every backend we run,
        # so the staged block is detached before the lock releases)
        self._stage_lock = threading.Lock()

        def _step(params, opt_state, block):
            # runs only while tracing: one increment per (bucket) compile
            self.trace_counts["train_step"] += 1
            loss, grads = jax.value_and_grad(
                lambda p: loss_block(model, p, block))(params)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        # jit's signature cache keys on leaf shapes + the plan's static
        # geometry — both pure functions of the bucket, so this single
        # jitted callable holds exactly one executable per touched bucket
        self._step = jax.jit(_step)
        self._acc = jax.jit(
            lambda params, block, mask: accuracy_block(model, params,
                                                       block, mask))

    def _prepare(self, view):
        with self._stage_lock:
            block = self.stager.stage(view)
            self.buckets_touched.add((int(block.x.shape[0]),
                                      int(block.src.shape[0])))
            # the staged block aliases the builder's ring buffers (and a
            # dense view's masks alias its ViewBuilder's ring). Handing
            # those to jax directly is unsafe: the CPU backend ZERO-COPIES
            # sufficiently aligned numpy arrays, and even an explicit
            # jax-side copy materializes asynchronously — either way a
            # later fill of the same ring slot races an in-flight step's
            # input. A numpy copy is synchronous by construction, so the
            # block is detached before the lock releases.
            return jax.tree_util.tree_map(np.array, block)

    # -- BaseTrainer hooks -----------------------------------------------------

    def _init_params(self, seed: int):
        return self.model.init(jax.random.PRNGKey(seed),
                               self.g.node_features.shape[1])

    def _make_prepare(self):
        return self._prepare

    def _dispatch(self, staged):
        return self._step(self.params, self.opt_state, staged)

    def assert_trace_contract(self) -> None:
        self.assert_compiled_per_bucket()

    # -- eval -------------------------------------------------------------------

    def evaluate(self, view, mask: Optional[np.ndarray] = None) -> float:
        """Accuracy over ``view``'s block (a dense GraphView stages the
        cached base block; a CompactView a tight-padded one-off)."""
        block = view.as_block(gcn_norm=self.stager.gcn_norm,
                              csc_plan=self.stager.csc_plan)
        if mask is None:
            g = view.graph
            mask = (g.test_mask if g.test_mask is not None else None)
        if mask is not None:
            flat = np.asarray(mask).astype(np.float32)
            if hasattr(view, "nodes"):   # CompactView: global -> local ids
                flat = flat[view.nodes]
            m = np.zeros(block.x.shape[0], np.float32)
            m[:len(flat)] = flat
        else:
            m = block.loss_mask
        return float(self._acc(self.params, block, m))

    # -- contracts ---------------------------------------------------------------

    def assert_compiled_per_bucket(self):
        """The bucketed trace-count contract: the step must have been
        traced exactly once per *touched* bucket shape — repeat epochs
        over the same buckets add zero traces."""
        _assert_once_per_bucket(self.trace_counts["train_step"],
                                len(self.buckets_touched), "train step")

    # -- static analysis hooks ---------------------------------------------------

    def traced_step_jaxpr(self, view):
        """Jaxpr of the bucketed step over ``view``'s staged block — what
        the O(view) compact-step rules walk. Staging and tracing both
        perturb the contract counters (buckets_touched / trace_counts),
        so they are saved and restored: analysis must not change the
        once-per-bucket certificate."""
        saved_counts = dict(self.trace_counts)
        saved_buckets = set(self.buckets_touched)
        try:
            block = self._prepare(view)
            return jax.make_jaxpr(self._step)(
                self.params, self.opt_state, block)
        finally:
            self.trace_counts = saved_counts
            self.buckets_touched = saved_buckets
