"""Compiled-once strategy Trainer with a host-side view prefetch pipeline.

The paper's training strategies (global-, mini-, cluster-batch, §2.3/§4.3)
all reduce to streams of :class:`GraphView` masks over one partitioned
graph, so a single jitted train step — whose shapes are fixed by the
:class:`PartitionPlan`, not by the view — serves every strategy. At scale
the bottleneck is not the device math but the host-side batch preparation
(DistDGL's observation); the Trainer attacks it on three fronts:

1. **Vectorized sharding** — views are mapped onto the plan with the
   ``np.take``-based :func:`repro.core.strategies.shard_view` (O(1) Python
   per step instead of a per-partition loop).
2. **Multi-stream prefetch** — for an indexable
   :class:`repro.core.views.ViewStream` (what ``strategy_views`` returns),
   a pool of ``prefetch_workers`` threads builds + shards + stages views
   ahead of the consumer, each worker owning a private
   :class:`~repro.core.views.ViewBuilder` (reused mask buffers). Because
   view i is a pure function of ``(seed, i)`` and the pool emits in index
   order, the loss trajectory is **bit-identical** for any worker count
   and for prefetch disabled — parallelism never costs reproducibility.
   Plain iterators fall back to the single-thread double-buffered
   pipeline.
3. **Compiled-once contract** — the jitted step donates its view buffers
   and carries a compile counter; :meth:`Trainer.assert_compiled_once`
   turns a silent retrace (a 10x regression in disguise) into a hard
   failure. CI asserts it across all three strategies
   (``benchmarks/strategies_bench.py --smoke``).

Periodic evaluation runs through the engine's (equally compiled-once)
distributed ``infer``; checkpoints go through
:mod:`repro.checkpoint.store` and restores resume mid-stream without
triggering a retrace.

Usage::

    engine = HybridParallelEngine(model, build_partitions(g, P))
    trainer = Trainer(engine, adam(1e-2), seed=0)
    trainer.fit(strategy_views(g, "cluster", K=2), steps=200,
                eval_every=50, eval_view=global_batch_view(g, 2))
    trainer.assert_compiled_once()
"""
from __future__ import annotations

import itertools
import os
import queue
import threading
from typing import Any, Iterable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.strategies import GraphView, shard_view
from repro.core.views import CompactBlockBuilder, ViewStream


class RetraceError(AssertionError):
    """The compiled-once contract was broken (or never exercised)."""


class _ViewPrefetcher:
    """Double-buffered host pipeline.

    A daemon thread pulls GraphViews from the iterator, runs ``prepare``
    (vectorized ``shard_view`` + ``device_put``) and parks up to ``depth``
    staged views in a bounded queue, so staging for step *i+1* overlaps
    device compute for step *i*. Exceptions in the thread re-raise in the
    consumer; exhaustion is signalled with a sentinel.
    """

    _END = object()

    def __init__(self, views: Iterable[GraphView], prepare, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._cancel = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(views, prepare), daemon=True,
            name="view-prefetch")
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded put that gives up when the consumer cancelled (so an
        abandoned fit can't leave the thread pinning staged buffers)."""
        while not self._cancel.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, views, prepare):
        try:
            for v in views:
                if self._cancel.is_set() or not self._put(prepare(v)):
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced in __next__
            self._err = e
        finally:
            self._put(self._END)

    def close(self):
        """Unblock and retire the producer thread; staged-but-unconsumed
        views are dropped."""
        self._cancel.set()
        while True:   # drain so a blocked _put wakes immediately
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._END:
            self._thread.join()
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class _MultiStreamPrefetcher:
    """Worker-pool pipeline over an indexable :class:`ViewStream`.

    ``workers`` threads each own a private ViewBuilder and claim view
    indices from a shared counter; finished (built + sharded + staged)
    views land in a reorder buffer and are emitted strictly in index
    order. Since ``stream.build(i)`` derives its RNG from ``(seed, i)``,
    the emitted sequence is bit-identical to sequential construction no
    matter how the OS schedules the workers.

    Run-ahead is bounded: no worker starts index i until
    ``i - emitted < depth + workers - 1``, so at most ~depth staged views
    wait in the buffer while every worker stays busy. The stream's cursor
    advances only as views are *emitted* (not as they are built), which is
    what makes the cursor checkpointable mid-pipeline.
    """

    def __init__(self, stream: ViewStream, prepare, steps: Optional[int],
                 workers: int = 1, depth: int = 2):
        self._stream = stream
        self._start = stream.cursor
        left = (None if stream.length is None
                else max(0, stream.length - self._start))
        if steps is None:
            self._limit = left
        else:
            self._limit = steps if left is None else min(steps, left)
        self._prepare = prepare
        self._cond = threading.Condition()
        self._results: dict = {}
        self._next_build = 0
        self._emitted = 0
        self._err: Optional[BaseException] = None
        self._cancel = False
        # materialize the graph's lazy CSC index before the fan-out so
        # worker-thread builders never race the unlocked cache
        stream.g.csc()
        workers = max(1, workers)
        self._max_ahead = max(1, depth) + workers - 1
        self._threads = [
            threading.Thread(target=self._work, daemon=True,
                             name=f"view-stream-{w}")
            for w in range(workers)]
        for t in self._threads:
            t.start()

    def _work(self):
        try:
            builder = self._stream.make_builder()
            while True:
                with self._cond:
                    while (not self._cancel and self._err is None
                           and (self._limit is None
                                or self._next_build < self._limit)
                           and (self._next_build - self._emitted
                                >= self._max_ahead)):
                        self._cond.wait()
                    if (self._cancel or self._err is not None
                            or (self._limit is not None
                                and self._next_build >= self._limit)):
                        return
                    i = self._next_build
                    self._next_build += 1
                item = self._prepare(
                    self._stream.build(self._start + i, builder))
                with self._cond:
                    self._results[i] = item
                    self._cond.notify_all()
        except BaseException as e:  # noqa: BLE001 — surfaced in __next__
            with self._cond:
                if self._err is None:
                    self._err = e
                self._cond.notify_all()

    def close(self):
        with self._cond:
            self._cancel = True
            self._results.clear()
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=5)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        with self._cond:
            if self._limit is not None and self._emitted >= self._limit:
                raise StopIteration
            while self._emitted not in self._results and self._err is None:
                self._cond.wait()
            if self._emitted not in self._results:
                err = self._err
                raise err
            item = self._results.pop(self._emitted)
            self._emitted += 1
            self._cond.notify_all()
        # cursor = views handed to the consumer, exact for checkpointing
        self._stream.seek(self._start + self._emitted)
        return item


class Trainer:
    """Drives any GraphView iterator through a :class:`HybridParallelEngine`
    with one shape-stable, compiled-once train step.

    The step's shapes are fixed by the partition plan — ``(P, K, n_m_pad)``
    node masks, ``(P, K, e_pad)`` edge masks — so global-, mini- and
    cluster-batch views all hit the same executable. View buffers are
    donated to XLA (every step stages a fresh view, so the device-side
    mask buffers are reused in place). ``trace_counts`` records how often
    the step (and the eval ``infer``) were actually traced.
    """

    def __init__(self, engine, opt, params: Optional[Any] = None,
                 seed: int = 0, prefetch_depth: int = 2):
        self.engine = engine
        self.opt = opt
        self.plan = engine.plan
        if params is None:
            params = engine.model.init(jax.random.PRNGKey(seed),
                                       engine.sg.feature_dim)
        self.params = params
        self.opt_state = opt.init(params)
        self.step_num = 0
        self.history: list = []
        self.prefetch_depth = prefetch_depth
        self.trace_counts = {"train_step": 0, "infer": 0}
        # view-stream position (checkpointed so restore() can fast-forward
        # the stream itself instead of asking the caller to)
        self.view_cursor = 0
        self._resume_cursor: Optional[int] = None

        lg = engine.make_loss_and_grad()

        def _step(params, opt_state, data, view):
            # runs only while tracing — this is the compile counter the
            # compiled-once contract is certified against
            self.trace_counts["train_step"] += 1
            loss, grads = lg(params, data, view)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        # view buffers are donated so XLA reuses the device-side mask
        # buffers in place step over step (donation is a no-op warning on
        # the CPU backend, so only ask for it where it exists)
        self._donate_views = jax.default_backend() != "cpu"
        donate = (3,) if self._donate_views else ()
        self._step = jax.jit(_step, donate_argnums=donate)
        self._infer = engine.make_infer(on_trace=self._count_infer_trace)
        # single-slot (view, staged-arrays) cache; holding the view object
        # itself both bounds the cache and keeps the identity check sound
        # (an id() key could be reused by a garbage-collected view)
        self._eval_cache: Optional[tuple] = None

    def _count_infer_trace(self):
        self.trace_counts["infer"] += 1

    # -- the training loop ----------------------------------------------------

    def fit(self, views: Iterable[GraphView], steps: Optional[int] = None,
            prefetch: bool = True, prefetch_workers: Optional[int] = None,
            eval_every: int = 0,
            eval_view: Optional[GraphView] = None,
            eval_mask: Optional[np.ndarray] = None,
            checkpoint_every: int = 0,
            checkpoint_dir: Optional[str] = None,
            max_in_flight: int = 2,
            log_every: int = 0, log=print) -> dict:
        """Run ``steps`` views (all of ``views`` if None) through the
        compiled step. Returns ``{"losses", "evals", "steps"}``; losses
        are synced once at the end so per-step host/device overlap is
        never serialized by a blocking ``float()``.

        When ``views`` is an indexable :class:`ViewStream` (what
        ``strategy_views`` returns) and ``prefetch`` is on, view
        construction fans out over ``prefetch_workers`` builder threads —
        deterministically: the loss trajectory is bit-identical for any
        worker count and for ``prefetch=False``, because view i only
        depends on ``(seed, i)`` and views are emitted in index order.
        The default (None) leaves one core for the device executor and
        caps at 4 — ``min(4, cpu_count - 1)`` — so builder threads never
        oversubscribe the box the step runs on. Plain iterators use the
        single-thread double-buffered pipeline.

        ``max_in_flight`` bounds the async-dispatch run-ahead: before
        dispatching step *i* the loop blocks on step *i - max_in_flight*,
        so at most that many steps' view/activation buffers are live at
        once — deep run-ahead piles up device memory and (on CPU) slows
        the executor more than the overlap buys.
        """
        stage = lambda v: self.engine.stage_view(  # noqa: E731
            shard_view(self.plan, v))
        if self._donate_views:
            # donated buffers are consumed by the step — always restage
            prepare = stage
        else:
            # static streams (global batch yields one GraphView object)
            # are staged exactly once and the device buffers reused; the
            # cache holds the view itself so the identity check can't be
            # fooled by a freed view's id being reused. Multiple prefetch
            # workers may race here: staged is written BEFORE the view key
            # and misses return their locally staged value, so a racing
            # reader can at worst duplicate work, never observe a
            # half-written entry
            cache = {"view": None, "staged": None}

            def prepare(v):
                if cache["view"] is v:
                    return cache["staged"]
                staged = stage(v)
                cache["staged"] = staged
                cache["view"] = v
                return staged

        stream = views if isinstance(views, ViewStream) else None
        # any fit consumes a pending restore cursor — a plain-iterator fit
        # must not leave it armed to silently fast-forward a later,
        # unrelated stream
        resume, self._resume_cursor = self._resume_cursor, None
        if stream is not None and resume is not None \
                and stream.cursor < resume:
            # a checkpoint restore recorded where the view stream stood —
            # fast-forward the stream itself (per-index RNG makes the
            # cursor the entire stream state)
            stream.seek(resume)
        if stream is not None:
            # indexable stream: the worker pool path (workers=1 is the
            # double-buffered pipeline with exact cursor accounting)
            if prefetch:
                if prefetch_workers is None:
                    prefetch_workers = max(
                        1, min(4, (os.cpu_count() or 2) - 1))
                staged_iter = _MultiStreamPrefetcher(
                    stream, prepare, steps, workers=prefetch_workers,
                    depth=self.prefetch_depth)
            else:
                bounded = (itertools.islice(stream, steps)
                           if steps is not None else stream)
                staged_iter = (prepare(v) for v in bounded)
        else:
            if steps is not None:
                views = itertools.islice(views, steps)
            staged_iter = (_ViewPrefetcher(views, prepare,
                                           self.prefetch_depth)
                           if prefetch else (prepare(v) for v in views))

        data = self.engine._device_data
        losses, pending, evals = [], [], []
        try:
            for staged in staged_iter:
                if max_in_flight > 0 and len(pending) >= max_in_flight:
                    # backpressure: wait on the oldest in-flight step (one
                    # scalar readiness wait, not a pipeline-wide sync) and
                    # retire its loss to a host float so live device
                    # arrays stay O(max_in_flight), not O(steps)
                    losses.append(float(pending.pop(0)))
                self.params, self.opt_state, loss = self._step(
                    self.params, self.opt_state, data, staged)
                self.step_num += 1
                self.view_cursor = (stream.cursor if stream is not None
                                    else self.step_num)
                pending.append(loss)
                if (eval_every and eval_view is not None
                        and self.step_num % eval_every == 0):
                    rec = {"step": self.step_num, "loss": float(loss),
                           "eval_acc": self.evaluate(eval_view, eval_mask)}
                    evals.append(rec)
                    if log_every:
                        log(f"step {rec['step']:5d}  "
                            f"loss {rec['loss']:.4f}  "
                            f"eval_acc {rec['eval_acc']:.4f}")
                if (checkpoint_every and checkpoint_dir
                        and self.step_num % checkpoint_every == 0):
                    self.save(checkpoint_dir)
        finally:
            if isinstance(staged_iter,
                          (_ViewPrefetcher, _MultiStreamPrefetcher)):
                staged_iter.close()
        losses.extend(float(l) for l in pending)
        self.history.extend(evals)
        return {"losses": losses, "evals": evals, "steps": self.step_num}

    # -- eval / infer -----------------------------------------------------------

    def evaluate(self, view: GraphView,
                 mask: Optional[np.ndarray] = None) -> float:
        """Distributed inference over ``view`` (compiled once, shared with
        every later eval); accuracy on ``mask`` (default: the graph's test
        mask, falling back to the view's loss mask)."""
        if self._eval_cache is None or self._eval_cache[0] is not view:
            self._eval_cache = (view, shard_view(self.plan, view))
        logits = self._infer(self.params, dict(self._eval_cache[1]))
        preds = self.engine.gather_predictions(np.asarray(logits)).argmax(-1)
        g = view.graph
        if mask is None:
            mask = (g.test_mask if g.test_mask is not None
                    else view.loss_mask > 0)
        mask = np.asarray(mask) > 0
        if not mask.any():
            return 0.0
        return float((preds[mask] == g.labels[mask]).mean())

    # -- checkpointing ---------------------------------------------------------

    def save(self, directory: str) -> str:
        # view_cursor is the entire state of a per-index ViewStream (the
        # RNG stream of view i is derived from (seed, i)), so storing it
        # lets restore() fast-forward the stream itself
        return save_checkpoint(directory, self.step_num, {
            "params": self.params,
            "opt_state": self.opt_state,
            "step": np.asarray(self.step_num, np.int64),
            "view_cursor": np.asarray(self.view_cursor, np.int64),
        })

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        """Load params/opt state/step from a checkpoint. The restored
        leaves match the compiled step's signature, so resuming does not
        retrace. If the checkpoint recorded a view-stream cursor, the next
        ``fit`` over a :class:`ViewStream` fast-forwards the stream to it
        automatically; for plain iterators the returned step lets the
        caller fast-forward by hand (legacy behavior)."""
        ck = load_checkpoint(directory, step)
        self.params = ck["params"]
        self.opt_state = ck["opt_state"]
        self.step_num = int(ck["step"])
        if "view_cursor" in ck:      # older checkpoints predate the key
            self.view_cursor = int(ck["view_cursor"])
            self._resume_cursor = self.view_cursor
        return self.step_num

    # -- contracts / lifecycle ---------------------------------------------------

    def reset(self, params: Optional[Any] = None, seed: int = 0):
        """Fresh params/opt state **keeping the compiled step**, so one
        compile serves many runs (strategy comparisons reset between
        strategies and still certify compiled-once)."""
        if params is None:
            params = self.engine.model.init(jax.random.PRNGKey(seed),
                                            self.engine.sg.feature_dim)
        self.params = params
        self.opt_state = self.opt.init(params)
        self.step_num = 0
        self.history = []
        self._eval_cache = None
        self.view_cursor = 0
        self._resume_cursor = None

    def assert_compiled_once(self):
        """The trace-count contract: after any number of steps across any
        mix of strategies, the train step must have been traced exactly
        once (and the eval infer at most once). A retrace is a silent
        ~10x slowdown — fail loudly instead."""
        n = self.trace_counts["train_step"]
        if n == 0:
            raise RetraceError(
                "assert_compiled_once: the train step never ran — call "
                "fit() before asserting the contract")
        if n != 1:
            raise RetraceError(
                f"train step was traced {n} times (expected exactly 1): "
                "some input changed shape/dtype between steps — view "
                "arrays must come from shard_view over one PartitionPlan")
        if self.trace_counts["infer"] > 1:
            raise RetraceError(
                f"eval infer was traced {self.trace_counts['infer']} "
                "times (expected at most 1)")

    # -- static analysis hooks ---------------------------------------------------

    @property
    def expected_donated(self) -> int:
        """How many step invars must carry donation flags: the three view
        leaves (node_active/edge_active/loss_mask) on accelerator
        backends, none on cpu (where donation is a no-op warning)."""
        return 3 if self._donate_views else 0

    def traced_step_jaxpr(self, view: GraphView):
        """Jaxpr of the jitted train step over ``view`` — what
        ``repro.analysis`` rules walk. Tracing runs the step's Python
        body (the compile counter), so the counters are saved/restored:
        analysis must not break the compiled-once certificate."""
        staged = self.engine.stage_view(shard_view(self.plan, view))
        saved = dict(self.trace_counts)
        try:
            return jax.make_jaxpr(self._step)(
                self.params, self.opt_state, self.engine._device_data,
                staged)
        finally:
            self.trace_counts = saved

    def traced_infer_jaxpr(self, view: GraphView):
        """Jaxpr of the jitted eval/infer computation over ``view``."""
        staged = self.engine.stage_view(shard_view(self.plan, view))
        saved = dict(self.trace_counts)
        try:
            return jax.make_jaxpr(self._infer.jitted)(
                self.params, self.engine._device_data, staged)
        finally:
            self.trace_counts = saved


class CompactTrainer:
    """Single-process trainer over size-bucketed compact blocks.

    Where :class:`Trainer` fixes the step's shapes with a PartitionPlan,
    this trainer fixes them with a :class:`~repro.core.views.BucketSpec`:
    every :class:`~repro.core.views.CompactView` is staged by a
    :class:`~repro.core.views.CompactBlockBuilder` into one of a small
    fixed menu of padded ``(n_pad, e_pad)`` shapes, so device compute and
    memory scale with the *view* while the jitted step still compiles at
    most once per bucket — the bucketed analog of the compiled-once
    contract, certified by :meth:`assert_compiled_per_bucket`.

    Dense GraphViews pass straight through (full-graph shape = its own
    bucket), so the same loop drives the dense parity oracle.
    """

    def __init__(self, model, g, opt, params: Optional[Any] = None,
                 seed: int = 0, buckets=None, slots: int = 2,
                 gcn_norm: bool = True, prefetch_depth: int = 2):
        from repro.core.mpgnn import accuracy_block, loss_block
        self.model = model
        self.g = g
        self.opt = opt
        backend = getattr(model, "aggregate_backend", "reference")
        self.stager = CompactBlockBuilder(
            g, model.K, buckets=buckets, slots=slots, gcn_norm=gcn_norm,
            csc_plan=(backend == "csc"))
        self.buckets = self.stager.buckets
        if params is None:
            params = model.init(jax.random.PRNGKey(seed),
                                g.node_features.shape[1])
        self.params = params
        self.opt_state = opt.init(params)
        self.step_num = 0
        self.history: list = []
        self.prefetch_depth = prefetch_depth
        self.trace_counts = {"train_step": 0}
        # (n_pad, e_pad) shapes actually staged — the denominator of the
        # once-per-bucket contract
        self.buckets_touched: set = set()
        # staging mutates per-bucket ring buffers; prefetch workers must
        # not interleave fills (device_put copies on every backend we run,
        # so the staged block is detached before the lock releases)
        self._stage_lock = threading.Lock()

        def _step(params, opt_state, block):
            # runs only while tracing: one increment per (bucket) compile
            self.trace_counts["train_step"] += 1
            loss, grads = jax.value_and_grad(
                lambda p: loss_block(model, p, block))(params)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        # jit's signature cache keys on leaf shapes + the plan's static
        # geometry — both pure functions of the bucket, so this single
        # jitted callable holds exactly one executable per touched bucket
        self._step = jax.jit(_step)
        self._acc = jax.jit(
            lambda params, block, mask: accuracy_block(model, params,
                                                       block, mask))

    def _prepare(self, view):
        with self._stage_lock:
            block = self.stager.stage(view)
            self.buckets_touched.add((int(block.x.shape[0]),
                                      int(block.src.shape[0])))
            # the staged block aliases the builder's ring buffers (and a
            # dense view's masks alias its ViewBuilder's ring). Handing
            # those to jax directly is unsafe: the CPU backend ZERO-COPIES
            # sufficiently aligned numpy arrays, and even an explicit
            # jax-side copy materializes asynchronously — either way a
            # later fill of the same ring slot races an in-flight step's
            # input. A numpy copy is synchronous by construction, so the
            # block is detached before the lock releases.
            return jax.tree_util.tree_map(np.array, block)

    # -- the training loop ----------------------------------------------------

    def fit(self, views, steps: Optional[int] = None, prefetch: bool = True,
            prefetch_workers: Optional[int] = None, eval_every: int = 0,
            eval_view=None, eval_mask: Optional[np.ndarray] = None,
            max_in_flight: int = 2, log_every: int = 0, log=print) -> dict:
        """Run ``steps`` views through the bucketed step; same contract
        and return shape as :meth:`Trainer.fit` (losses synced at the
        end, ViewStreams get the deterministic multi-worker prefetch,
        plain iterators the double-buffered pipeline)."""
        stream = views if isinstance(views, ViewStream) else None
        if stream is not None:
            if prefetch:
                if prefetch_workers is None:
                    prefetch_workers = max(
                        1, min(4, (os.cpu_count() or 2) - 1))
                staged_iter = _MultiStreamPrefetcher(
                    stream, self._prepare, steps, workers=prefetch_workers,
                    depth=self.prefetch_depth)
            else:
                bounded = (itertools.islice(stream, steps)
                           if steps is not None else stream)
                staged_iter = (self._prepare(v) for v in bounded)
        else:
            if steps is not None:
                views = itertools.islice(views, steps)
            staged_iter = (_ViewPrefetcher(views, self._prepare,
                                           self.prefetch_depth)
                           if prefetch else
                           (self._prepare(v) for v in views))

        losses, pending, evals = [], [], []
        try:
            for staged in staged_iter:
                if max_in_flight > 0 and len(pending) >= max_in_flight:
                    losses.append(float(pending.pop(0)))
                self.params, self.opt_state, loss = self._step(
                    self.params, self.opt_state, staged)
                self.step_num += 1
                pending.append(loss)
                if (eval_every and eval_view is not None
                        and self.step_num % eval_every == 0):
                    rec = {"step": self.step_num, "loss": float(loss),
                           "eval_acc": self.evaluate(eval_view, eval_mask)}
                    evals.append(rec)
                    if log_every:
                        log(f"step {rec['step']:5d}  "
                            f"loss {rec['loss']:.4f}  "
                            f"eval_acc {rec['eval_acc']:.4f}")
        finally:
            if isinstance(staged_iter,
                          (_ViewPrefetcher, _MultiStreamPrefetcher)):
                staged_iter.close()
        losses.extend(float(l) for l in pending)
        self.history.extend(evals)
        return {"losses": losses, "evals": evals, "steps": self.step_num}

    # -- eval -------------------------------------------------------------------

    def evaluate(self, view, mask: Optional[np.ndarray] = None) -> float:
        """Accuracy over ``view``'s block (a dense GraphView stages the
        cached base block; a CompactView a tight-padded one-off)."""
        block = view.as_block(gcn_norm=self.stager.gcn_norm,
                              csc_plan=self.stager.csc_plan)
        if mask is None:
            g = view.graph
            mask = (g.test_mask if g.test_mask is not None else None)
        if mask is not None:
            flat = np.asarray(mask).astype(np.float32)
            if hasattr(view, "nodes"):   # CompactView: global -> local ids
                flat = flat[view.nodes]
            m = np.zeros(block.x.shape[0], np.float32)
            m[:len(flat)] = flat
        else:
            m = block.loss_mask
        return float(self._acc(self.params, block, m))

    # -- contracts / lifecycle ---------------------------------------------------

    def reset(self, params: Optional[Any] = None, seed: int = 0):
        """Fresh params/opt state keeping the per-bucket compiled steps."""
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed),
                                     self.g.node_features.shape[1])
        self.params = params
        self.opt_state = self.opt.init(params)
        self.step_num = 0
        self.history = []

    def assert_compiled_per_bucket(self):
        """The bucketed trace-count contract: the step must have been
        traced exactly once per *touched* bucket shape — repeat epochs
        over the same buckets add zero traces."""
        n = self.trace_counts["train_step"]
        touched = len(self.buckets_touched)
        if touched == 0:
            raise RetraceError(
                "assert_compiled_per_bucket: the train step never ran — "
                "call fit() before asserting the contract")
        if n != touched:
            raise RetraceError(
                f"train step was traced {n} times over {touched} touched "
                f"bucket shapes (expected exactly one trace per bucket): "
                "a view was staged with a shape or plan geometry not "
                "determined by its bucket")

    # -- static analysis hooks ---------------------------------------------------

    def traced_step_jaxpr(self, view):
        """Jaxpr of the bucketed step over ``view``'s staged block — what
        the O(view) compact-step rules walk. Staging and tracing both
        perturb the contract counters (buckets_touched / trace_counts),
        so they are saved and restored: analysis must not change the
        once-per-bucket certificate."""
        saved_counts = dict(self.trace_counts)
        saved_buckets = set(self.buckets_touched)
        try:
            block = self._prepare(view)
            return jax.make_jaxpr(self._step)(
                self.params, self.opt_state, block)
        finally:
            self.trace_counts = saved_counts
            self.buckets_touched = saved_buckets
