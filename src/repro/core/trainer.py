"""Compiled-once strategy Trainer with a host-side view prefetch pipeline.

The paper's training strategies (global-, mini-, cluster-batch, §2.3/§4.3)
all reduce to streams of :class:`GraphView` masks over one partitioned
graph, so a single jitted train step — whose shapes are fixed by the
:class:`PartitionPlan`, not by the view — serves every strategy. At scale
the bottleneck is not the device math but the host-side batch preparation
(DistDGL's observation); the Trainer attacks it on three fronts:

1. **Vectorized sharding** — views are mapped onto the plan with the
   ``np.take``-based :func:`repro.core.strategies.shard_view` (O(1) Python
   per step instead of a per-partition loop).
2. **Double-buffered prefetch** — a daemon thread builds and
   ``device_put``\\ s the view arrays for step *i+1* while step *i* runs on
   the devices, so host work and device compute overlap.
3. **Compiled-once contract** — the jitted step donates its view buffers
   and carries a compile counter; :meth:`Trainer.assert_compiled_once`
   turns a silent retrace (a 10x regression in disguise) into a hard
   failure. CI asserts it across all three strategies
   (``benchmarks/strategies_bench.py --smoke``).

Periodic evaluation runs through the engine's (equally compiled-once)
distributed ``infer``; checkpoints go through
:mod:`repro.checkpoint.store` and restores resume mid-stream without
triggering a retrace.

Usage::

    engine = HybridParallelEngine(model, build_partitions(g, P))
    trainer = Trainer(engine, adam(1e-2), seed=0)
    trainer.fit(strategy_views(g, "cluster", K=2), steps=200,
                eval_every=50, eval_view=global_batch_view(g, 2))
    trainer.assert_compiled_once()
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Any, Iterable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.strategies import GraphView, shard_view


class RetraceError(AssertionError):
    """The compiled-once contract was broken (or never exercised)."""


class _ViewPrefetcher:
    """Double-buffered host pipeline.

    A daemon thread pulls GraphViews from the iterator, runs ``prepare``
    (vectorized ``shard_view`` + ``device_put``) and parks up to ``depth``
    staged views in a bounded queue, so staging for step *i+1* overlaps
    device compute for step *i*. Exceptions in the thread re-raise in the
    consumer; exhaustion is signalled with a sentinel.
    """

    _END = object()

    def __init__(self, views: Iterable[GraphView], prepare, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._cancel = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(views, prepare), daemon=True,
            name="view-prefetch")
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded put that gives up when the consumer cancelled (so an
        abandoned fit can't leave the thread pinning staged buffers)."""
        while not self._cancel.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, views, prepare):
        try:
            for v in views:
                if self._cancel.is_set() or not self._put(prepare(v)):
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced in __next__
            self._err = e
        finally:
            self._put(self._END)

    def close(self):
        """Unblock and retire the producer thread; staged-but-unconsumed
        views are dropped."""
        self._cancel.set()
        while True:   # drain so a blocked _put wakes immediately
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._END:
            self._thread.join()
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class Trainer:
    """Drives any GraphView iterator through a :class:`HybridParallelEngine`
    with one shape-stable, compiled-once train step.

    The step's shapes are fixed by the partition plan — ``(P, K, n_m_pad)``
    node masks, ``(P, K, e_pad)`` edge masks — so global-, mini- and
    cluster-batch views all hit the same executable. View buffers are
    donated to XLA (every step stages a fresh view, so the device-side
    mask buffers are reused in place). ``trace_counts`` records how often
    the step (and the eval ``infer``) were actually traced.
    """

    def __init__(self, engine, opt, params: Optional[Any] = None,
                 seed: int = 0, prefetch_depth: int = 2):
        self.engine = engine
        self.opt = opt
        self.plan = engine.plan
        if params is None:
            params = engine.model.init(jax.random.PRNGKey(seed),
                                       engine.sg.feature_dim)
        self.params = params
        self.opt_state = opt.init(params)
        self.step_num = 0
        self.history: list = []
        self.prefetch_depth = prefetch_depth
        self.trace_counts = {"train_step": 0, "infer": 0}

        lg = engine.make_loss_and_grad()

        def _step(params, opt_state, data, view):
            # runs only while tracing — this is the compile counter the
            # compiled-once contract is certified against
            self.trace_counts["train_step"] += 1
            loss, grads = lg(params, data, view)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        # view buffers are donated so XLA reuses the device-side mask
        # buffers in place step over step (donation is a no-op warning on
        # the CPU backend, so only ask for it where it exists)
        self._donate_views = jax.default_backend() != "cpu"
        donate = (3,) if self._donate_views else ()
        self._step = jax.jit(_step, donate_argnums=donate)
        self._infer = engine.make_infer(on_trace=self._count_infer_trace)
        # single-slot (view, staged-arrays) cache; holding the view object
        # itself both bounds the cache and keeps the identity check sound
        # (an id() key could be reused by a garbage-collected view)
        self._eval_cache: Optional[tuple] = None

    def _count_infer_trace(self):
        self.trace_counts["infer"] += 1

    # -- the training loop ----------------------------------------------------

    def fit(self, views: Iterable[GraphView], steps: Optional[int] = None,
            prefetch: bool = True, eval_every: int = 0,
            eval_view: Optional[GraphView] = None,
            eval_mask: Optional[np.ndarray] = None,
            checkpoint_every: int = 0,
            checkpoint_dir: Optional[str] = None,
            max_in_flight: int = 2,
            log_every: int = 0, log=print) -> dict:
        """Run ``steps`` views (all of ``views`` if None) through the
        compiled step. Returns ``{"losses", "evals", "steps"}``; losses
        are synced once at the end so per-step host/device overlap is
        never serialized by a blocking ``float()``.

        ``max_in_flight`` bounds the async-dispatch run-ahead: before
        dispatching step *i* the loop blocks on step *i - max_in_flight*,
        so at most that many steps' view/activation buffers are live at
        once — deep run-ahead piles up device memory and (on CPU) slows
        the executor more than the overlap buys.
        """
        if steps is not None:
            views = itertools.islice(views, steps)
        stage = lambda v: self.engine.stage_view(  # noqa: E731
            shard_view(self.plan, v))
        if self._donate_views:
            # donated buffers are consumed by the step — always restage
            prepare = stage
        else:
            # static streams (global batch yields one GraphView object)
            # are staged exactly once and the device buffers reused; the
            # cache holds the view itself so the identity check can't be
            # fooled by a freed view's id being reused
            cache = {"view": None, "staged": None}

            def prepare(v):
                if cache["view"] is not v:
                    cache["view"], cache["staged"] = v, stage(v)
                return cache["staged"]

        staged_iter = (_ViewPrefetcher(views, prepare, self.prefetch_depth)
                       if prefetch else (prepare(v) for v in views))

        data = self.engine._device_data
        losses, pending, evals = [], [], []
        try:
            for staged in staged_iter:
                if max_in_flight > 0 and len(pending) >= max_in_flight:
                    # backpressure: wait on the oldest in-flight step (one
                    # scalar readiness wait, not a pipeline-wide sync) and
                    # retire its loss to a host float so live device
                    # arrays stay O(max_in_flight), not O(steps)
                    losses.append(float(pending.pop(0)))
                self.params, self.opt_state, loss = self._step(
                    self.params, self.opt_state, data, staged)
                self.step_num += 1
                pending.append(loss)
                if (eval_every and eval_view is not None
                        and self.step_num % eval_every == 0):
                    rec = {"step": self.step_num, "loss": float(loss),
                           "eval_acc": self.evaluate(eval_view, eval_mask)}
                    evals.append(rec)
                    if log_every:
                        log(f"step {rec['step']:5d}  "
                            f"loss {rec['loss']:.4f}  "
                            f"eval_acc {rec['eval_acc']:.4f}")
                if (checkpoint_every and checkpoint_dir
                        and self.step_num % checkpoint_every == 0):
                    self.save(checkpoint_dir)
        finally:
            if isinstance(staged_iter, _ViewPrefetcher):
                staged_iter.close()
        losses.extend(float(l) for l in pending)
        self.history.extend(evals)
        return {"losses": losses, "evals": evals, "steps": self.step_num}

    # -- eval / infer -----------------------------------------------------------

    def evaluate(self, view: GraphView,
                 mask: Optional[np.ndarray] = None) -> float:
        """Distributed inference over ``view`` (compiled once, shared with
        every later eval); accuracy on ``mask`` (default: the graph's test
        mask, falling back to the view's loss mask)."""
        if self._eval_cache is None or self._eval_cache[0] is not view:
            self._eval_cache = (view, shard_view(self.plan, view))
        logits = self._infer(self.params, dict(self._eval_cache[1]))
        preds = self.engine.gather_predictions(np.asarray(logits)).argmax(-1)
        g = view.graph
        if mask is None:
            mask = (g.test_mask if g.test_mask is not None
                    else view.loss_mask > 0)
        mask = np.asarray(mask) > 0
        if not mask.any():
            return 0.0
        return float((preds[mask] == g.labels[mask]).mean())

    # -- checkpointing ---------------------------------------------------------

    def save(self, directory: str) -> str:
        return save_checkpoint(directory, self.step_num, {
            "params": self.params,
            "opt_state": self.opt_state,
            "step": np.asarray(self.step_num, np.int64),
        })

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        """Load params/opt state/step from a checkpoint. The restored
        leaves match the compiled step's signature, so resuming does not
        retrace. Returns the restored step so the caller can fast-forward
        its view iterator (view streams are host-side state)."""
        ck = load_checkpoint(directory, step)
        self.params = ck["params"]
        self.opt_state = ck["opt_state"]
        self.step_num = int(ck["step"])
        return self.step_num

    # -- contracts / lifecycle ---------------------------------------------------

    def reset(self, params: Optional[Any] = None, seed: int = 0):
        """Fresh params/opt state **keeping the compiled step**, so one
        compile serves many runs (strategy comparisons reset between
        strategies and still certify compiled-once)."""
        if params is None:
            params = self.engine.model.init(jax.random.PRNGKey(seed),
                                            self.engine.sg.feature_dim)
        self.params = params
        self.opt_state = self.opt.init(params)
        self.step_num = 0
        self.history = []
        self._eval_cache = None

    def assert_compiled_once(self):
        """The trace-count contract: after any number of steps across any
        mix of strategies, the train step must have been traced exactly
        once (and the eval infer at most once). A retrace is a silent
        ~10x slowdown — fail loudly instead."""
        n = self.trace_counts["train_step"]
        if n == 0:
            raise RetraceError(
                "assert_compiled_once: the train step never ran — call "
                "fit() before asserting the contract")
        if n != 1:
            raise RetraceError(
                f"train step was traced {n} times (expected exactly 1): "
                "some input changed shape/dtype between steps — view "
                "arrays must come from shard_view over one PartitionPlan")
        if self.trace_counts["infer"] > 1:
            raise RetraceError(
                f"eval infer was traced {self.trace_counts['infer']} "
                "times (expected at most 1)")
