# The paper's primary contribution: NN-TGAR + hybrid-parallel distributed
# graph training engine with flexible training strategies.
from repro.core.aggregate import (
    COMBINE_SPECS, AggregationBackend, CombineSpec, ShardContext, combine,
    get_backend, register_backend,
)
from repro.core.tgar import (
    TGARLayer, segment_sum, segment_mean, segment_max, segment_softmax,
)
from repro.core.mpgnn import MPGNNModel, forward_block, loss_block
from repro.core.partition import (
    PartitionPlan, ShardedGraph, build_partitions, partition_stats,
)
from repro.core.strategies import (
    GraphView, global_batch_view, mini_batch_views, cluster_batch_views,
    shard_view, shard_view_loop, strategy_views,
)
from repro.core.views import (
    ClusterViewCache, ClusterViewStream, GlobalViewStream,
    MiniBatchViewStream, ViewBuilder, ViewStream, cluster_view_recompute,
)
from repro.core.subgraph import (
    khop_subgraph_view, bfs_layers, bfs_layers_loop,
)
from repro.core.clustering import label_propagation_clusters, hash_clusters
from repro.core.engine import HybridParallelEngine
from repro.core.trainer import RetraceError, Trainer

__all__ = [k for k in dir() if not k.startswith("_")]
