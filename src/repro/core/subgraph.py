"""Distributed-subgraph abstraction: BFS k-hop exploration + active sets.

The paper (§4.2) constructs subgraphs by breadth-first traversal from the
target nodes and "initializes a minimal number of layers per node" — i.e.
each node participates only in the layers its distance from the targets
requires. We materialize that as per-layer *active sets* over the global
node/edge arrays (the paper's "active status of nodes and edges", §1
challenge 3): memory O(K·N) bits, no subgraph copy-out, and the global
CSR/CSC indexing is reused exactly as §4.2 prescribes (vertex-ID mapping =
identity here because we never re-index).

Optional random neighbor sampling (GraphSAGE-style) caps fan-in per hop —
the paper implements it but champions the non-sampling path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.csr import Graph


def bfs_layers(g: Graph, targets: np.ndarray, depth: int,
               neighbor_cap: int = 0, rng: Optional[np.random.Generator] = None):
    """Hop sets [S_0=targets, S_1, ..., S_depth] where S_k = nodes at <=k
    hops following *incoming* edges (messages flow src->dst, so computing
    h^K on targets needs h^{K-1} on their in-neighbors, etc.).

    neighbor_cap > 0 samples at most that many in-neighbors per node per
    hop (random neighbor sampling [31]).
    """
    indptr, order = g.csc()            # incoming edges per node
    src = g.src
    frontier = np.unique(targets).astype(np.int64)
    visited = np.zeros(g.num_nodes, bool)
    visited[frontier] = True
    hops = [frontier]
    reached = frontier
    for _ in range(depth):
        nbrs = []
        for u in reached:
            eids = order[indptr[u]:indptr[u + 1]]
            if neighbor_cap and len(eids) > neighbor_cap:
                assert rng is not None
                eids = rng.choice(eids, neighbor_cap, replace=False)
            nbrs.append(src[eids])
        new = (np.unique(np.concatenate(nbrs)) if nbrs
               else np.zeros(0, np.int64))
        new = new[~visited[new]]
        visited[new] = True
        hops.append(np.union1d(hops[-1], new))
        reached = new
        if len(new) == 0:
            # keep remaining hop sets constant
            for _ in range(depth - len(hops) + 1):
                hops.append(hops[-1])
            break
    return hops, visited


def khop_subgraph_view(g: Graph, targets: np.ndarray, K: int,
                       neighbor_cap: int = 0,
                       rng: Optional[np.random.Generator] = None):
    """Per-layer active sets for a K-layer GNN computing loss on targets.

    Returns (node_active (K, N) f32, edge_active (K, E) f32,
    loss_mask (N,) f32, subgraph_nodes (bool N)).

    Layer k (0-based, output = h^{k+1}) must produce embeddings for nodes
    within K-1-k hops of the targets; its active edges are those whose dst
    is in that set and whose src is within one more hop.
    """
    hops, visited = bfs_layers(g, targets, K, neighbor_cap, rng)
    N, E = g.num_nodes, g.num_edges
    node_active = np.zeros((K, N), np.float32)
    edge_active = np.zeros((K, E), np.float32)
    in_hop = np.zeros((K + 1, N), bool)
    for d in range(K + 1):
        in_hop[d, hops[min(d, len(hops) - 1)]] = True
    for k in range(K):
        out_set = in_hop[K - 1 - k]          # nodes whose h^{k+1} is needed
        src_set = in_hop[K - k]              # their in-neighborhood
        node_active[k, out_set] = 1.0
        edge_active[k] = (out_set[g.dst] & src_set[g.src]).astype(np.float32)
    loss_mask = np.zeros(N, np.float32)
    loss_mask[np.unique(targets)] = 1.0
    return node_active, edge_active, loss_mask, visited


def subgraph_size_stats(g: Graph, targets: np.ndarray, K: int) -> dict:
    """Paper §1: subgraph explosion metrics (fraction of graph touched)."""
    hops, visited = bfs_layers(g, targets, K)
    return {
        "targets": int(len(np.unique(targets))),
        "touched_nodes": int(visited.sum()),
        "touched_frac": float(visited.sum() / g.num_nodes),
        "hop_sizes": [int(len(h)) for h in hops],
    }
