"""Distributed-subgraph abstraction: BFS k-hop exploration + active sets.

The paper (§4.2) constructs subgraphs by breadth-first traversal from the
target nodes and "initializes a minimal number of layers per node" — i.e.
each node participates only in the layers its distance from the targets
requires. We materialize that as per-layer *active sets* over the global
node/edge arrays (the paper's "active status of nodes and edges", §1
challenge 3): memory O(K·N) bits, no subgraph copy-out, and the global
CSR/CSC indexing is reused exactly as §4.2 prescribes (vertex-ID mapping =
identity here because we never re-index).

Frontier expansion is fully vectorized (the host-side hot path of
mini-batch view construction — the bottleneck DistDGL attacks with
dedicated samplers): all out-slices of the frontier are expanded in one
``np.repeat`` over the CSC indptr degree counts, dedup runs through a
boolean visited array instead of per-hop ``np.unique``/``np.union1d``, and
the optional per-node neighbor cap (GraphSAGE-style sampling [31]) is a
single segment-ranked draw over the expanded edge slots. The original
per-node Python loop survives as :func:`bfs_layers_loop`, the parity
oracle (tests assert bit-exact hop sets for the non-sampling path).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.csr import Graph


def _require_rng(neighbor_cap: int, rng) -> None:
    """``neighbor_cap`` sampling without a Generator used to be a bare
    ``assert`` — which vanishes under ``python -O`` and then crashes (or
    silently mis-samples) deep inside the hop loop. Fail loudly up front."""
    if neighbor_cap and rng is None:
        raise ValueError(
            "neighbor_cap sampling needs an explicit numpy Generator: "
            "pass rng=np.random.default_rng(seed) (a hidden default would "
            "make view streams non-reproducible)")


def bfs_layers(g: Graph, targets: np.ndarray, depth: int,
               neighbor_cap: int = 0,
               rng: Optional[np.random.Generator] = None,
               _visited_out: Optional[np.ndarray] = None):
    """Hop sets [S_0=targets, S_1, ..., S_depth] where S_k = nodes at <=k
    hops following *incoming* edges (messages flow src->dst, so computing
    h^K on targets needs h^{K-1} on their in-neighbors, etc.).

    neighbor_cap > 0 samples at most that many in-neighbors per node per
    hop (random neighbor sampling [31]); requires ``rng``.

    Vectorized: per hop, one CSR-segment expansion of every frontier
    out-slice (``np.repeat`` over degree counts) and boolean-array dedup.
    Bit-exact with :func:`bfs_layers_loop` when ``neighbor_cap == 0``
    (with a cap both draw different — equally valid — samples).
    ``_visited_out`` lets callers (ViewBuilder) supply a reusable (N,)
    bool scratch instead of a fresh allocation.
    """
    _require_rng(neighbor_cap, rng)
    indptr, order = g.csc()            # incoming edges per node
    src = g.src
    frontier = np.unique(targets).astype(np.int64)
    if _visited_out is not None:
        visited = _visited_out
        visited.fill(False)
    else:
        # documented caller-owned-scratch fallback: one O(N) allocation
        # per call when no scratch is supplied
        visited = np.zeros(g.num_nodes, bool)  # lint: waive=src.hot-full-graph-alloc
    visited[frontier] = True
    hops = [frontier]
    reached = frontier
    for _ in range(depth):
        eidx = _expand_frontier(indptr, order, reached, neighbor_cap, rng)
        if len(eidx):
            # O(view) dedup: unique sorts the candidates, so the fresh
            # set comes out ascending exactly like the old full-width
            # flatnonzero — without a per-hop (N,) mask allocation
            cand = np.unique(src[eidx]).astype(np.int64)
            new = cand[~visited[cand]]
            visited[new] = True
        else:
            new = np.zeros(0, np.int64)
        # hops[-1] ∪ new == all visited so far, already sorted
        hops.append(np.flatnonzero(visited))
        reached = new
        if len(new) == 0:
            # keep remaining hop sets constant
            for _ in range(depth - len(hops) + 1):
                hops.append(hops[-1])
            break
    return hops, visited


def bfs_layers_fresh(g: Graph, targets: np.ndarray, depth: int,
                     neighbor_cap: int = 0,
                     rng: Optional[np.random.Generator] = None,
                     stamp: Optional[np.ndarray] = None,
                     stamp_val: int = 0):
    """Fresh-per-hop node sets ``[F_0=targets, F_1, ..., F_depth]`` where
    F_d holds the nodes *first* reached at hop d (sorted) — the hop-ordered
    relabeling a :class:`repro.core.views.CompactView` is built from.

    Unlike :func:`bfs_layers` this never materializes a full-width array
    per hop: dedup runs through ``np.unique`` over the expanded candidates
    plus a caller-owned **stamp** array (``stamp[v] == stamp_val`` marks v
    visited in *this* build), so per-view host work is O(view edges), not
    O(K·N). The cumulative union of F_0..F_d is bit-identical to
    ``bfs_layers``' ``hops[d]``, and with a ``neighbor_cap`` both consume
    the exact same rng draws — sampled sets match bit-for-bit.

    ``stamp`` defaults to a fresh (N,) array (one O(N) allocation); reuse
    it across builds with a fresh ``stamp_val`` each time to amortize.
    """
    _require_rng(neighbor_cap, rng)
    indptr, order = g.csc()
    src = g.src
    if stamp is None:
        # documented caller-owned-scratch fallback (see docstring)
        stamp = np.full(g.num_nodes, -1, np.int64)  # lint: waive=src.hot-full-graph-alloc
        stamp_val = 0
    frontier = np.unique(targets).astype(np.int64)
    stamp[frontier] = stamp_val
    fresh = [frontier]
    reached = frontier
    for _ in range(depth):
        eidx = _expand_frontier(indptr, order, reached, neighbor_cap, rng)
        if len(eidx):
            cand = src[eidx]
            new = np.unique(cand[stamp[cand] != stamp_val]).astype(np.int64)
        else:
            new = np.zeros(0, np.int64)
        stamp[new] = stamp_val
        fresh.append(new)
        reached = new
        if len(new) == 0:
            # keep remaining fresh sets empty (hop sets stalled)
            for _ in range(depth - len(fresh) + 1):
                fresh.append(np.zeros(0, np.int64))
            break
    return fresh, stamp


def stamped_in_edges(g: Graph, dst_nodes: np.ndarray, stamp: np.ndarray,
                     stamp_val: int) -> np.ndarray:
    """Global edge ids of every in-edge of ``dst_nodes`` whose src is
    stamped (``stamp[src] == stamp_val``), grouped by ``dst_nodes`` order.
    O(in-edges of dst_nodes) — the compact view's edge-extraction pass.

    The src filter is what makes neighbor-capped compact views match the
    dense masks: a sampled view's edge set is {(u, v) : v within K-1 hops,
    u *visited*}, and with a cap some in-neighbors of v were never
    sampled."""
    indptr, order = g.csc()
    eidx = _expand_frontier(indptr, order, dst_nodes, 0, None)
    if len(eidx) == 0:
        return eidx
    return eidx[stamp[g.src[eidx]] == stamp_val]


def _expand_frontier(indptr: np.ndarray, order: np.ndarray,
                     reached: np.ndarray, neighbor_cap: int,
                     rng) -> np.ndarray:
    """Edge ids (into the global edge arrays) of every incoming edge of
    ``reached``, expanded in one shot: ``np.repeat`` of the per-node slice
    starts over the degree counts plus an arange ramp. With a cap, each
    node keeps the ``cap`` smallest of per-slot uniform keys — a
    without-replacement sample per segment, drawn for all segments in one
    ``rng.random`` call."""
    if len(reached) == 0:
        return np.zeros(0, np.int32)
    starts = indptr[reached]
    degs = indptr[reached + 1] - starts
    total = int(degs.sum())
    if total == 0:
        return np.zeros(0, np.int32)
    cum = np.cumsum(degs)
    seg_off = np.repeat(cum - degs, degs)        # expanded segment starts
    pos = np.arange(total, dtype=np.int64)
    idx = pos - seg_off + np.repeat(starts, degs)
    if neighbor_cap:
        keys = rng.random(total)
        seg_ids = np.repeat(np.arange(len(reached), dtype=np.int64), degs)
        sorter = np.lexsort((keys, seg_ids))
        # segments stay contiguous at the same offsets after the sort, so
        # sorted position p has within-segment rank p - seg_off[p]
        rank = pos - seg_off
        idx = idx[sorter[rank < neighbor_cap]]
    return order[idx]


def bfs_layers_loop(g: Graph, targets: np.ndarray, depth: int,
                    neighbor_cap: int = 0,
                    rng: Optional[np.random.Generator] = None):
    """Reference per-node Python loop implementation of
    :func:`bfs_layers` — the parity oracle (tests assert bit-exact hop
    sets and masks) and the host-path baseline timed by
    ``benchmarks/strategies_bench.py view_build``."""
    _require_rng(neighbor_cap, rng)
    indptr, order = g.csc()
    src = g.src
    frontier = np.unique(targets).astype(np.int64)
    visited = np.zeros(g.num_nodes, bool)
    visited[frontier] = True
    hops = [frontier]
    reached = frontier
    for _ in range(depth):
        nbrs = []
        for u in reached:
            eids = order[indptr[u]:indptr[u + 1]]
            if neighbor_cap and len(eids) > neighbor_cap:
                eids = rng.choice(eids, neighbor_cap, replace=False)
            nbrs.append(src[eids])
        new = (np.unique(np.concatenate(nbrs)) if nbrs
               else np.zeros(0, np.int64))
        new = new[~visited[new]]
        visited[new] = True
        hops.append(np.union1d(hops[-1], new))
        reached = new
        if len(new) == 0:
            # keep remaining hop sets constant
            for _ in range(depth - len(hops) + 1):
                hops.append(hops[-1])
            break
    return hops, visited


def fill_khop_masks(g: Graph, hops, K: int, node_active: np.ndarray,
                    edge_active: np.ndarray,
                    in_hop: Optional[np.ndarray] = None) -> None:
    """Write the per-layer active masks derived from BFS ``hops`` into the
    caller-owned ``(K, N)``/``(K, E)`` float32 buffers (zeroed here — the
    ViewBuilder reuses its buffers across steps, so no fresh allocations).

    Layer k (0-based, output = h^{k+1}) must produce embeddings for nodes
    within K-1-k hops of the targets; its active edges are those whose dst
    is in that set and whose src is within one more hop.
    """
    N = g.num_nodes
    if in_hop is None:
        # documented caller-owned-scratch fallback (the ViewBuilder
        # passes its reusable (K+1, N) buffer)
        in_hop = np.zeros((K + 1, N), bool)  # lint: waive=src.hot-full-graph-alloc
    else:
        in_hop.fill(False)
    for d in range(K + 1):
        in_hop[d, hops[min(d, len(hops) - 1)]] = True
    node_active.fill(0.0)
    edge_active.fill(0.0)
    for k in range(K):
        out_set = in_hop[K - 1 - k]          # nodes whose h^{k+1} is needed
        src_set = in_hop[K - k]              # their in-neighborhood
        node_active[k, out_set] = 1.0
        edge_active[k] = out_set[g.dst] & src_set[g.src]


def khop_subgraph_view(g: Graph, targets: np.ndarray, K: int,
                       neighbor_cap: int = 0,
                       rng: Optional[np.random.Generator] = None,
                       _bfs=None):
    """Per-layer active sets for a K-layer GNN computing loss on targets.

    Returns (node_active (K, N) f32, edge_active (K, E) f32,
    loss_mask (N,) f32, subgraph_nodes (bool N)).

    ``_bfs`` swaps the frontier-expansion implementation (the bench times
    :func:`bfs_layers_loop` through it); allocation-free repeated
    construction goes through :class:`repro.core.views.ViewBuilder`.
    """
    hops, visited = (_bfs or bfs_layers)(g, targets, K, neighbor_cap, rng)
    N, E = g.num_nodes, g.num_edges
    node_active = np.zeros((K, N), np.float32)
    edge_active = np.zeros((K, E), np.float32)
    fill_khop_masks(g, hops, K, node_active, edge_active)
    loss_mask = np.zeros(N, np.float32)
    loss_mask[np.unique(targets)] = 1.0
    return node_active, edge_active, loss_mask, visited


def subgraph_size_stats(g: Graph, targets: np.ndarray, K: int) -> dict:
    """Paper §1: subgraph explosion metrics (fraction of graph touched)."""
    hops, visited = bfs_layers(g, targets, K)
    return {
        "targets": int(len(np.unique(targets))),
        "touched_nodes": int(visited.sum()),
        "touched_frac": float(visited.sum() / g.num_nodes),
        "hop_sizes": [int(len(h)) for h in hops],
    }
