"""Distributed graph representation (paper §4.1).

Nodes are distributed evenly; each edge is assigned to one partition; a
node owned elsewhere but referenced locally becomes a **mirror** — a
placeholder holding *no values* (the paper's replica-factor-1 claim): the
halo exchange materializes a compact ``(n_mirror, d)`` buffer per layer,
synchronizing only the masters a layer actually uses.

Partitioning methods (§5.4):
- ``1d_src`` (default) — edge goes to the owner of its source node (master
  node and all its out-edges colocated: edge attributes/attention local).
- ``1d_dst`` — by destination owner.
- ``vertex_cut`` — 2D grid hash over (src, dst) (PowerGraph-style), which
  balances edges on skewed graphs at the cost of replication.

The exchange plan is precomputed dense numpy (static shapes for JIT):
``send_idx[p, q, i]`` = local master slot on p of the i-th value p sends to
q; ``recv_slot[q, p, i]`` = the mirror slot on q where it lands. The engine
executes the plan with ``lax.all_to_all`` inside ``shard_map``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.graph.csr import Graph


def _round_up(x: int, m: int = 8) -> int:
    return max(m, ((x + m - 1) // m) * m)


@dataclass
class PartitionPlan:
    P: int
    method: str
    owner: np.ndarray                 # (N,) int32 node -> partition
    masters: np.ndarray               # (P, n_m_pad) int32 global node ids
    master_mask: np.ndarray           # (P, n_m_pad) f32
    mirrors: np.ndarray               # (P, n_mir_pad) int32 global node ids
    mirror_mask: np.ndarray           # (P, n_mir_pad) f32
    src_local: np.ndarray             # (P, e_pad) int32 into [masters;mirrors]
    dst_local: np.ndarray             # (P, e_pad) int32
    edge_mask: np.ndarray             # (P, e_pad) f32
    edge_orig: np.ndarray             # (P, e_pad) int32 global edge ids
    send_idx: np.ndarray              # (P, P, s_pad) int32 master slots
    send_mask: np.ndarray             # (P, P, s_pad) f32
    recv_slot: np.ndarray             # (P, P, s_pad) int32 mirror slots
    recv_mask: np.ndarray             # (P, P, s_pad) f32
    # per-shard CSCPlans for the "csc" aggregation backend, cached by
    # (block_n, block_e) — built once per partitioning, reused by every
    # batch/view the engine stages (paper §4.2 reused indexing)
    _csc_plans: dict = field(default_factory=dict, repr=False)
    # cached inverse maps (global id -> local slot), built on first use by
    # the compact shard path (shard_view over CompactView scatters a few
    # thousand ids instead of gathering all N / all E per step)
    _locators: dict = field(default_factory=dict, repr=False)

    @property
    def n_m_pad(self) -> int:
        return int(self.masters.shape[1])

    @property
    def n_mir_pad(self) -> int:
        return int(self.mirrors.shape[1])

    @property
    def e_pad(self) -> int:
        return int(self.src_local.shape[1])

    @property
    def s_pad(self) -> int:
        return int(self.send_idx.shape[2])

    def csc_plans(self, block_n: int = 128, block_e: int = 256):
        """One CSCPlan per partition over its local destination ids
        (segments = the shard's [masters ; mirrors] axis), all with
        identical padded shapes so the engine can stack them (P, nb, L)
        and shard them over the worker group. The stacked index arrays
        are exactly what the fused-gather kernels scalar-prefetch — the
        shard's raw edge messages are never re-laid-out on device."""
        key = (block_n, block_e)
        if key not in self._csc_plans:
            from repro.kernels.ops import build_csc_plans_stacked
            n_tot = self.n_m_pad + self.n_mir_pad
            self._csc_plans[key] = build_csc_plans_stacked(
                self.dst_local, n_tot, block_n, block_e)
        return self._csc_plans[key]

    def node_locator(self) -> np.ndarray:
        """(N,) int64: master slot of each global node on its owner
        partition (``masters[owner[v], node_locator()[v]] == v``)."""
        if "node" not in self._locators:
            valid = self.master_mask > 0
            cols = np.broadcast_to(
                np.arange(self.n_m_pad, dtype=np.int64),
                self.masters.shape)
            slot = np.zeros(int(self.masters.max()) + 1, np.int64)
            slot[self.masters[valid].astype(np.int64)] = cols[valid]
            self._locators["node"] = slot
        return self._locators["node"]

    def edge_locator(self):
        """(part, slot): for each global edge id, its partition and edge
        slot there (``edge_orig[part[e], slot[e]] == e``)."""
        if "edge" not in self._locators:
            valid = self.edge_mask > 0
            M = int(self.edge_orig[valid].max()) + 1 if valid.any() else 1
            part = np.zeros(M, np.int64)
            slot = np.zeros(M, np.int64)
            rows = np.broadcast_to(
                np.arange(self.P, dtype=np.int64)[:, None],
                self.edge_orig.shape)
            cols = np.broadcast_to(
                np.arange(self.e_pad, dtype=np.int64),
                self.edge_orig.shape)
            ids = self.edge_orig[valid].astype(np.int64)
            part[ids] = rows[valid]
            slot[ids] = cols[valid]
            self._locators["edge"] = (part, slot)
        return self._locators["edge"]


@dataclass
class ShardedGraph:
    """Per-partition node/edge data, stacked over the partition axis."""
    plan: PartitionPlan
    x: np.ndarray                     # (P, n_m_pad, F)
    y: np.ndarray                     # (P, n_m_pad) int32
    edge_weight: np.ndarray           # (P, e_pad) f32
    edge_attr: Optional[np.ndarray]   # (P, e_pad, Fe) or None
    feature_dim: int


def build_partitions(g: Graph, P: int, method: str = "1d_src",
                     seed: int = 0, gcn_norm: bool = True
                     ) -> ShardedGraph:
    rng = np.random.default_rng(seed)
    N, M = g.num_nodes, g.num_edges

    # ---- master assignment: even split of a shuffled permutation ----------
    perm = rng.permutation(N)
    owner = np.empty(N, np.int32)
    owner[perm] = np.arange(N) % P

    # ---- edge assignment ----------------------------------------------------
    if method == "1d_src":
        e_part = owner[g.src]
    elif method == "1d_dst":
        e_part = owner[g.dst]
    elif method == "vertex_cut":
        r = int(np.floor(np.sqrt(P)))
        while P % r:
            r -= 1
        c = P // r
        hs = (g.src.astype(np.int64) * 2654435761 % (1 << 31)) % r
        hd = (g.dst.astype(np.int64) * 40503 % (1 << 31)) % c
        e_part = (hs * c + hd).astype(np.int32)
    else:
        raise ValueError(f"unknown partition method {method!r}")

    # ---- per-partition locals ----------------------------------------------
    masters_l, mirrors_l, edges_l = [], [], []
    for p in range(P):
        m_nodes = np.where(owner == p)[0].astype(np.int64)
        eids = np.where(e_part == p)[0].astype(np.int64)
        endpoints = np.unique(np.concatenate([g.src[eids], g.dst[eids]]))
        mir = endpoints[owner[endpoints] != p]
        masters_l.append(m_nodes)
        mirrors_l.append(np.sort(mir))
        edges_l.append(eids)

    n_m_pad = _round_up(max(len(m) for m in masters_l))
    n_mir_pad = _round_up(max((len(m) for m in mirrors_l), default=1))
    e_pad = _round_up(max(len(e) for e in edges_l))

    masters = np.zeros((P, n_m_pad), np.int32)
    master_mask = np.zeros((P, n_m_pad), np.float32)
    mirrors = np.zeros((P, n_mir_pad), np.int32)
    mirror_mask = np.zeros((P, n_mir_pad), np.float32)
    src_local = np.zeros((P, e_pad), np.int32)
    dst_local = np.zeros((P, e_pad), np.int32)
    edge_mask = np.zeros((P, e_pad), np.float32)
    edge_orig = np.zeros((P, e_pad), np.int32)

    master_slot = {}   # global id -> (p, slot)
    mirror_slot = {}
    for p in range(P):
        ml, rl = masters_l[p], mirrors_l[p]
        masters[p, :len(ml)] = ml
        master_mask[p, :len(ml)] = 1.0
        mirrors[p, :len(rl)] = rl
        mirror_mask[p, :len(rl)] = 1.0
        for i, nid in enumerate(ml):
            master_slot[(p, int(nid))] = i
        for i, nid in enumerate(rl):
            mirror_slot[(p, int(nid))] = i
        eids = edges_l[p]
        loc = np.empty(N, np.int64)   # scratch local index map for p
        loc[ml] = np.arange(len(ml))
        loc[rl] = n_m_pad + np.arange(len(rl))
        src_local[p, :len(eids)] = loc[g.src[eids]]
        dst_local[p, :len(eids)] = loc[g.dst[eids]]
        edge_mask[p, :len(eids)] = 1.0
        edge_orig[p, :len(eids)] = eids

    # ---- exchange plan: owner p -> mirror holder q ---------------------------
    pair_sends = {}
    for q in range(P):
        for nid in mirrors_l[q]:
            p = int(owner[nid])
            pair_sends.setdefault((p, q), []).append(int(nid))
    s_pad = _round_up(max((len(v) for v in pair_sends.values()), default=1))
    send_idx = np.zeros((P, P, s_pad), np.int32)
    send_mask = np.zeros((P, P, s_pad), np.float32)
    recv_slot = np.zeros((P, P, s_pad), np.int32)
    recv_mask = np.zeros((P, P, s_pad), np.float32)
    for (p, q), nids in pair_sends.items():
        for i, nid in enumerate(nids):
            send_idx[p, q, i] = master_slot[(p, nid)]
            send_mask[p, q, i] = 1.0
            recv_slot[q, p, i] = mirror_slot[(q, nid)]
            recv_mask[q, p, i] = 1.0

    plan = PartitionPlan(P, method, owner, masters, master_mask, mirrors,
                         mirror_mask, src_local, dst_local, edge_mask,
                         edge_orig, send_idx, send_mask, recv_slot, recv_mask)

    # ---- node/edge data sliced per partition ---------------------------------
    F = g.node_features.shape[1]
    x = np.zeros((P, n_m_pad, F), np.float32)
    y = np.zeros((P, n_m_pad), np.int32)
    for p in range(P):
        x[p] = g.node_features[masters[p]] * master_mask[p][:, None]
        y[p] = g.labels[masters[p]] * master_mask[p].astype(np.int32)
    ew = np.zeros((P, e_pad), np.float32)
    norm = g.gcn_norm() if gcn_norm else (
        g.edge_weights if g.edge_weights is not None
        else np.ones(M, np.float32))
    ea = None
    if g.edge_features is not None:
        ea = np.zeros((P, e_pad, g.edge_features.shape[1]), np.float32)
    for p in range(P):
        k = int(plan.edge_mask[p].sum())
        eids = edges_l[p]
        ew[p, :k] = norm[eids]
        if ea is not None:
            ea[p, :k] = g.edge_features[eids]
    return ShardedGraph(plan, x, y, ew, ea, F)


def partition_stats(sg: ShardedGraph) -> dict:
    """Metrics the paper reports for partitioning methods (Fig. 10, §4.1)."""
    plan = sg.plan
    n_masters = plan.master_mask.sum(axis=1)
    n_mirrors = plan.mirror_mask.sum(axis=1)
    n_edges = plan.edge_mask.sum(axis=1)
    comm = plan.send_mask.sum()          # values moved per broadcast phase
    total_nodes = float(n_masters.sum())
    return {
        "method": plan.method,
        "P": plan.P,
        "replica_factor": float((n_masters.sum() + n_mirrors.sum())
                                / max(total_nodes, 1)),
        "edge_balance": float(n_edges.max() / max(n_edges.mean(), 1e-9)),
        "master_balance": float(n_masters.max()
                                / max(n_masters.mean(), 1e-9)),
        "halo_values_per_sync": float(comm),
        "mirrors_total": float(n_mirrors.sum()),
        "edges_per_part_max": float(n_edges.max()),
        "memory_per_part_nodes": float(n_masters.max() + n_mirrors.max()),
    }
