"""Community detection for cluster-batched training (paper §2.3, §4.1).

The paper generates clusters "by using a community detection algorithm
based on maximizing intra-community edges" (Louvain [5]; METIS also
supported). We provide:

- ``label_propagation_clusters`` — native numpy asynchronous label
  propagation (Louvain-quality-ish, linear time) with a balancing pass that
  splits oversized communities (cluster-batch wants bounded batch sizes).
- ``louvain_clusters`` — networkx Louvain when available (small graphs).
- ``hash_clusters`` — degenerate hash partition (the "no community
  structure" baseline the paper warns about in Table A1).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.csr import Graph


def cluster_members(labels: np.ndarray,
                    num_clusters: Optional[int] = None) -> list:
    """Per-cluster sorted member node-id arrays, in one argsort instead of
    C boolean scans. The ClusterViewCache (repro.core.views) builds its
    static member sets through this."""
    labels = np.asarray(labels)
    C = int(num_clusters if num_clusters is not None else labels.max() + 1)
    order = np.argsort(labels, kind="stable")   # ties keep node-id order
    counts = np.bincount(labels, minlength=C)
    return np.split(order, np.cumsum(counts)[:-1])


def hash_clusters(g: Graph, num_clusters: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_nodes)
    out = np.empty(g.num_nodes, np.int32)
    out[perm] = np.arange(g.num_nodes) % num_clusters
    return out


def label_propagation_clusters(g: Graph, max_cluster_size: int = 0,
                               iters: int = 8, seed: int = 0) -> np.ndarray:
    """Asynchronous label propagation; returns dense cluster ids (0..C-1)."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    labels = np.arange(n, dtype=np.int64)
    indptr, order = g.csc()
    src = g.src
    nodes = np.arange(n)
    for _ in range(iters):
        rng.shuffle(nodes)
        changed = 0
        for u in nodes:
            eids = order[indptr[u]:indptr[u + 1]]
            if len(eids) == 0:
                continue
            nbr_labels = labels[src[eids]]
            vals, counts = np.unique(nbr_labels, return_counts=True)
            best = vals[np.argmax(counts)]
            if best != labels[u]:
                labels[u] = best
                changed += 1
        if changed == 0:
            break
    labels = _densify(labels)
    if max_cluster_size:
        labels = _split_oversized(labels, max_cluster_size, rng)
    return labels.astype(np.int32)


def louvain_clusters(g: Graph, seed: int = 0,
                     max_cluster_size: int = 0) -> np.ndarray:
    """networkx Louvain (small/medium graphs only)."""
    import networkx as nx

    G = nx.Graph()
    G.add_nodes_from(range(g.num_nodes))
    G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    comms = nx.community.louvain_communities(G, seed=seed)
    labels = np.zeros(g.num_nodes, np.int64)
    for c, nodes in enumerate(comms):
        labels[list(nodes)] = c
    if max_cluster_size:
        labels = _split_oversized(labels, max_cluster_size,
                                  np.random.default_rng(seed))
    return _densify(labels).astype(np.int32)


def _densify(labels: np.ndarray) -> np.ndarray:
    _, dense = np.unique(labels, return_inverse=True)
    return dense


def _split_oversized(labels: np.ndarray, max_size: int,
                     rng: np.random.Generator) -> np.ndarray:
    labels = _densify(labels)
    next_id = labels.max() + 1
    for c in range(labels.max() + 1):
        members = np.where(labels == c)[0]
        if len(members) > max_size:
            rng.shuffle(members)
            n_sub = int(np.ceil(len(members) / max_size))
            for i in range(1, n_sub):
                labels[members[i * max_size:(i + 1) * max_size]] = next_id
                next_id += 1
    return _densify(labels)


def modularity(g: Graph, labels: np.ndarray) -> float:
    """Newman modularity Q of a clustering (quality metric for Fig. 10)."""
    m = g.num_edges
    if m == 0:
        return 0.0
    # edges are stored in both directions => treat as a symmetric digraph:
    # Q = Σ_c [ e_cc/M - (d_c/M)^2 ]  with d_c = Σ out-degree in c
    same = labels[g.src] == labels[g.dst]
    intra = float(same.sum()) / m
    deg = np.bincount(g.src, minlength=g.num_nodes).astype(np.float64)
    tot = np.zeros(int(labels.max()) + 1)
    np.add.at(tot, labels, deg)
    return intra - float(np.sum((tot / m) ** 2))
