"""MPGNN (paper Algorithm 1): K passes of Proj/Prop/Agg + decoder + loss.

``MPGNNModel`` composes TGAR layers with a decoder (an NN-T stage) and the
loss (another NN-T stage) — matching the paper's "forward = K+2 passes of
NN-TGA" description (§3.2). The same model object runs on a single
GraphBlock (this module) or distributed via the hybrid-parallel engine
(:mod:`repro.core.engine`) — the paper's "training and inference via a
unified implementation".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.tgar import TGARLayer, layer_forward_block
from repro.nn.layers import dense_init, dense_apply, softmax_cross_entropy


@dataclass(frozen=True)
class MPGNNModel:
    layers: Sequence[TGARLayer]
    num_classes: int
    decoder_hidden: int = 0          # optional extra FC before the decoder
    # Sum-stage aggregation backend ("reference" | "csc", see
    # repro.core.aggregate); the "csc" kernel path additionally needs a
    # CSCPlan on the block (build_block(csc_plan=True)) or engine shard
    aggregate_backend: str = "reference"

    @property
    def K(self):
        return len(self.layers)

    def init(self, key, feature_dim: int):
        keys = jax.random.split(key, self.K + 2)
        params = {"layers": [ly.init(k) for ly, k in zip(self.layers, keys)]}
        last = self.layers[-1].out_dim
        if self.decoder_hidden:
            params["dec_fc"] = dense_init(keys[-2], last, self.decoder_hidden)
            last = self.decoder_hidden
        params["decoder"] = dense_init(keys[-1], last, self.num_classes)
        return params

    def encode(self, params, block):
        """K passes of NN-TGA over the block; returns final embeddings."""
        h = block.x
        n = block.num_nodes_padded
        for k, layer in enumerate(self.layers):
            h = layer_forward_block(layer, params["layers"][k], h, block, k,
                                    n, backend=self.aggregate_backend)
        return h

    def decode(self, params, h):
        """Decoder = a single NN-T (node-local) stage (§3.2)."""
        if self.decoder_hidden:
            h = jax.nn.relu(dense_apply(params["dec_fc"], h))
        return dense_apply(params["decoder"], h)


def forward_block(model: MPGNNModel, params, block):
    h = model.encode(params, block)
    return model.decode(params, h)


def loss_block(model: MPGNNModel, params, block):
    """Loss = a single NN-T stage over labeled (loss-masked) nodes."""
    logits = forward_block(model, params, block)
    return softmax_cross_entropy(logits, block.y, block.loss_mask)


def accuracy_block(model: MPGNNModel, params, block, mask=None):
    logits = forward_block(model, params, block)
    pred = jnp.argmax(logits, axis=-1)
    m = (mask if mask is not None else block.loss_mask).astype(jnp.float32)
    correct = (pred == block.y).astype(jnp.float32) * m
    return jnp.sum(correct) / jnp.maximum(jnp.sum(m), 1.0)
