"""NN-TGAR: the paper's graph-learning compute pattern (§3).

One GNN encoding layer = NN-Transform -> NN-Gather -> Sum -> NN-Apply, with
NN-Reduce aggregating parameter gradients across workers. Stages are neural
network functions (UDFs in the paper); here they are pure JAX callables
carried by a :class:`TGARLayer`. The backward pass is the reverse message
flow (paper App. A.2) — produced by ``jax.grad`` through these stages, and
*also* materialized explicitly in :mod:`repro.core.autodiff` to demonstrate
the equivalence the paper proves.

Combine modes supported by Sum (paper §3.1: "non-parameterized method like
averaging, concatenation or a parameterized one"):
  - "sum"     — plain Σ of edge messages per destination
  - "mean"    — Σ / active-degree
  - "max"     — per-feature max over active in-edges (max-pooling SAGE)
  - "softmax" — attention-style normalized Σ (GAT / GAT-E)

The Sum stage itself lives in :mod:`repro.core.aggregate`: one combine
implementation shared with the distributed engine, dispatched over the
``CombineSpec`` registry and executed by a pluggable
:class:`~repro.core.aggregate.AggregationBackend` — ``"reference"`` (the
jnp segment ops below) or ``"csc"`` (the Pallas CSC-blocked kernels in
:mod:`repro.kernels`, fed by the ``CSCPlan`` cached on the GraphBlock).
``combine_messages`` here is the thin single-block entry point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import aggregate as agg

NEG = agg.NEG           # one masking sentinel, defined in kernels/segment_sum


# ---------------------------------------------------------------------------
# segment primitives: the portable jnp oracles of the Sum stage, kept as
# the "reference" backend's math and for property tests / stage benches.
# ---------------------------------------------------------------------------


def segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments)


def segment_mean(data, segment_ids, num_segments, weights=None):
    ones = jnp.ones(data.shape[:1], data.dtype) if weights is None else weights
    total = jax.ops.segment_sum(data, segment_ids, num_segments)
    count = jax.ops.segment_sum(ones, segment_ids, num_segments)
    # broadcast the (N,) count over ALL trailing axes — (E, H, D) multi-head
    # messages need (N, 1, 1), not the (N, 1) that [..., None] produced
    count = count.reshape(count.shape + (1,) * (total.ndim - 1))
    return total / jnp.maximum(count, 1e-9)


def segment_max(data, segment_ids, num_segments):
    return jax.ops.segment_max(data, segment_ids, num_segments)


def segment_softmax(logits, values, segment_ids, num_segments, edge_mask):
    """Softmax over incoming edges per destination, applied to values.

    logits: (E, H)  values: (E, H, D)  -> (num_segments, H, D)
    """
    masked = jnp.where(edge_mask[:, None] > 0, logits, NEG)
    seg_max = jax.ops.segment_max(masked, segment_ids, num_segments)
    seg_max = jnp.maximum(seg_max, NEG)          # empty segments
    ex = jnp.exp(masked - seg_max[segment_ids]) * edge_mask[:, None]
    den = jax.ops.segment_sum(ex, segment_ids, num_segments)
    num = jax.ops.segment_sum(ex[..., None] * values, segment_ids,
                              num_segments)
    return num / jnp.maximum(den, 1e-9)[..., None]


# ---------------------------------------------------------------------------
# TGAR layer protocol
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TGARLayer:
    """One encoding layer in the NN-TGAR pattern.

    init(key) -> params
    transform(params, h) -> n                      # NN-T, per node
    gather(params, n_src, n_dst, edge_attr, edge_w) -> msg   # NN-G, per edge
        msg is {"value": (E,H,D)} and, for combine == "softmax",
        additionally {"logit": (E,H)}.
    apply(params, h, M) -> h_next                  # NN-A, per node
    combine: "sum" | "mean" | "max" | "softmax"    # Sum stage semantics
        (any mode registered in aggregate.COMBINE_SPECS)
    out_dim / heads: bookkeeping for model composition.
    """
    name: str
    init: Callable[[Any], Any]
    transform: Callable[..., Any]
    gather: Callable[..., Any]
    apply: Callable[..., Any]
    combine: str = "sum"
    out_dim: int = 0
    heads: int = 1

    def message_dim(self):
        return self.out_dim // self.heads


def tree_take(tree, idx):
    """Index the leading axis of every leaf (edge-endpoint lookup)."""
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


def combine_messages(layer: TGARLayer, msg, dst, num_segments, edge_mask,
                     backend=None, plan=None):
    """The Sum stage on a single block (non-distributed path).

    Delegates to the shared combine engine; ``backend`` selects the
    aggregation implementation ("reference" when None) and ``plan`` is the
    graph's cached CSCPlan for the kernel path.
    """
    return agg.combine(layer.combine, msg, dst, num_segments, edge_mask,
                       backend=backend, plan=plan)


def layer_forward_block(layer: TGARLayer, params, h, block, layer_idx: int,
                        num_nodes: int, backend=None):
    """Forward one TGAR layer on a GraphBlock (whole/sub-graph in one shard).

    Applies the per-layer active sets (paper §4.2) so that a mini-batch
    computes exactly the k-hop neighborhood, nothing more. ``backend``
    picks the Sum-stage aggregation backend; the block's cached
    ``csc_plan`` (built once per graph, reused by every view and batch —
    the paper's reused CSC indexing) feeds the ``"csc"`` kernel path.
    """
    edge_mask = block.edge_mask
    node_act = None
    if block.edge_active is not None:
        edge_mask = edge_mask * block.edge_active[layer_idx]
    if block.node_active is not None:
        node_act = block.node_active[layer_idx]

    n = layer.transform(params, h)                        # NN-T
    n_src = tree_take(n, block.src)
    n_dst = tree_take(n, block.dst)
    ea = block.edge_attr
    msg = layer.gather(params, n_src, n_dst, ea, block.edge_weight,
                       edge_mask)                         # NN-G
    M = combine_messages(layer, msg, block.dst, num_nodes, edge_mask,
                         backend=backend,
                         plan=getattr(block, "csc_plan", None))  # Sum
    h_next = layer.apply(params, h, M)                    # NN-A
    if node_act is not None:
        h_next = h_next * node_act[:, None]
    return h_next * block.node_mask[:, None]
