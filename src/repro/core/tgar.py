"""NN-TGAR: the paper's graph-learning compute pattern (§3).

One GNN encoding layer = NN-Transform -> NN-Gather -> Sum -> NN-Apply, with
NN-Reduce aggregating parameter gradients across workers. Stages are neural
network functions (UDFs in the paper); here they are pure JAX callables
carried by a :class:`TGARLayer`. The backward pass is the reverse message
flow (paper App. A.2) — produced by ``jax.grad`` through these stages, and
*also* materialized explicitly in :mod:`repro.core.autodiff` to demonstrate
the equivalence the paper proves.

Combine modes supported by Sum (paper §3.1: "non-parameterized method like
averaging, concatenation or a parameterized one"):
  - "sum"     — plain Σ of edge messages per destination
  - "mean"    — Σ / active-degree
  - "softmax" — attention-style normalized Σ (GAT / GAT-E)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

NEG = -1e30


# ---------------------------------------------------------------------------
# segment primitives (the Sum stage). The Pallas kernel in
# repro/kernels/segment_sum.py implements the same contract for TPU; the
# jnp versions here are the portable reference used on CPU and in dry-runs.
# ---------------------------------------------------------------------------


def segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments)


def segment_mean(data, segment_ids, num_segments, weights=None):
    ones = jnp.ones(data.shape[:1], data.dtype) if weights is None else weights
    total = jax.ops.segment_sum(data, segment_ids, num_segments)
    count = jax.ops.segment_sum(ones, segment_ids, num_segments)
    return total / jnp.maximum(count, 1e-9)[..., None]


def segment_max(data, segment_ids, num_segments):
    return jax.ops.segment_max(data, segment_ids, num_segments)


def segment_softmax(logits, values, segment_ids, num_segments, edge_mask):
    """Softmax over incoming edges per destination, applied to values.

    logits: (E, H)  values: (E, H, D)  -> (num_segments, H, D)
    """
    masked = jnp.where(edge_mask[:, None] > 0, logits, NEG)
    seg_max = jax.ops.segment_max(masked, segment_ids, num_segments)
    seg_max = jnp.maximum(seg_max, NEG)          # empty segments
    ex = jnp.exp(masked - seg_max[segment_ids]) * edge_mask[:, None]
    den = jax.ops.segment_sum(ex, segment_ids, num_segments)
    num = jax.ops.segment_sum(ex[..., None] * values, segment_ids,
                              num_segments)
    return num / jnp.maximum(den, 1e-9)[..., None]


# ---------------------------------------------------------------------------
# TGAR layer protocol
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TGARLayer:
    """One encoding layer in the NN-TGAR pattern.

    init(key) -> params
    transform(params, h) -> n                      # NN-T, per node
    gather(params, n_src, n_dst, edge_attr, edge_w) -> msg   # NN-G, per edge
        msg is {"value": (E,H,D)} and, for combine == "softmax",
        additionally {"logit": (E,H)}.
    apply(params, h, M) -> h_next                  # NN-A, per node
    combine: "sum" | "mean" | "softmax"            # Sum stage semantics
    out_dim / heads: bookkeeping for model composition.
    """
    name: str
    init: Callable[[Any], Any]
    transform: Callable[..., Any]
    gather: Callable[..., Any]
    apply: Callable[..., Any]
    combine: str = "sum"
    out_dim: int = 0
    heads: int = 1

    def message_dim(self):
        return self.out_dim // self.heads


def tree_take(tree, idx):
    """Index the leading axis of every leaf (edge-endpoint lookup)."""
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


def combine_messages(layer: TGARLayer, msg, dst, num_segments, edge_mask):
    """The Sum stage on a single block (non-distributed path)."""
    value = msg["value"] * edge_mask[:, None, None]
    if layer.combine == "softmax":
        return segment_softmax(msg["logit"], msg["value"], dst, num_segments,
                               edge_mask)
    total = segment_sum(value, dst, num_segments)
    if layer.combine == "mean":
        deg = segment_sum(edge_mask, dst, num_segments)
        return total / jnp.maximum(deg, 1e-9)[:, None, None]
    return total


def layer_forward_block(layer: TGARLayer, params, h, block, layer_idx: int,
                        num_nodes: int):
    """Forward one TGAR layer on a GraphBlock (whole/sub-graph in one shard).

    Applies the per-layer active sets (paper §4.2) so that a mini-batch
    computes exactly the k-hop neighborhood, nothing more.
    """
    edge_mask = block.edge_mask
    node_act = None
    if block.edge_active is not None:
        edge_mask = edge_mask * block.edge_active[layer_idx]
    if block.node_active is not None:
        node_act = block.node_active[layer_idx]

    n = layer.transform(params, h)                        # NN-T
    n_src = tree_take(n, block.src)
    n_dst = tree_take(n, block.dst)
    ea = block.edge_attr
    msg = layer.gather(params, n_src, n_dst, ea, block.edge_weight,
                       edge_mask)                         # NN-G
    M = combine_messages(layer, msg, block.dst, num_nodes, edge_mask)  # Sum
    h_next = layer.apply(params, h, M)                    # NN-A
    if node_act is not None:
        h_next = h_next * node_act[:, None]
    return h_next * block.node_mask[:, None]
