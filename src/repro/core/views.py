"""Vectorized view-construction engine (paper §2.3/§4.2 host path).

PR 4 made the device step compiled-once, which moved the per-step cost to
*host-side view construction* — the same batch-preparation bottleneck
DistDGL attacks with dedicated samplers. This module owns that path:

- :class:`GraphView` — "a light-weighted logic view of the global graph"
  (per-layer node/edge active masks + a loss mask), the unification all
  three training strategies reduce to.
- :class:`ViewBuilder` — builds views into a ring of *reusable*
  preallocated ``(K, N)``/``(K, E)`` mask buffers: repeated construction
  does zero fresh mask allocations. Single consumer; a view's arrays are
  valid until ``slots`` more views are built from the same builder.
- :class:`ClusterViewCache` — per-cluster member and halo node sets are
  precomputed **once** from the static clustering; each step's active set
  is composed by OR-ing the chosen clusters' cached sets, so the per-step
  ``np.isin`` membership scan and halo edge walks disappear. (Halo
  distributes over unions: grow(A∪B) = grow(A) ∪ grow(B), because an edge
  contributes exactly when its dst is inside — so the union of cached
  per-cluster halos IS the halo of the union, bit-exactly.)
- :class:`ViewStream` — an *indexable* strategy stream: view i is built
  from an RNG stream derived from (seed, i), so any worker can build any
  index and the result is order-stable regardless of scheduling. This is
  what the Trainer's multi-stream prefetch pool fans out over, and what
  makes the view cursor checkpointable (the RNG state IS the index).
- :class:`CompactView` — the relabeled sampled-subgraph form (DistDGL's
  compact blocks): local-id edge list over only the sampled nodes plus a
  local→global map and per-hop offsets, so per-view host work, bytes and
  device memory scale with the *view*, not the graph. Dense masks remain
  the bit-parity oracle (``CompactView.to_dense``).
- :class:`BucketSpec` / :class:`CompactBlockBuilder` — size-bucketed
  padding: compact blocks are padded to a small fixed menu of
  ``(n_pad, e_pad)`` shapes (per-bucket buffer rings), so a jitted step
  compiles at most once per bucket instead of once per view shape.

``cluster_view_recompute`` keeps the pre-cache per-step recompute as the
parity oracle and benchmark baseline.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.graph.csr import Graph, GraphBlock, base_block
from repro.core.subgraph import (bfs_layers, bfs_layers_fresh,
                                 fill_khop_masks, stamped_in_edges)


# ---------------------------------------------------------------------------
# the view abstraction
# ---------------------------------------------------------------------------


@dataclass
class GraphView:
    graph: Graph
    K: int
    strategy: str
    node_active: Optional[np.ndarray]    # (K, N) f32 or None (=all)
    edge_active: Optional[np.ndarray]    # (K, M) f32 or None
    loss_mask: np.ndarray                # (N,) f32
    meta: dict

    def as_block(self, gcn_norm: bool = True,
                 csc_plan: bool = False) -> GraphBlock:
        """Stamp this view's loss/activity masks onto a shallow copy of
        the graph's cached strategy-invariant base block — features, edge
        layout, degree norms and (with ``csc_plan=True``) the CSCPlan are
        shared read-only across every view of one graph instead of being
        rebuilt (degree recompute included) per view."""
        base = base_block(self.graph, gcn_norm=gcn_norm, csc_plan=csc_plan)
        return replace(base,
                       loss_mask=(self.loss_mask > 0).astype(np.float32),
                       node_active=self.node_active,
                       edge_active=self.edge_active)

    _COUNT_KEYS = ("active_nodes", "active_edges", "targets")

    def active_counts(self) -> dict:
        """Builder-recorded counts from ``meta`` (O(1) — the logging path
        must not rescan (K, N)/(K, E) masks every call); hand-built views
        without the meta keys fall back to the mask scan."""
        m = self.meta
        if all(k in m for k in self._COUNT_KEYS):
            return {k: int(m[k]) for k in self._COUNT_KEYS}
        n_nodes = (self.graph.num_nodes if self.node_active is None
                   else int((self.node_active.max(axis=0) > 0).sum()))
        n_edges = (self.graph.num_edges if self.edge_active is None
                   else int((self.edge_active.max(axis=0) > 0).sum()))
        return {"active_nodes": n_nodes, "active_edges": n_edges,
                "targets": int((self.loss_mask > 0).sum())}

    def copy_masks(self) -> "GraphView":
        """Detach from any builder buffers (fresh mask arrays)."""
        return GraphView(
            self.graph, self.K, self.strategy,
            None if self.node_active is None else self.node_active.copy(),
            None if self.edge_active is None else self.edge_active.copy(),
            self.loss_mask.copy(), dict(self.meta))


# ---------------------------------------------------------------------------
# compact sampled-subgraph views (relabeled local-id blocks)
# ---------------------------------------------------------------------------


@dataclass
class CompactView:
    """A relabeled sampled subgraph — DistDGL-style compact block.

    ``nodes`` holds the sampled global ids in **hop order**: the hop-0
    targets first, then the nodes first reached at hop 1, etc.
    (``hop_offsets[d]`` = number of nodes within d hops; ``hop_offsets[K]``
    = all sampled nodes). Because BFS hop sets are nested, per-layer
    activity reduces to rank comparisons in local-id space::

        node active in layer k  <=>  local_id < hop_offsets[K-1-k]
        edge active in layer k  <=>  dst_local < hop_offsets[K-1-k]
                                  and src_local < hop_offsets[K-k]

    so no (K, N) or (K, E) array ever exists — host bytes and build time
    are O(view), not O(graph). Cluster views use a flat ordering with all
    offsets equal to n (every sampled node active in every layer).

    ``edge_ids`` maps local edges back to the global edge arrays (edge
    weights / GCN norms / attributes are *gathered*, never recomputed);
    ``src_local``/``dst_local`` are the relabeled CSC-sorted edge list
    (nondecreasing dst) the per-bucket CSCPlan is built from.
    """
    graph: Graph
    K: int
    strategy: str
    nodes: np.ndarray         # (n,) int64 global ids, hop-ordered
    hop_offsets: np.ndarray   # (K+1,) int64; hop_offsets[-1] == n
    src_local: np.ndarray     # (e,) int32
    dst_local: np.ndarray     # (e,) int32, nondecreasing
    edge_ids: np.ndarray      # (e,) int64 global edge ids
    loss_local: np.ndarray    # (n,) f32 loss mask in local id space
    meta: dict

    @property
    def num_nodes(self) -> int:
        return int(len(self.nodes))

    @property
    def num_edges(self) -> int:
        return int(len(self.edge_ids))

    def nbytes(self) -> int:
        """Host bytes this view owns — the compact-vs-dense memory model."""
        return int(self.nodes.nbytes + self.hop_offsets.nbytes
                   + self.src_local.nbytes + self.dst_local.nbytes
                   + self.edge_ids.nbytes + self.loss_local.nbytes)

    def layer_bounds(self, k: int) -> tuple:
        """(dst-side, src-side) local-id bounds of layer k."""
        off = self.hop_offsets
        return int(off[self.K - 1 - k]), int(off[self.K - k])

    def edge_layer_mask(self, k: int) -> np.ndarray:
        d_bound, s_bound = self.layer_bounds(k)
        return (self.dst_local < d_bound) & (self.src_local < s_bound)

    def active_counts(self) -> dict:
        return {"active_nodes": int(self.hop_offsets[self.K - 1]),
                "active_edges": self.num_edges,
                "targets": int((self.loss_local > 0).sum())}

    def copy_masks(self) -> "CompactView":
        """Detach (fresh arrays) — the ViewStream iterator contract."""
        return CompactView(self.graph, self.K, self.strategy,
                           self.nodes.copy(), self.hop_offsets.copy(),
                           self.src_local.copy(), self.dst_local.copy(),
                           self.edge_ids.copy(), self.loss_local.copy(),
                           dict(self.meta))

    def to_dense(self) -> GraphView:
        """Materialize the dense (K, N)/(K, E) mask view — the bit-parity
        bridge to the retained dense oracle path (tests assert this equals
        the dense builder's masks for the same stream index)."""
        g, K = self.graph, self.K
        na = np.zeros((K, g.num_nodes), np.float32)
        ea = np.zeros((K, g.num_edges), np.float32)
        for k in range(K):
            d_bound, _ = self.layer_bounds(k)
            na[k, self.nodes[:d_bound]] = 1.0
            ea[k, self.edge_ids[self.edge_layer_mask(k)]] = 1.0
        loss = np.zeros(g.num_nodes, np.float32)
        loss[self.nodes] = self.loss_local
        return GraphView(g, K, self.strategy, na, ea, loss,
                         dict(self.meta))

    def as_block(self, gcn_norm: bool = True, csc_plan: bool = False,
                 bucket: Optional[tuple] = None, block_n: int = 128,
                 block_e: int = 256) -> GraphBlock:
        """One-off padded block with fresh arrays; ``bucket`` is an
        ``(n_pad, e_pad)`` pair (None pads tight). Streamed training goes
        through :class:`CompactBlockBuilder` — per-bucket buffer rings and
        a shape-stable plan per bucket."""
        n_pad, e_pad = bucket or (max(1, self.num_nodes),
                                  max(1, self.num_edges))
        slot = _CompactSlot(self.graph, self.K, int(n_pad), int(e_pad))
        return _fill_compact_block(self, slot, gcn_norm, csc_plan,
                                   block_n, block_e)


def _ceil_pow2(x: int) -> int:
    return 1 << (max(1, int(x)) - 1).bit_length()


@dataclass(frozen=True)
class BucketSpec:
    """A small fixed menu of ``(n_pad, e_pad)`` padded shapes for compact
    blocks. A jitted step over bucketed blocks compiles at most once per
    bucket (shapes + CSCPlan geometry are pure functions of the bucket);
    :meth:`pick` returns the smallest bucket fitting a view and raises
    past the largest — the default ladder tops out at graph capacity, so
    only config-supplied specs can overflow."""
    shapes: tuple    # ((n_pad, e_pad), ...), kept sorted ascending

    def __post_init__(self):
        shapes = tuple(sorted({(int(n), int(e)) for n, e in self.shapes}))
        if not shapes:
            raise ValueError("BucketSpec needs at least one (n_pad, e_pad)")
        object.__setattr__(self, "shapes", shapes)

    @classmethod
    def for_graph(cls, g: Graph, levels: int = 4, n_min: int = 64,
                  e_min: int = 256) -> "BucketSpec":
        """Powers-of-two ladder from ``(n_min, e_min)`` up to graph
        capacity (halving per level): small batches trace small shapes,
        and the largest bucket always fits the worst-case view."""
        n_top = _ceil_pow2(max(n_min, g.num_nodes))
        e_top = _ceil_pow2(max(e_min, g.num_edges))
        return cls(tuple((max(n_min, n_top >> i), max(e_min, e_top >> i))
                         for i in range(max(1, int(levels)))))

    def __len__(self) -> int:
        return len(self.shapes)

    def pick(self, n: int, e: int) -> tuple:
        for shape in self.shapes:
            if shape[0] >= n and shape[1] >= e:
                return shape
        raise ValueError(
            f"view ({n} nodes, {e} edges) overflows every bucket "
            f"{list(self.shapes)} — supply a BucketSpec with a larger "
            f"(n_pad, e_pad)")


class _CompactSlot:
    """One bucket-shaped set of reusable block buffers. ``feature_dim``
    overrides the feature width when the staged ``x`` rows come from a
    source other than ``g.node_features`` (the serving embedding cache
    stages cached hidden-layer rows, whose width is the model's hidden
    dim, not the raw feature dim)."""

    def __init__(self, g: Graph, K: int, n_pad: int, e_pad: int,
                 feature_dim: Optional[int] = None):
        F = (g.node_features.shape[1] if feature_dim is None
             else int(feature_dim))
        self.src = np.zeros(e_pad, np.int32)
        self.dst = np.zeros(e_pad, np.int32)
        self.edge_mask = np.zeros(e_pad, np.float32)
        self.node_mask = np.zeros(n_pad, np.float32)
        self.x = np.zeros((n_pad, F), np.float32)
        self.y = np.zeros(n_pad, np.int32)
        self.loss = np.zeros(n_pad, np.float32)
        self.edge_weight = np.zeros(e_pad, np.float32)
        self.edge_attr = (np.zeros((e_pad, g.edge_features.shape[1]),
                                   np.float32)
                          if g.edge_features is not None else None)
        self.node_active = np.zeros((K, n_pad), np.float32)
        self.edge_active = np.zeros((K, e_pad), np.float32)


def _fill_compact_block(view: CompactView, slot: _CompactSlot,
                        gcn_norm: bool, csc_plan: bool, block_n: int,
                        block_e: int,
                        features: Optional[np.ndarray] = None
                        ) -> GraphBlock:
    """Gather the view's node/edge data into (zeroed) bucket-shaped
    buffers. Pad edges keep src = dst = 0 with edge_mask 0 — inert under
    every combine mode, exactly like the dense path's padding.
    ``features`` substitutes an alternate (N, D) per-node row source for
    ``g.node_features`` (the serving cache's embedding table)."""
    g, K = view.graph, view.K
    n, e = view.num_nodes, view.num_edges
    x_src = g.node_features if features is None else features
    slot.src.fill(0)
    slot.src[:e] = view.src_local
    slot.dst.fill(0)
    slot.dst[:e] = view.dst_local
    slot.edge_mask.fill(0.0)
    slot.edge_mask[:e] = 1.0
    slot.node_mask.fill(0.0)
    slot.node_mask[:n] = 1.0
    slot.x.fill(0.0)
    slot.x[:n] = x_src[view.nodes]
    slot.y.fill(0)
    slot.y[:n] = g.labels[view.nodes]
    slot.loss.fill(0.0)
    slot.loss[:n] = view.loss_local
    slot.edge_weight.fill(0.0)
    if gcn_norm:
        slot.edge_weight[:e] = g.gcn_norm()[view.edge_ids]
    elif g.edge_weights is not None:
        slot.edge_weight[:e] = g.edge_weights[view.edge_ids]
    else:
        slot.edge_weight[:e] = 1.0
    if slot.edge_attr is not None:
        slot.edge_attr.fill(0.0)
        slot.edge_attr[:e] = g.edge_features[view.edge_ids]
    slot.node_active.fill(0.0)
    slot.edge_active.fill(0.0)
    for k in range(K):
        d_bound, _ = view.layer_bounds(k)
        slot.node_active[k, :d_bound] = 1.0   # hop-ordered: a prefix
        slot.edge_active[k, :e][view.edge_layer_mask(k)] = 1.0
    plan = None
    if csc_plan:
        from repro.kernels.ops import build_bucket_csc_plan
        plan = build_bucket_csc_plan(view.dst_local, len(slot.node_mask),
                                     len(slot.edge_mask), block_n, block_e)
    return GraphBlock(slot.src, slot.dst, slot.edge_mask, slot.node_mask,
                      slot.x, slot.y, slot.loss, slot.edge_weight,
                      slot.edge_attr, node_active=slot.node_active,
                      edge_active=slot.edge_active, csc_plan=plan)


class CompactBlockBuilder:
    """Stages CompactViews into per-bucket rings of reusable padded block
    buffers — the compact analog of ViewBuilder's mask-buffer ring. Each
    touched bucket shape owns ``slots`` preallocated buffer sets, so
    steady-state staging does zero fresh O(bucket) allocations, and with
    ``csc_plan=True`` a bucket-shape-stable CSCPlan is built per view from
    the compact dst ids (host cost O(view)).

    A staged block's arrays alias ring memory and stay valid until
    ``slots`` more views land in the *same* bucket; consumers that hold
    blocks longer (e.g. across a prefetch queue) ``device_put`` them
    first **and block until the transfer completes** (under async
    dispatch the host->device copy may be deferred, and a later ring
    fill would race it). Dense GraphViews pass through :meth:`GraphView.as_block`
    unchanged (the full-graph shape acts as its own single bucket), so
    one trainer loop drives both paths for parity benches.
    """

    def __init__(self, g: Graph, K: int,
                 buckets: Optional[BucketSpec] = None, slots: int = 2,
                 gcn_norm: bool = True, csc_plan: bool = False,
                 block_n: int = 128, block_e: int = 256,
                 features: Optional[np.ndarray] = None):
        self.g = g
        self.K = int(K)
        # alternate per-node row source for block.x (the serving embedding
        # cache passes its table; updated in place, so the ref stays live)
        self.features = features
        self.buckets = buckets or BucketSpec.for_graph(g)
        self.slots = max(1, int(slots))
        self.gcn_norm = bool(gcn_norm)
        self.csc_plan = bool(csc_plan)
        self.block_n = int(block_n)
        self.block_e = int(block_e)
        self._rings: dict = {}     # (n_pad, e_pad) -> [_CompactSlot, ...]
        self._turns: dict = {}
        self.stages = 0
        # views too large for every configured bucket (degraded to an
        # escalation shape rather than crashing mid-training)
        self.overflows = 0
        self._warned_overflow = False

    def _pick(self, view) -> tuple:
        """The view's bucket — degrading gracefully on overflow: a view
        too large for every configured bucket escalates to a
        power-of-two shape covering it (capped at graph capacity). The
        escalated shape behaves as one extra bucket (compiles once,
        counted in ``overflows``, warned about once) instead of killing
        a long training run over one oversized cluster."""
        try:
            return self.buckets.pick(view.num_nodes, view.num_edges)
        except ValueError:
            self.overflows += 1
            if not self._warned_overflow:
                self._warned_overflow = True
                warnings.warn(
                    f"CompactView ({view.num_nodes} nodes, "
                    f"{view.num_edges} edges) overflows every bucket "
                    f"{list(self.buckets.shapes)}; escalating to a "
                    "power-of-two shape at most graph capacity. Supply "
                    "a BucketSpec with a larger top bucket to avoid the "
                    "extra compile.", RuntimeWarning, stacklevel=3)
            n = min(_ceil_pow2(view.num_nodes), self.g.num_nodes)
            e = min(_ceil_pow2(view.num_edges), self.g.num_edges)
            return (max(n, view.num_nodes), max(e, view.num_edges))

    def bucket_for(self, view) -> tuple:
        if isinstance(view, GraphView):   # dense: its own full-graph shape
            return (view.graph.num_nodes, view.graph.num_edges)
        return self._pick(view)

    def stage(self, view) -> GraphBlock:
        self.stages += 1
        if isinstance(view, GraphView):
            return view.as_block(gcn_norm=self.gcn_norm,
                                 csc_plan=self.csc_plan)
        shape = self._pick(view)
        ring = self._rings.setdefault(shape, [])
        if len(ring) < self.slots:
            fdim = (None if self.features is None
                    else self.features.shape[1])
            ring.append(_CompactSlot(self.g, self.K, *shape,
                                     feature_dim=fdim))
        turn = self._turns.get(shape, 0)
        self._turns[shape] = turn + 1
        return _fill_compact_block(view, ring[turn % len(ring)],
                                   self.gcn_norm, self.csc_plan,
                                   self.block_n, self.block_e,
                                   features=self.features)


# ---------------------------------------------------------------------------
# cluster-view cache
# ---------------------------------------------------------------------------


def cluster_view_recompute(g: Graph, clusters: np.ndarray,
                           chosen: np.ndarray, halo_hops: int,
                           train: np.ndarray):
    """The pre-cache per-step recompute: ``np.isin`` membership + halo
    edge walks. Kept as the parity oracle (tests assert the cached path
    is bit-exact against it) and as the ``view_build`` bench baseline.

    Returns (member bool(N), active bool(N), loss f32(N)).
    """
    member = np.isin(clusters, chosen)
    active = member.copy()
    for _ in range(halo_hops):
        # grow along incoming edges (neighbors feeding the members)
        grow = np.zeros(g.num_nodes, bool)
        inside = active[g.dst]
        grow[g.src[inside]] = True
        active |= grow
    loss = (member & train).astype(np.float32)
    if loss.sum() == 0:
        loss = member.astype(np.float32)
    return member, active, loss


class ClusterViewCache:
    """Static per-cluster node sets, computed once per clustering.

    ``members[c]`` — sorted member node ids of cluster c;
    ``halo[c]`` — sorted node ids of c's ``halo_hops``-grown active set.
    A step's active set over any chosen cluster subset is the union of the
    cached sets (halo distributes over unions — see module docstring), so
    composing a view costs O(Σ|halo(c)|), not O(N + E·halo_hops).
    """

    def __init__(self, g: Graph, clusters: np.ndarray, halo_hops: int = 0):
        from repro.core.clustering import cluster_members
        self.g = g
        self.clusters = np.asarray(clusters)
        self.halo_hops = int(halo_hops)
        self.num_clusters = int(self.clusters.max()) + 1
        self.members = cluster_members(self.clusters, self.num_clusters)
        self.halo = (self.members if self.halo_hops == 0
                     else self._grow_halos())

    def _grow_halos(self) -> list:
        """Per-cluster halo BFS over in-edges of the *frontier* only —
        the same CSR-segment expansion as ``bfs_layers`` — with a stamp
        array (last cluster to visit each node) standing in for a visited
        bitmap, so there is nothing to clear between clusters. Total work
        is O(Σ_c in-edges(halo_c)), NOT C full-edge scans per hop (the
        old recompute's cost, fatal at C ~ thousands)."""
        from repro.core.subgraph import _expand_frontier
        g, C = self.g, self.num_clusters
        indptr, order = g.csc()
        src = g.src
        stamp = np.full(g.num_nodes, -1, np.int64)
        halos = []
        for c in range(C):
            frontier = self.members[c]
            stamp[frontier] = c
            grown = [frontier]
            for _ in range(self.halo_hops):
                eidx = _expand_frontier(indptr, order, frontier, 0, None)
                if len(eidx) == 0:
                    break
                cand = src[eidx]
                fresh = np.unique(cand[stamp[cand] != c])
                if len(fresh) == 0:
                    break
                stamp[fresh] = c
                grown.append(fresh)
                frontier = fresh
            halos.append(np.unique(np.concatenate(grown))
                         if len(grown) > 1 else np.asarray(frontier))
        return halos

    def compose(self, chosen: Sequence[int], member_out: np.ndarray,
                active_out: np.ndarray) -> None:
        """OR the chosen clusters' cached sets into the caller's (N,) bool
        scratch buffers."""
        member_out.fill(False)
        member_out[np.concatenate([self.members[c] for c in chosen])] = True
        active_out.fill(False)
        active_out[np.concatenate([self.halo[c] for c in chosen])] = True


# ---------------------------------------------------------------------------
# the builder: reusable mask buffers
# ---------------------------------------------------------------------------


class _Slot:
    def __init__(self, K: int, N: int, E: int):
        self.node = np.zeros((K, N), np.float32)
        self.edge = np.zeros((K, E), np.float32)
        self.loss = np.zeros(N, np.float32)


class ViewBuilder:
    """Builds GraphViews into a ring of preallocated mask buffers.

    Repeated view construction does **zero** fresh ``(K, N)``/``(K, E)``
    allocations: each build rotates to the next slot and overwrites it.
    Consequently a built view's arrays alias builder memory and stay valid
    only until ``slots`` more views are built — the Trainer's pipeline
    consumes (shards + stages) each view before the ring wraps, and each
    prefetch worker owns a private builder. Callers that need detached
    views use :meth:`GraphView.copy_masks`.
    """

    def __init__(self, g: Graph, K: int, slots: int = 2,
                 compact: bool = False):
        self.g = g
        self.K = K
        self.compact = bool(compact)
        N, E = g.num_nodes, g.num_edges
        g.csc()     # no-op when cached; the prefetch pool materializes it
                    # before fan-out, direct users pay it here once
        if self.compact:
            # compact builds never touch dense (K, N)/(K, E) buffers —
            # don't allocate them (that O(K·N) footprint is the point)
            self._slots = []
        else:
            self._slots = [_Slot(K, N, E) for _ in range(max(1, slots))]
            # shared scratch (single consumer; never escapes into views)
            self._visited = np.zeros(N, bool)
            self._in_hop = np.zeros((K + 1, N), bool)
            self._member = np.zeros(N, bool)
            self._active = np.zeros(N, bool)
        self._turn = 0
        self.builds = 0
        # stamp / local-id scratch for the compact build paths, created on
        # first use (dense-only builders never pay for it)
        self._stamp: Optional[np.ndarray] = None
        self._g2l: Optional[np.ndarray] = None
        self._tick = 0
        # all-ones train fallback for graphs without a train_mask,
        # allocated once per builder instead of once per cluster build
        self._all_train: Optional[np.ndarray] = None

    def _train_mask(self, train: Optional[np.ndarray]) -> np.ndarray:
        if train is not None:
            return train
        if self.g.train_mask is not None:
            return self.g.train_mask
        if self._all_train is None:
            self._all_train = np.ones(self.g.num_nodes, bool)
        return self._all_train

    def _next_slot(self) -> _Slot:
        if not self._slots:
            raise RuntimeError(
                "this ViewBuilder was created compact=True and owns no "
                "dense mask buffers; use khop_compact/cluster_compact")
        slot = self._slots[self._turn % len(self._slots)]
        self._turn += 1
        self.builds += 1
        return slot

    def _compact_scratch(self):
        if self._stamp is None:
            self._stamp = np.full(self.g.num_nodes, -1, np.int64)
            self._g2l = np.zeros(self.g.num_nodes, np.int64)
        self._tick += 1
        return self._stamp, self._g2l, self._tick

    # -- mini-batch (k-hop BFS) views -----------------------------------------

    def khop_view(self, targets: np.ndarray, neighbor_cap: int = 0,
                  rng: Optional[np.random.Generator] = None) -> GraphView:
        """Vectorized :func:`repro.core.subgraph.khop_subgraph_view` into
        reused buffers; bit-exact with the allocating function."""
        slot = self._next_slot()
        hops, visited = bfs_layers(self.g, targets, self.K, neighbor_cap,
                                   rng, _visited_out=self._visited)
        fill_khop_masks(self.g, hops, self.K, slot.node, slot.edge,
                        in_hop=self._in_hop)
        slot.loss.fill(0.0)
        uniq = np.unique(targets)
        slot.loss[uniq] = 1.0
        # counts recorded at build time: active_counts() must never rescan
        # the (K, N)/(K, E) masks (layer 0 is the union across layers)
        return GraphView(self.g, self.K, "mini", slot.node, slot.edge,
                         slot.loss,
                         {"targets": int(len(uniq)),
                          "touched": int(visited.sum()),
                          "active_nodes": int(len(hops[self.K - 1])),
                          "active_edges": int(slot.edge[0].sum())})

    # -- cluster-batch views ---------------------------------------------------

    def cluster_view(self, chosen: np.ndarray, cache: ClusterViewCache,
                     train: Optional[np.ndarray] = None) -> GraphView:
        """Compose the chosen clusters' cached member/halo sets; bit-exact
        with :func:`cluster_view_recompute`."""
        g = self.g
        slot = self._next_slot()
        cache.compose(chosen, self._member, self._active)
        member, active = self._member, self._active
        slot.node[:] = active                    # (N,) bool -> (K, N) f32
        slot.edge[:] = active[g.src] & active[g.dst]
        train = self._train_mask(train)
        np.multiply(member, train, out=slot.loss, casting="unsafe")
        if not slot.loss.any():
            slot.loss[:] = member
        n_active = int(active.sum())
        return GraphView(g, self.K, "cluster", slot.node, slot.edge,
                         slot.loss,
                         {"clusters": [int(c) for c in chosen],
                          "members": int(member.sum()),
                          "active": n_active,
                          "active_nodes": n_active,
                          "active_edges": int(slot.edge[0].sum()),
                          "targets": int(slot.loss.sum())})

    # -- compact (relabeled sampled-subgraph) builds ---------------------------

    def khop_compact(self, targets: np.ndarray, neighbor_cap: int = 0,
                     rng: Optional[np.random.Generator] = None
                     ) -> CompactView:
        """The compact form of :meth:`khop_view`: hop-ordered relabeling
        straight from the fresh-per-hop frontier output — no (K, N) array
        exists at any point. Same-index parity with the dense builder is
        bit-exact (``CompactView.to_dense()``): both consume identical rng
        draws, so sampled node/edge sets match."""
        g, K = self.g, self.K
        stamp, g2l, tick = self._compact_scratch()
        fresh, _ = bfs_layers_fresh(g, targets, K, neighbor_cap, rng,
                                    stamp=stamp, stamp_val=tick)
        self.builds += 1
        offsets = np.cumsum([len(f) for f in fresh]).astype(np.int64)
        nodes = np.concatenate(fresh)
        n = int(offsets[-1])
        g2l[nodes] = np.arange(n)
        # edges: ALL in-edges of nodes within K-1 hops whose src was
        # visited (with a neighbor cap, unsampled in-neighbors stay out —
        # matching the dense masks' semantics), CSC-sorted by local dst
        eidx = stamped_in_edges(g, nodes[:int(offsets[K - 1])], stamp, tick)
        src_local = g2l[g.src[eidx]].astype(np.int32)
        dst_local = g2l[g.dst[eidx]].astype(np.int32)
        sorter = np.argsort(dst_local, kind="stable")
        loss_local = np.zeros(n, np.float32)
        loss_local[:int(offsets[0])] = 1.0    # hop 0 = the unique targets
        return CompactView(
            g, K, "mini", nodes, offsets, src_local[sorter],
            dst_local[sorter], eidx[sorter].astype(np.int64), loss_local,
            {"targets": int(offsets[0]), "touched": n,
             "active_nodes": int(offsets[K - 1]),
             "active_edges": int(len(eidx))})

    def cluster_compact(self, chosen: np.ndarray, cache: ClusterViewCache,
                        train: Optional[np.ndarray] = None) -> CompactView:
        """The compact form of :meth:`cluster_view`: the active set is the
        union of the chosen clusters' cached halo sets, edges are the
        in-edges of that set with both endpoints inside — O(view), never a
        full-edge scan. All hop offsets equal n (every active node is
        active in every layer, matching the dense broadcast)."""
        g, K = self.g, self.K
        stamp, g2l, tick = self._compact_scratch()
        members = np.unique(np.concatenate(
            [cache.members[c] for c in chosen])).astype(np.int64)
        nodes = (members if cache.halo_hops == 0 else np.unique(
            np.concatenate([cache.halo[c] for c in chosen])).astype(
                np.int64))
        self.builds += 1
        n = len(nodes)
        stamp[nodes] = tick
        g2l[nodes] = np.arange(n)
        eidx = stamped_in_edges(g, nodes, stamp, tick)
        src_local = g2l[g.src[eidx]].astype(np.int32)
        dst_local = g2l[g.dst[eidx]].astype(np.int32)
        sorter = np.argsort(dst_local, kind="stable")
        train = self._train_mask(train)
        labeled = members[train[members]]
        if len(labeled) == 0:
            labeled = members
        loss_local = np.zeros(n, np.float32)
        loss_local[g2l[labeled]] = 1.0
        return CompactView(
            g, K, "cluster", nodes, np.full(K + 1, n, np.int64),
            src_local[sorter], dst_local[sorter],
            eidx[sorter].astype(np.int64), loss_local,
            {"clusters": [int(c) for c in chosen],
             "members": int(len(members)), "active": n,
             "active_nodes": n, "active_edges": int(len(eidx)),
             "targets": int(len(labeled))})


# ---------------------------------------------------------------------------
# indexable strategy streams (per-index RNG -> order-stable parallel builds)
# ---------------------------------------------------------------------------


class ViewStream:
    """An indexable stream of GraphViews: ``build(i)`` is a pure function
    of the index (per-view RNG streams derived from ``(seed, i)``), so

    - the Trainer's multi-stream prefetch pool can build views on any
      worker in any order and emit them in index order, bit-identically to
      sequential construction, and
    - the stream position is a single checkpointable integer
      (``cursor``) — ``Trainer.restore`` fast-forwards with ``seek``.

    Also a plain iterator (``next`` builds at ``cursor`` and advances) —
    iterator consumers receive *detached* views (fresh mask arrays, the
    old generator contract), so buffering several is safe. Zero-copy
    buffer-ring access is the ``build(i, builder)`` path the Trainer's
    prefetch pool uses, where each view is consumed before its slot is
    rebuilt.
    """

    strategy = "?"
    compact = False   # mini/cluster streams flip this to yield CompactViews

    def __init__(self, g: Graph, K: int, seed: int = 0,
                 length: Optional[int] = None):
        self.g = g
        self.K = K
        self.seed = int(seed)
        self.length = length
        self.cursor = 0
        self._builder: Optional[ViewBuilder] = None

    # -- the indexable API -----------------------------------------------------

    def rng_for(self, i: int) -> np.random.Generator:
        """The order-stable per-view RNG stream."""
        return np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(int(i),)))

    def build(self, i: int,
              builder: Optional[ViewBuilder] = None) -> GraphView:
        raise NotImplementedError

    def make_builder(self) -> Optional[ViewBuilder]:
        """A private ViewBuilder for one consumer thread (None when the
        stream needs no buffers — the static global view). Compact streams
        get builders without dense mask buffers."""
        return ViewBuilder(self.g, self.K, compact=self.compact)

    def seek(self, i: int) -> None:
        self.cursor = int(i)

    # -- iterator compatibility ------------------------------------------------

    def __iter__(self) -> Iterator[GraphView]:
        return self

    def __next__(self) -> GraphView:
        if self.length is not None and self.cursor >= self.length:
            raise StopIteration
        if self._builder is None:
            self._builder = self.make_builder()
        view = self.build(self.cursor, self._builder)
        self.cursor += 1
        if self._builder is not None:
            # detach from the builder's buffer ring (static streams have
            # no builder and must keep yielding the identical object)
            view = view.copy_masks()
        return view


class GlobalViewStream(ViewStream):
    """The static full-graph view — every index is the same object, so the
    Trainer's staging cache recognizes it and stages exactly once."""

    strategy = "global"

    def __init__(self, view: GraphView, length: Optional[int] = None):
        super().__init__(view.graph, view.K, seed=0, length=length)
        self._view = view

    def build(self, i: int, builder=None) -> GraphView:
        return self._view

    def make_builder(self) -> None:
        return None


class MiniBatchViewStream(ViewStream):
    """Random labeled targets + K-hop BFS active sets, one independent RNG
    stream per index."""

    strategy = "mini"

    def __init__(self, g: Graph, K: int, batch_nodes: int = 0,
                 neighbor_cap: int = 0, seed: int = 0,
                 length: Optional[int] = None, compact: bool = False):
        super().__init__(g, K, seed=seed, length=length)
        self.compact = bool(compact)
        self.labeled = np.where(g.train_mask if g.train_mask is not None
                                else np.ones(g.num_nodes, bool))[0]
        if len(self.labeled) == 0:
            raise ValueError(
                "mini-batch views: the graph has no labeled nodes "
                "(train_mask selects nothing) to sample batch targets from")
        self.batch_nodes = batch_nodes or max(1, len(self.labeled) // 100)
        self.neighbor_cap = neighbor_cap

    def build(self, i: int, builder: Optional[ViewBuilder] = None):
        rng = self.rng_for(i)
        targets = rng.choice(self.labeled,
                             size=min(self.batch_nodes, len(self.labeled)),
                             replace=False)
        builder = builder or self.make_builder()
        if self.compact:
            return builder.khop_compact(targets, self.neighbor_cap, rng)
        return builder.khop_view(targets, self.neighbor_cap, rng)


class ClusterViewStream(ViewStream):
    """Random cluster picks composed from one shared (read-only)
    ClusterViewCache, one independent RNG stream per index."""

    strategy = "cluster"

    def __init__(self, g: Graph, K: int, clusters: np.ndarray,
                 clusters_per_batch: int = 0, halo_hops: int = 0,
                 seed: int = 0, length: Optional[int] = None,
                 compact: bool = False):
        super().__init__(g, K, seed=seed, length=length)
        self.compact = bool(compact)
        self.cache = ClusterViewCache(g, clusters, halo_hops)
        C = self.cache.num_clusters
        self.clusters_per_batch = min(
            clusters_per_batch or max(1, C // 100), C)
        self.train = (g.train_mask if g.train_mask is not None
                      else np.ones(g.num_nodes, bool))

    def build(self, i: int, builder: Optional[ViewBuilder] = None):
        rng = self.rng_for(i)
        chosen = rng.choice(self.cache.num_clusters,
                            size=self.clusters_per_batch, replace=False)
        builder = builder or self.make_builder()
        if self.compact:
            return builder.cluster_compact(chosen, self.cache, self.train)
        return builder.cluster_view(chosen, self.cache, self.train)
