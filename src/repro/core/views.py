"""Vectorized view-construction engine (paper §2.3/§4.2 host path).

PR 4 made the device step compiled-once, which moved the per-step cost to
*host-side view construction* — the same batch-preparation bottleneck
DistDGL attacks with dedicated samplers. This module owns that path:

- :class:`GraphView` — "a light-weighted logic view of the global graph"
  (per-layer node/edge active masks + a loss mask), the unification all
  three training strategies reduce to.
- :class:`ViewBuilder` — builds views into a ring of *reusable*
  preallocated ``(K, N)``/``(K, E)`` mask buffers: repeated construction
  does zero fresh mask allocations. Single consumer; a view's arrays are
  valid until ``slots`` more views are built from the same builder.
- :class:`ClusterViewCache` — per-cluster member and halo node sets are
  precomputed **once** from the static clustering; each step's active set
  is composed by OR-ing the chosen clusters' cached sets, so the per-step
  ``np.isin`` membership scan and halo edge walks disappear. (Halo
  distributes over unions: grow(A∪B) = grow(A) ∪ grow(B), because an edge
  contributes exactly when its dst is inside — so the union of cached
  per-cluster halos IS the halo of the union, bit-exactly.)
- :class:`ViewStream` — an *indexable* strategy stream: view i is built
  from an RNG stream derived from (seed, i), so any worker can build any
  index and the result is order-stable regardless of scheduling. This is
  what the Trainer's multi-stream prefetch pool fans out over, and what
  makes the view cursor checkpointable (the RNG state IS the index).

``cluster_view_recompute`` keeps the pre-cache per-step recompute as the
parity oracle and benchmark baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.graph.csr import Graph, GraphBlock, build_block
from repro.core.subgraph import bfs_layers, fill_khop_masks


# ---------------------------------------------------------------------------
# the view abstraction
# ---------------------------------------------------------------------------


@dataclass
class GraphView:
    graph: Graph
    K: int
    strategy: str
    node_active: Optional[np.ndarray]    # (K, N) f32 or None (=all)
    edge_active: Optional[np.ndarray]    # (K, M) f32 or None
    loss_mask: np.ndarray                # (N,) f32
    meta: dict

    def as_block(self, gcn_norm: bool = True,
                 csc_plan: bool = False) -> GraphBlock:
        """``csc_plan=True`` attaches the graph's cached CSCPlan (shared by
        all views — only the activity masks differ) for the "csc"
        aggregation backend."""
        block = build_block(self.graph, loss_mask=self.loss_mask > 0,
                            gcn_norm=gcn_norm, csc_plan=csc_plan)
        block.node_active = self.node_active
        block.edge_active = self.edge_active
        return block

    def active_counts(self) -> dict:
        n_nodes = (self.graph.num_nodes if self.node_active is None
                   else int((self.node_active.max(axis=0) > 0).sum()))
        n_edges = (self.graph.num_edges if self.edge_active is None
                   else int((self.edge_active.max(axis=0) > 0).sum()))
        return {"active_nodes": n_nodes, "active_edges": n_edges,
                "targets": int((self.loss_mask > 0).sum())}

    def copy_masks(self) -> "GraphView":
        """Detach from any builder buffers (fresh mask arrays)."""
        return GraphView(
            self.graph, self.K, self.strategy,
            None if self.node_active is None else self.node_active.copy(),
            None if self.edge_active is None else self.edge_active.copy(),
            self.loss_mask.copy(), dict(self.meta))


# ---------------------------------------------------------------------------
# cluster-view cache
# ---------------------------------------------------------------------------


def cluster_view_recompute(g: Graph, clusters: np.ndarray,
                           chosen: np.ndarray, halo_hops: int,
                           train: np.ndarray):
    """The pre-cache per-step recompute: ``np.isin`` membership + halo
    edge walks. Kept as the parity oracle (tests assert the cached path
    is bit-exact against it) and as the ``view_build`` bench baseline.

    Returns (member bool(N), active bool(N), loss f32(N)).
    """
    member = np.isin(clusters, chosen)
    active = member.copy()
    for _ in range(halo_hops):
        # grow along incoming edges (neighbors feeding the members)
        grow = np.zeros(g.num_nodes, bool)
        inside = active[g.dst]
        grow[g.src[inside]] = True
        active |= grow
    loss = (member & train).astype(np.float32)
    if loss.sum() == 0:
        loss = member.astype(np.float32)
    return member, active, loss


class ClusterViewCache:
    """Static per-cluster node sets, computed once per clustering.

    ``members[c]`` — sorted member node ids of cluster c;
    ``halo[c]`` — sorted node ids of c's ``halo_hops``-grown active set.
    A step's active set over any chosen cluster subset is the union of the
    cached sets (halo distributes over unions — see module docstring), so
    composing a view costs O(Σ|halo(c)|), not O(N + E·halo_hops).
    """

    def __init__(self, g: Graph, clusters: np.ndarray, halo_hops: int = 0):
        from repro.core.clustering import cluster_members
        self.g = g
        self.clusters = np.asarray(clusters)
        self.halo_hops = int(halo_hops)
        self.num_clusters = int(self.clusters.max()) + 1
        self.members = cluster_members(self.clusters, self.num_clusters)
        self.halo = (self.members if self.halo_hops == 0
                     else self._grow_halos())

    def _grow_halos(self) -> list:
        """Per-cluster halo BFS over in-edges of the *frontier* only —
        the same CSR-segment expansion as ``bfs_layers`` — with a stamp
        array (last cluster to visit each node) standing in for a visited
        bitmap, so there is nothing to clear between clusters. Total work
        is O(Σ_c in-edges(halo_c)), NOT C full-edge scans per hop (the
        old recompute's cost, fatal at C ~ thousands)."""
        from repro.core.subgraph import _expand_frontier
        g, C = self.g, self.num_clusters
        indptr, order = g.csc()
        src = g.src
        stamp = np.full(g.num_nodes, -1, np.int64)
        halos = []
        for c in range(C):
            frontier = self.members[c]
            stamp[frontier] = c
            grown = [frontier]
            for _ in range(self.halo_hops):
                eidx = _expand_frontier(indptr, order, frontier, 0, None)
                if len(eidx) == 0:
                    break
                cand = src[eidx]
                fresh = np.unique(cand[stamp[cand] != c])
                if len(fresh) == 0:
                    break
                stamp[fresh] = c
                grown.append(fresh)
                frontier = fresh
            halos.append(np.unique(np.concatenate(grown))
                         if len(grown) > 1 else np.asarray(frontier))
        return halos

    def compose(self, chosen: Sequence[int], member_out: np.ndarray,
                active_out: np.ndarray) -> None:
        """OR the chosen clusters' cached sets into the caller's (N,) bool
        scratch buffers."""
        member_out.fill(False)
        member_out[np.concatenate([self.members[c] for c in chosen])] = True
        active_out.fill(False)
        active_out[np.concatenate([self.halo[c] for c in chosen])] = True


# ---------------------------------------------------------------------------
# the builder: reusable mask buffers
# ---------------------------------------------------------------------------


class _Slot:
    def __init__(self, K: int, N: int, E: int):
        self.node = np.zeros((K, N), np.float32)
        self.edge = np.zeros((K, E), np.float32)
        self.loss = np.zeros(N, np.float32)


class ViewBuilder:
    """Builds GraphViews into a ring of preallocated mask buffers.

    Repeated view construction does **zero** fresh ``(K, N)``/``(K, E)``
    allocations: each build rotates to the next slot and overwrites it.
    Consequently a built view's arrays alias builder memory and stay valid
    only until ``slots`` more views are built — the Trainer's pipeline
    consumes (shards + stages) each view before the ring wraps, and each
    prefetch worker owns a private builder. Callers that need detached
    views use :meth:`GraphView.copy_masks`.
    """

    def __init__(self, g: Graph, K: int, slots: int = 2):
        self.g = g
        self.K = K
        N, E = g.num_nodes, g.num_edges
        g.csc()     # no-op when cached; the prefetch pool materializes it
                    # before fan-out, direct users pay it here once
        self._slots = [_Slot(K, N, E) for _ in range(max(1, slots))]
        self._turn = 0
        self.builds = 0
        # shared scratch (single consumer; never escapes into views)
        self._visited = np.zeros(N, bool)
        self._in_hop = np.zeros((K + 1, N), bool)
        self._member = np.zeros(N, bool)
        self._active = np.zeros(N, bool)

    def _next_slot(self) -> _Slot:
        slot = self._slots[self._turn % len(self._slots)]
        self._turn += 1
        self.builds += 1
        return slot

    # -- mini-batch (k-hop BFS) views -----------------------------------------

    def khop_view(self, targets: np.ndarray, neighbor_cap: int = 0,
                  rng: Optional[np.random.Generator] = None) -> GraphView:
        """Vectorized :func:`repro.core.subgraph.khop_subgraph_view` into
        reused buffers; bit-exact with the allocating function."""
        slot = self._next_slot()
        hops, visited = bfs_layers(self.g, targets, self.K, neighbor_cap,
                                   rng, _visited_out=self._visited)
        fill_khop_masks(self.g, hops, self.K, slot.node, slot.edge,
                        in_hop=self._in_hop)
        slot.loss.fill(0.0)
        slot.loss[np.unique(targets)] = 1.0
        return GraphView(self.g, self.K, "mini", slot.node, slot.edge,
                         slot.loss,
                         {"targets": int(len(np.unique(targets))),
                          "touched": int(visited.sum())})

    # -- cluster-batch views ---------------------------------------------------

    def cluster_view(self, chosen: np.ndarray, cache: ClusterViewCache,
                     train: Optional[np.ndarray] = None) -> GraphView:
        """Compose the chosen clusters' cached member/halo sets; bit-exact
        with :func:`cluster_view_recompute`."""
        g = self.g
        slot = self._next_slot()
        cache.compose(chosen, self._member, self._active)
        member, active = self._member, self._active
        slot.node[:] = active                    # (N,) bool -> (K, N) f32
        slot.edge[:] = active[g.src] & active[g.dst]
        if train is None:
            train = (g.train_mask if g.train_mask is not None
                     else np.ones(g.num_nodes, bool))
        np.multiply(member, train, out=slot.loss, casting="unsafe")
        if not slot.loss.any():
            slot.loss[:] = member
        return GraphView(g, self.K, "cluster", slot.node, slot.edge,
                         slot.loss,
                         {"clusters": [int(c) for c in chosen],
                          "members": int(member.sum()),
                          "active": int(active.sum())})


# ---------------------------------------------------------------------------
# indexable strategy streams (per-index RNG -> order-stable parallel builds)
# ---------------------------------------------------------------------------


class ViewStream:
    """An indexable stream of GraphViews: ``build(i)`` is a pure function
    of the index (per-view RNG streams derived from ``(seed, i)``), so

    - the Trainer's multi-stream prefetch pool can build views on any
      worker in any order and emit them in index order, bit-identically to
      sequential construction, and
    - the stream position is a single checkpointable integer
      (``cursor``) — ``Trainer.restore`` fast-forwards with ``seek``.

    Also a plain iterator (``next`` builds at ``cursor`` and advances) —
    iterator consumers receive *detached* views (fresh mask arrays, the
    old generator contract), so buffering several is safe. Zero-copy
    buffer-ring access is the ``build(i, builder)`` path the Trainer's
    prefetch pool uses, where each view is consumed before its slot is
    rebuilt.
    """

    strategy = "?"

    def __init__(self, g: Graph, K: int, seed: int = 0,
                 length: Optional[int] = None):
        self.g = g
        self.K = K
        self.seed = int(seed)
        self.length = length
        self.cursor = 0
        self._builder: Optional[ViewBuilder] = None

    # -- the indexable API -----------------------------------------------------

    def rng_for(self, i: int) -> np.random.Generator:
        """The order-stable per-view RNG stream."""
        return np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(int(i),)))

    def build(self, i: int,
              builder: Optional[ViewBuilder] = None) -> GraphView:
        raise NotImplementedError

    def make_builder(self) -> Optional[ViewBuilder]:
        """A private ViewBuilder for one consumer thread (None when the
        stream needs no buffers — the static global view)."""
        return ViewBuilder(self.g, self.K)

    def seek(self, i: int) -> None:
        self.cursor = int(i)

    # -- iterator compatibility ------------------------------------------------

    def __iter__(self) -> Iterator[GraphView]:
        return self

    def __next__(self) -> GraphView:
        if self.length is not None and self.cursor >= self.length:
            raise StopIteration
        if self._builder is None:
            self._builder = self.make_builder()
        view = self.build(self.cursor, self._builder)
        self.cursor += 1
        if self._builder is not None:
            # detach from the builder's buffer ring (static streams have
            # no builder and must keep yielding the identical object)
            view = view.copy_masks()
        return view


class GlobalViewStream(ViewStream):
    """The static full-graph view — every index is the same object, so the
    Trainer's staging cache recognizes it and stages exactly once."""

    strategy = "global"

    def __init__(self, view: GraphView, length: Optional[int] = None):
        super().__init__(view.graph, view.K, seed=0, length=length)
        self._view = view

    def build(self, i: int, builder=None) -> GraphView:
        return self._view

    def make_builder(self) -> None:
        return None


class MiniBatchViewStream(ViewStream):
    """Random labeled targets + K-hop BFS active sets, one independent RNG
    stream per index."""

    strategy = "mini"

    def __init__(self, g: Graph, K: int, batch_nodes: int = 0,
                 neighbor_cap: int = 0, seed: int = 0,
                 length: Optional[int] = None):
        super().__init__(g, K, seed=seed, length=length)
        self.labeled = np.where(g.train_mask if g.train_mask is not None
                                else np.ones(g.num_nodes, bool))[0]
        if len(self.labeled) == 0:
            raise ValueError(
                "mini-batch views: the graph has no labeled nodes "
                "(train_mask selects nothing) to sample batch targets from")
        self.batch_nodes = batch_nodes or max(1, len(self.labeled) // 100)
        self.neighbor_cap = neighbor_cap

    def build(self, i: int,
              builder: Optional[ViewBuilder] = None) -> GraphView:
        rng = self.rng_for(i)
        targets = rng.choice(self.labeled,
                             size=min(self.batch_nodes, len(self.labeled)),
                             replace=False)
        builder = builder or ViewBuilder(self.g, self.K)
        return builder.khop_view(targets, self.neighbor_cap, rng)


class ClusterViewStream(ViewStream):
    """Random cluster picks composed from one shared (read-only)
    ClusterViewCache, one independent RNG stream per index."""

    strategy = "cluster"

    def __init__(self, g: Graph, K: int, clusters: np.ndarray,
                 clusters_per_batch: int = 0, halo_hops: int = 0,
                 seed: int = 0, length: Optional[int] = None):
        super().__init__(g, K, seed=seed, length=length)
        self.cache = ClusterViewCache(g, clusters, halo_hops)
        C = self.cache.num_clusters
        self.clusters_per_batch = min(
            clusters_per_batch or max(1, C // 100), C)
        self.train = (g.train_mask if g.train_mask is not None
                      else np.ones(g.num_nodes, bool))

    def build(self, i: int,
              builder: Optional[ViewBuilder] = None) -> GraphView:
        rng = self.rng_for(i)
        chosen = rng.choice(self.cache.num_clusters,
                            size=self.clusters_per_batch, replace=False)
        builder = builder or ViewBuilder(self.g, self.K)
        return builder.cluster_view(chosen, self.cache, self.train)
