"""Hybrid-parallel distributed training engine (paper §1/§4.3).

Conventional GNN data-parallelism gives each worker a whole subgraph; the
paper instead computes **each batch by a group of workers jointly**: node
and edge tensors are partition-sharded, parameters are replicated, and each
NN-TGAR stage runs as a local compute + a master/mirror halo exchange. We
realize the worker group as a mesh axis (default ``"graph"``) and the halo
exchange as `lax.all_to_all` over a precomputed static plan inside
``shard_map``. Gradients of the replicated parameters are combined with
``psum`` — the paper's NN-Reduce stage.

Communication matches §4.1: a value moves only master→mirror (broadcast
phase) and partial aggregates move mirror→master (reduce phase); traffic is
O(#mirrors) per layer, not O(edges) — the paper's "local message bombing"
fix. Attention models (softmax combine) add a max- and a sum-reduce pass —
the distributed segment-softmax.

The per-shard Sum stage is the shared combine engine of
:mod:`repro.core.aggregate`: shard-local partial aggregates run through the
selected :class:`AggregationBackend` (``"reference"`` jnp segment ops or
the ``"csc"`` Pallas kernels over per-shard cached CSCPlans) and are
finalized through a :class:`ShardContext` wrapping the halo exchange.
The stacked plan arrays staged here (``csc_gather``/``csc_local``,
(P, nb, L) with identical padded shapes across shards) feed the kernels
directly as scalar-prefetch operands — the per-edge gather is fused into
the kernel grid, so no shard ever materializes a pre-gathered message
tensor.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.aggregate import ShardContext, combine, get_backend
from repro.core.mpgnn import MPGNNModel
from repro.core.partition import ShardedGraph
from repro.core.tgar import TGARLayer, tree_take, NEG
from repro.kernels.ops import CSCPlan
from repro.utils.compat import shard_map

Axis = str


# ---------------------------------------------------------------------------
# halo exchange primitives (run inside shard_map; arrays are per-device)
# ---------------------------------------------------------------------------


def _exchange(buf, axis: Axis):
    """buf (P, s_pad, D) -> (P, s_pad, D) with row q = what device q sent."""
    return jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                              tiled=False)


def _bcast_array(arr, send_idx, send_mask, recv_slot, recv_mask, n_mir_pad,
                 axis: Axis):
    """Master values (n_m_pad, ...) -> mirror buffer (n_mir_pad, ...)."""
    shape = arr.shape
    flat = arr.reshape(shape[0], -1)
    buf = flat[send_idx] * send_mask[..., None]          # (P, s_pad, D)
    got = _exchange(buf, axis)
    got = got * recv_mask[..., None]
    mir = jnp.zeros((n_mir_pad, flat.shape[1]), flat.dtype)
    mir = mir.at[recv_slot.reshape(-1)].add(
        got.reshape(-1, flat.shape[1]), mode="drop")
    return mir.reshape((n_mir_pad,) + shape[1:])


def _reduce_array(mir, send_idx, send_mask, recv_slot, recv_mask, n_m_pad,
                  axis: Axis, op: str = "sum"):
    """Mirror partials (n_mir_pad, ...) -> master accumulation (n_m_pad, ...)."""
    shape = mir.shape
    flat = mir.reshape(shape[0], -1)
    picked = flat[recv_slot]                              # (P, s_pad, D)
    if op == "sum":
        buf = picked * recv_mask[..., None]
    else:  # max
        buf = jnp.where(recv_mask[..., None] > 0, picked, NEG)
    got = _exchange(buf, axis)                            # rows by mirror holder
    D = flat.shape[1]
    if op == "sum":
        got = got * send_mask[..., None]
        out = jnp.zeros((n_m_pad, D), flat.dtype)
        out = out.at[send_idx.reshape(-1)].add(got.reshape(-1, D),
                                               mode="drop")
    else:
        got = jnp.where(send_mask[..., None] > 0, got, NEG)
        out = jnp.full((n_m_pad, D), NEG, flat.dtype)
        out = out.at[send_idx.reshape(-1)].max(got.reshape(-1, D),
                                               mode="drop")
    return out.reshape((n_m_pad,) + shape[1:])


def _bcast_tree(tree, shard, axis):
    f = lambda a: _bcast_array(a, shard["send_idx"], shard["send_mask"],
                               shard["recv_slot"], shard["recv_mask"],
                               shard["n_mir_pad"], axis)
    return jax.tree_util.tree_map(f, tree)


# ---------------------------------------------------------------------------
# distributed TGAR layer forward
# ---------------------------------------------------------------------------


def _layer_forward_sharded(layer: TGARLayer, lp, h, shard, k: int,
                           axis: Axis, backend=None):
    n_m_pad = shard["n_m_pad"]
    n_mir_pad = shard["n_mir_pad"]
    n_tot = n_m_pad + n_mir_pad
    src, dst = shard["src_local"], shard["dst_local"]
    em = shard["edge_mask"] * shard["edge_active"][k]

    # NN-T on masters, then master -> mirror halo broadcast (the paper's
    # "synchronize only the masters used": one value per mirror per layer)
    n = layer.transform(lp, h)
    n_mir = _bcast_tree(n, shard, axis)
    n_all = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b.astype(a.dtype)], axis=0),
        n, n_mir)

    # NN-G on local edges
    n_src = tree_take(n_all, src)
    n_dst = tree_take(n_all, dst)
    msg = layer.gather(lp, n_src, n_dst, shard["edge_attr"],
                       shard["edge_weight"], em)

    # Sum: shard-local partial aggregation (shared combine engine) +
    # mirror->master halo finalize via the exchange plan
    red = functools.partial(_reduce_array, send_idx=shard["send_idx"],
                            send_mask=shard["send_mask"],
                            recv_slot=shard["recv_slot"],
                            recv_mask=shard["recv_mask"],
                            n_m_pad=n_m_pad, axis=axis)
    ctx = ShardContext(
        n_master=n_m_pad,
        reduce=lambda arr, op: red(arr, op=op),
        bcast=lambda arr: _bcast_tree(arr, shard, axis))
    M = combine(layer.combine, msg, dst, n_tot, em, backend=backend,
                plan=shard.get("csc_plan"), shard=ctx)

    h_next = layer.apply(lp, h, M)
    h_next = h_next * shard["node_active"][k][:, None]
    return h_next * shard["master_mask"][:, None]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class HybridParallelEngine:
    """Runs an MPGNNModel over a partitioned graph with a device group.

    Requires a mesh whose ``axis`` has exactly ``plan.P`` devices. The same
    engine serves training (``train_step``) and inference (``infer``) — the
    paper's unified implementation. ``backend`` selects the Sum-stage
    aggregation backend (defaults to the model's ``aggregate_backend``);
    with ``"csc"`` the per-shard CSCPlans are built once at staging time
    and reused by every batch/view — the paper's reused CSC indexing.
    """

    def __init__(self, model: MPGNNModel, sharded: ShardedGraph,
                 mesh: Optional[Mesh] = None, axis: Axis = "graph",
                 backend=None):
        self.model = model
        self.sg = sharded
        self.plan = sharded.plan
        self.axis = axis
        if backend is None:
            backend = getattr(model, "aggregate_backend", "reference")
        self.backend = get_backend(backend)
        self._csc_meta = None
        if mesh is None:
            devs = np.array(jax.devices()[: self.plan.P])
            if devs.size < self.plan.P:
                raise ValueError(
                    f"need {self.plan.P} devices, have {len(jax.devices())}")
            mesh = Mesh(devs, (axis,))
        self.mesh = mesh
        self._device_data = self._stage()

    # -- data staging ---------------------------------------------------------

    def _stage(self):
        plan, sg = self.plan, self.sg
        shd = lambda a: jax.device_put(
            a, NamedSharding(self.mesh, P(self.axis)))
        data = {
            "masters": shd(plan.masters),
            "master_mask": shd(plan.master_mask),
            "src_local": shd(plan.src_local),
            "dst_local": shd(plan.dst_local),
            "edge_mask": shd(plan.edge_mask),
            "send_idx": shd(plan.send_idx),
            "send_mask": shd(plan.send_mask),
            "recv_slot": shd(plan.recv_slot),
            "recv_mask": shd(plan.recv_mask),
            "x": shd(sg.x),
            "y": shd(sg.y),
            "edge_weight": shd(sg.edge_weight),
        }
        if sg.edge_attr is not None:
            data["edge_attr"] = shd(sg.edge_attr)
        if self.backend.name == "csc":
            plans = plan.csc_plans()
            self._csc_meta = plans[0]
            data["csc_gather"] = shd(np.stack(
                [p.gather_idx for p in plans]))
            data["csc_local"] = shd(np.stack(
                [p.local_ids for p in plans]))
            # the plans' inverse maps: per-shard (E_pad,) destination
            # rows, scalar-prefetched by the fused backward kernels so
            # the sharded grad path never falls back to g[ids] gathers
            data["csc_dst"] = shd(np.stack(
                [p.edge_dst for p in plans]))
        return data

    def stage_view(self, view_arrays: dict, retry=None):
        """Stage sharded view arrays onto the device mesh. With a
        :class:`repro.runtime.faults.Retrier`, the device_put batch is a
        retryable ``device_put`` stage — transfers are idempotent (host
        arrays are unchanged by a failed put), so a transient staging
        failure re-stages the same view."""
        shd = lambda a: jax.device_put(
            a, NamedSharding(self.mesh, P(self.axis)))

        def put():
            return {k: shd(v) for k, v in view_arrays.items()}

        if retry is None:
            return put()
        return retry("device_put", put)

    def default_view_arrays(self):
        plan = self.plan
        K = self.model.K
        return {
            "node_active": np.broadcast_to(
                plan.master_mask[:, None, :],
                (plan.P, K, plan.n_m_pad)).copy(),
            "edge_active": np.broadcast_to(
                plan.edge_mask[:, None, :],
                (plan.P, K, plan.e_pad)).copy(),
            "loss_mask": plan.master_mask.copy(),
        }

    # -- shard-local forward ----------------------------------------------------

    def _local_shard(self, data, view):
        """Squeeze the leading (1-sized) partition axis of shard blocks."""
        sq = lambda a: a[0]
        shard = {k: sq(v) for k, v in data.items()}
        shard.update({k: sq(v) for k, v in view.items()})
        shard["n_m_pad"] = self.plan.n_m_pad
        shard["n_mir_pad"] = self.plan.n_mir_pad
        if "edge_attr" not in shard:
            shard["edge_attr"] = None
        if "csc_gather" in shard:
            meta = self._csc_meta
            shard["csc_plan"] = CSCPlan(
                shard.pop("csc_gather"), shard.pop("csc_local"),
                shard.pop("csc_dst"),
                meta.num_blocks, meta.block_n, meta.block_e,
                meta.num_segments, meta.num_edges)
        return shard

    def _forward_local(self, params, shard):
        h = shard["x"]
        for k, layer in enumerate(self.model.layers):
            h = _layer_forward_sharded(layer, params["layers"][k], h,
                                       shard, k, self.axis,
                                       backend=self.backend)
        return self.model.decode(params, h)

    def _local_objective(self, params, shard):
        """Local loss contribution / global target count (see DESIGN.md:
        grads of the replicated params are psum'd by the caller — the
        paper's NN-Reduce)."""
        logits = self._forward_local(params, shard)
        lm = shard["loss_mask"] * shard["master_mask"]
        logits32 = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits32, axis=-1)
        ll = jnp.take_along_axis(logits32, shard["y"][:, None], axis=-1)[:, 0]
        nll = (logz - ll) * lm
        local_sum = jnp.sum(nll)
        count = jnp.sum(lm)
        total = jax.lax.psum(count, self.axis)
        return local_sum / jnp.maximum(total, 1.0)

    # -- public API ---------------------------------------------------------------

    def make_loss_and_grad(self):
        specs_data = {k: P(self.axis) for k in self._device_data}
        specs_view = {k: P(self.axis)
                      for k in ("node_active", "edge_active", "loss_mask")}

        @functools.partial(
            jax.jit,
            static_argnames=())
        def fn(params, data, view):
            def shard_fn(params, data, view):
                shard = self._local_shard(data, view)
                obj, grads = jax.value_and_grad(self._local_objective)(
                    params, shard)
                loss = jax.lax.psum(obj, self.axis)
                grads = jax.lax.psum(grads, self.axis)
                return loss, grads

            return shard_map(
                shard_fn, mesh=self.mesh,
                in_specs=(P(), specs_data, specs_view),
                out_specs=(P(), P()),
            )(params, data, view)

        return fn

    def make_train_step(self, opt):
        lg = self.make_loss_and_grad()

        @jax.jit
        def step(params, opt_state, data, view):
            loss, grads = lg(params, data, view)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        def run(params, opt_state, view_arrays):
            view = self.stage_view(view_arrays)
            return step(params, opt_state, self._device_data, view)

        return run

    def make_infer(self, on_trace: Optional[Callable[[], None]] = None):
        specs_data = {k: P(self.axis) for k in self._device_data}
        specs_view = {k: P(self.axis)
                      for k in ("node_active", "edge_active", "loss_mask")}

        # jit the shard_map closure ONCE (like make_loss_and_grad): every
        # call used to re-trace the whole distributed forward.
        # ``on_trace`` runs as a Python side effect of tracing only — the
        # Trainer uses it as a compile counter (retrace = contract breach).
        @jax.jit
        def infer_jit(params, data, view):
            if on_trace is not None:
                on_trace()

            def shard_fn(params, data, view):
                shard = self._local_shard(data, view)
                logits = self._forward_local(params, shard)
                return logits[None]

            return shard_map(
                shard_fn, mesh=self.mesh,
                in_specs=(P(), specs_data, specs_view),
                out_specs=P(self.axis),
            )(params, data, view)

        def fn(params, view_arrays):
            view = self.stage_view(view_arrays)
            # (P, n_m_pad, C) aligned with plan.masters
            return infer_jit(params, self._device_data, view)

        # the jitted core is exposed so repro.analysis can trace the
        # actual compiled computation (fn itself stages host arrays)
        fn.jitted = infer_jit
        return fn

    def gather_predictions(self, logits_sharded) -> np.ndarray:
        """(P, n_m_pad, C) -> (N, C) in global node order: one masked
        scatter over all partitions (valid master slots land on their
        global node row; padding slots drop out with the mask)."""
        plan = self.plan
        lg = np.asarray(logits_sharded)
        out = np.zeros((len(plan.owner), lg.shape[-1]), np.float32)
        valid = plan.master_mask > 0                      # (P, n_m_pad)
        out[plan.masters[valid]] = lg[valid]
        return out
