"""Flexible training strategies over the GraphView abstraction (paper §4.2/4.3).

A :class:`GraphView` is "a light-weighted logic view of the global graph":
per-layer node/edge active masks + a loss mask. The same view drives both
the single-shard path (``as_block``) and the distributed hybrid-parallel
engine (``shard_view`` maps global masks onto a PartitionPlan). Global-,
mini- and cluster-batch are all expressed as views — the unification the
paper claims as its second contribution.

View *construction* lives in :mod:`repro.core.views` (the vectorized
engine: reusable mask buffers, the cluster-view cache, indexable
per-index-RNG streams). This module keeps the strategy entry points:

- the legacy generator API (``mini_batch_views`` / ``cluster_batch_views``)
  — sequential RNG, detached (freshly copied) mask arrays, semantics
  unchanged — now running on the vectorized builder underneath, and
- :func:`strategy_views`, which returns a :class:`repro.core.views.ViewStream`
  — the indexable form the Trainer's multi-stream prefetch pool and the
  checkpointable view cursor require.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.graph.csr import Graph
from repro.core.views import (ClusterViewCache, ClusterViewStream,
                              CompactView, GlobalViewStream, GraphView,
                              MiniBatchViewStream, ViewBuilder, ViewStream)

__all__ = [
    "GraphView", "ViewStream", "global_batch_view", "mini_batch_views",
    "cluster_batch_views", "strategy_views", "shard_view",
    "shard_view_loop",
]


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


def global_batch_view(g: Graph, K: int) -> GraphView:
    """Full graph convolution each step (paper: stable, costliest step)."""
    loss = (g.train_mask if g.train_mask is not None
            else np.ones(g.num_nodes, bool)).astype(np.float32)
    return GraphView(g, K, "global", None, None, loss,
                     {"targets": int(loss.sum()),
                      "active_nodes": int(g.num_nodes),
                      "active_edges": int(g.num_edges)})


def mini_batch_views(g: Graph, K: int, batch_nodes: int = 0,
                     neighbor_cap: int = 0, seed: int = 0,
                     steps: Optional[int] = None) -> Iterator[GraphView]:
    """Random labeled targets + K-hop BFS active sets. ``neighbor_cap``
    enables random neighbor sampling (off by default — non-sampling is the
    paper's point). Paper defaults: 1% of labeled nodes per step.

    Legacy generator API: one sequential RNG (identical target sequences
    to the pre-engine implementation when ``neighbor_cap == 0``) and
    detached mask arrays. The Trainer path uses the indexable
    :class:`repro.core.views.MiniBatchViewStream` instead.
    """
    rng = np.random.default_rng(seed)
    labeled = np.where(g.train_mask if g.train_mask is not None
                       else np.ones(g.num_nodes, bool))[0]
    if len(labeled) == 0:
        # without this guard the generator silently yields empty views
        # (zero targets, zero loss) forever — fail loudly instead
        raise ValueError(
            "mini_batch_views: the graph has no labeled nodes "
            "(train_mask selects nothing) to sample batch targets from")
    bsz = batch_nodes or max(1, len(labeled) // 100)
    builder = ViewBuilder(g, K, slots=1)   # views are copied out below
    i = 0
    while steps is None or i < steps:
        targets = rng.choice(labeled, size=min(bsz, len(labeled)),
                             replace=False)
        yield builder.khop_view(targets, neighbor_cap, rng).copy_masks()
        i += 1


def cluster_batch_views(g: Graph, K: int, clusters: np.ndarray,
                        clusters_per_batch: int = 0, halo_hops: int = 0,
                        seed: int = 0, steps: Optional[int] = None
                        ) -> Iterator[GraphView]:
    """Cluster-batched training (paper §2.3).

    Picks random clusters; active nodes = cluster members (+ optional 1- or
    2-hop boundary halo — the paper's extension over Cluster-GCN, App. B);
    active edges = edges inside the active set; loss on labeled members.

    Per-cluster member/halo sets are cached once (ClusterViewCache) and
    composed per step — the per-step ``np.isin`` membership scan and halo
    edge walks of the old implementation are gone (bit-exact against
    :func:`repro.core.views.cluster_view_recompute`, the retained oracle).
    """
    rng = np.random.default_rng(seed)
    num_clusters = int(clusters.max()) + 1
    cpb = clusters_per_batch or max(1, num_clusters // 100)
    train = (g.train_mask if g.train_mask is not None
             else np.ones(g.num_nodes, bool))
    cache = ClusterViewCache(g, clusters, halo_hops)
    builder = ViewBuilder(g, K, slots=1)   # views are copied out below
    i = 0
    while steps is None or i < steps:
        chosen = rng.choice(num_clusters, size=min(cpb, num_clusters),
                            replace=False)
        yield builder.cluster_view(chosen, cache, train).copy_masks()
        i += 1


def strategy_views(g: Graph, strategy: str, K: int, seed: int = 0,
                   steps: Optional[int] = None,
                   batch_nodes: int = 0,
                   clusters: Optional[np.ndarray] = None,
                   clusters_per_batch: int = 0,
                   halo_hops: int = 1,
                   neighbor_cap: int = 0,
                   compact: bool = False) -> ViewStream:
    """One entry point for all three strategies (paper §2.3): returns the
    indexable :class:`ViewStream` the Trainer / examples / benchmarks
    drive (also a plain iterator, so ``next()`` keeps working). View i is
    a pure function of ``(seed, i)``, which is what makes the Trainer's
    multi-stream prefetch deterministic and the stream cursor
    checkpointable. The ``cluster`` strategy computes label-propagation
    communities when ``clusters`` is not supplied.

    ``compact=True`` makes the mini/cluster streams yield
    :class:`repro.core.views.CompactView` (relabeled sampled subgraphs;
    same node/edge sets and RNG draws as the dense views, O(view) host
    cost). The global strategy is already the whole graph and ignores it.
    """
    if strategy == "global":
        # the global view is static — every index yields the SAME object
        # so consumers (Trainer) can recognize it and stage it once
        return GlobalViewStream(global_batch_view(g, K), length=steps)
    if strategy == "mini":
        return MiniBatchViewStream(g, K, batch_nodes=batch_nodes,
                                   neighbor_cap=neighbor_cap,
                                   seed=seed, length=steps,
                                   compact=compact)
    if strategy == "cluster":
        if clusters is None:
            from repro.core.clustering import label_propagation_clusters
            clusters = label_propagation_clusters(
                g, max_cluster_size=max(64, g.num_nodes // 20), seed=seed)
        return ClusterViewStream(g, K, clusters,
                                 clusters_per_batch=clusters_per_batch,
                                 halo_hops=halo_hops, seed=seed,
                                 length=steps, compact=compact)
    raise ValueError(f"unknown strategy {strategy!r} "
                     "(expected global|mini|cluster)")


# ---------------------------------------------------------------------------
# sharding a view onto a partition plan (for the distributed engine)
# ---------------------------------------------------------------------------


def shard_view(plan, view: GraphView) -> dict:
    """Map a GraphView's global masks onto per-partition local arrays.

    Returns numpy arrays stacked over partitions, ready for device_put:
      node_active (P, K, n_m_pad), edge_active (P, K, e_pad),
      loss_mask (P, n_m_pad).

    Fully vectorized: one ``np.take`` over the stacked ``plan.masters`` /
    ``plan.edge_orig`` index arrays per mask, so the host cost per step is
    O(1) Python regardless of P — this is the per-step hot path the
    Trainer's prefetch workers run (see :mod:`repro.core.trainer`).
    """
    if isinstance(view, CompactView):
        return _shard_compact(plan, view)
    P = plan.P
    K = view.K
    n_m_pad = plan.masters.shape[1]
    e_pad = plan.src_local.shape[1]
    loss = view.loss_mask[plan.masters] * plan.master_mask
    if view.node_active is None:
        node_active = np.broadcast_to(plan.master_mask[:, None, :],
                                      (P, K, n_m_pad)).copy()
    else:
        # (K, P, n_m_pad) -> (P, K, n_m_pad)
        node_active = (np.take(view.node_active, plan.masters, axis=1)
                       .transpose(1, 0, 2)
                       * plan.master_mask[:, None, :])
    if view.edge_active is None:
        edge_active = np.broadcast_to(plan.edge_mask[:, None, :],
                                      (P, K, e_pad)).copy()
    else:
        edge_active = (np.take(view.edge_active, plan.edge_orig, axis=1)
                       .transpose(1, 0, 2)
                       * plan.edge_mask[:, None, :])
    return {"node_active": np.ascontiguousarray(node_active, np.float32),
            "edge_active": np.ascontiguousarray(edge_active, np.float32),
            "loss_mask": np.ascontiguousarray(loss, np.float32)}


def _shard_compact(plan, view: CompactView) -> dict:
    """Sharded masks straight from a CompactView's id lists.

    Scatters only the view's |nodes| + |edges| entries into zeroed
    per-partition buffers via the plan's cached inverse locators —
    O(view) host work per step instead of the dense path's O(P·K·N)
    gathers. Bit-exact against ``shard_view(plan, view.to_dense())``:
    slots the view never touches stay zero, which is exactly what the
    dense path's ``* master_mask`` / ``* edge_mask`` produce.
    """
    P, K = plan.P, view.K
    n_m_pad = plan.masters.shape[1]
    e_pad = plan.src_local.shape[1]
    node_active = np.zeros((P, K, n_m_pad), np.float32)
    edge_active = np.zeros((P, K, e_pad), np.float32)
    loss = np.zeros((P, n_m_pad), np.float32)
    nslot = plan.node_locator()
    owner = plan.owner
    epart, eslot = plan.edge_locator()
    lidx = np.flatnonzero(view.loss_local)
    if len(lidx):
        ln = view.nodes[lidx]
        loss[owner[ln], nslot[ln]] = view.loss_local[lidx]
    off = view.hop_offsets
    for k in range(K):
        act = view.nodes[: int(off[K - 1 - k])]
        if len(act):
            node_active[owner[act], k, nslot[act]] = 1.0
        ids = view.edge_ids[view.edge_layer_mask(k)]
        if len(ids):
            edge_active[epart[ids], k, eslot[ids]] = 1.0
    return {"node_active": node_active, "edge_active": edge_active,
            "loss_mask": loss}


def shard_view_loop(plan, view: GraphView) -> dict:
    """Reference per-partition loop implementation of :func:`shard_view`.

    Kept as the parity oracle (tests assert bit-exact agreement with the
    vectorized path) and as the naive host-side baseline timed by
    ``benchmarks/strategies_bench.py``.
    """
    P = plan.P
    K = view.K
    n_m_pad = plan.masters.shape[1]
    e_pad = plan.src_local.shape[1]
    node_active = np.ones((P, K, n_m_pad), np.float32)
    edge_active = np.ones((P, K, e_pad), np.float32)
    loss = np.zeros((P, n_m_pad), np.float32)
    for p in range(P):
        mids = plan.masters[p]
        loss[p] = view.loss_mask[mids] * plan.master_mask[p]
        if view.node_active is not None:
            node_active[p] = (view.node_active[:, mids]
                              * plan.master_mask[p][None, :])
        else:
            node_active[p] *= plan.master_mask[p][None, :]
        eids = plan.edge_orig[p]
        if view.edge_active is not None:
            edge_active[p] = (view.edge_active[:, eids]
                              * plan.edge_mask[p][None, :])
        else:
            edge_active[p] *= plan.edge_mask[p][None, :]
    return {"node_active": node_active, "edge_active": edge_active,
            "loss_mask": loss}
