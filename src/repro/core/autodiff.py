"""Explicit NN-TGAR backward schedule (paper §3.3, App. A.2/A.3).

GraphTheta implements auto-differentiation by pairing every stage with a
backward version and executing K+2 reverse passes of NN-TGAR: the gradient
of a node flows to its in-neighbors along reversed edges ("if a node
aggregates information from its neighbor along every out-edge in the
forward, it aggregates gradient along every in-edge in the backward").

This module materializes that schedule explicitly — stage-by-stage VJPs
orchestrated in the paper's order — instead of letting ``jax.grad`` trace
the whole model. Tests assert it produces bit-comparable gradients to
``jax.grad``, which is the reproduction of the paper's App. A.2 equivalence
proof. (The production engine uses ``jax.grad``; this is the reference
semantics.)
"""
from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp

from repro.core.mpgnn import MPGNNModel
from repro.core.tgar import combine_messages, tree_take


def _stage_masks(block, k):
    em = block.edge_mask
    if block.edge_active is not None:
        em = em * block.edge_active[k]
    na = None
    if block.node_active is not None:
        na = block.node_active[k]
    return em, na


def explicit_loss_and_grad(model: MPGNNModel, params, block):
    """Forward (storing stage residuals) + explicit reverse schedule.

    Returns (loss, grads) with grads matching ``jax.grad(loss_block)``.
    """
    n_pad = block.num_nodes_padded

    # ---------------- forward: K passes of NN-TGA, keep stage closures ------
    h = block.x
    residuals: List[dict] = []
    for k, layer in enumerate(model.layers):
        lp = params["layers"][k]
        em, na = _stage_masks(block, k)

        t_fn = lambda p_, h_, layer_=layer: layer_.transform(p_, h_)
        n, t_vjp = jax.vjp(t_fn, lp, h)

        def g_fn(p_, n_, layer_=layer, em_=em):
            n_src = tree_take(n_, block.src)
            n_dst = tree_take(n_, block.dst)
            return layer_.gather(p_, n_src, n_dst, block.edge_attr,
                                 block.edge_weight, em_)
        msg, g_vjp = jax.vjp(g_fn, lp, n)

        def s_fn(msg_, layer_=layer, em_=em):
            return combine_messages(layer_, msg_, block.dst, n_pad, em_)
        M, s_vjp = jax.vjp(s_fn, msg)

        def a_fn(p_, h_, M_, layer_=layer, na_=na):
            out = layer_.apply(p_, h_, M_)
            if na_ is not None:
                out = out * na_[:, None]
            return out * block.node_mask[:, None]
        h_next, a_vjp = jax.vjp(a_fn, lp, h, M)

        residuals.append({"t_vjp": t_vjp, "g_vjp": g_vjp, "s_vjp": s_vjp,
                          "a_vjp": a_vjp})
        h = h_next

    # ---------------- decoder + loss: two NN-T stages ------------------------
    def dec_fn(p_, h_):
        return model.decode({"decoder": p_["decoder"],
                             **({"dec_fc": p_["dec_fc"]}
                                if "dec_fc" in p_ else {})}, h_)
    dec_params = {k_: v for k_, v in params.items() if k_ != "layers"}
    logits, dec_vjp = jax.vjp(dec_fn, dec_params, h)

    def loss_fn(logits_):
        lg = logits_.astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, block.y[:, None], axis=-1)[:, 0]
        lm = block.loss_mask
        return jnp.sum((logz - ll) * lm) / jnp.maximum(jnp.sum(lm), 1.0)
    loss, l_vjp = jax.vjp(loss_fn, logits)

    # ---------------- backward: reverse schedule ------------------------------
    # loss NN-T backward
    (d_logits,) = l_vjp(jnp.ones((), jnp.float32))
    # decoder NN-T backward (+ its parameter grads -> NN-Reduce)
    d_dec_params, d_h = dec_vjp(d_logits)

    layer_grads: List[Any] = [None] * model.K
    for k in range(model.K - 1, -1, -1):
        r = residuals[k]
        # NN-T stage of the backward pass = derivative of Apy_k (Fig. 3b)
        d_lp_a, d_h_in_a, d_M = r["a_vjp"](d_h)
        # NN-G stage = derivative of Acc_k & Prop_k: gradient flows along
        # reversed edges to source/destination nodes
        (d_msg,) = r["s_vjp"](d_M)
        d_lp_g, d_n = r["g_vjp"](d_msg)
        # NN-A stage = derivative of Proj_k, back to node embeddings
        d_lp_t, d_h_prev = r["t_vjp"](d_n)
        # NN-Reduce: parameter gradients aggregated across stages
        layer_grads[k] = jax.tree_util.tree_map(
            lambda a, b, c: a + b + c, d_lp_a, d_lp_g, d_lp_t)
        d_h = jax.tree_util.tree_map(jnp.add, d_h_in_a, d_h_prev)

    grads = dict(d_dec_params)
    grads["layers"] = layer_grads
    return loss, grads
