"""The paper's §5.2.2 configuration: GAT-E (edge-attributed attention, a
simplified GIPA) on the billion-scale Alipay graph — here the power-law
edge-attributed stand-in, trained with all three strategies (Table 4)."""
from repro.config import GNNConfig, TrainConfig

CONFIG = GNNConfig(model="gat_e", num_layers=2, hidden_dim=32,
                   num_classes=2, edge_feature_dim=8, num_heads=4)
TRAIN = {
    "global": TrainConfig(strategy="global", lr=5e-3, steps=400),
    "mini": TrainConfig(strategy="mini", lr=5e-3, steps=3000),
    "cluster": TrainConfig(strategy="cluster", lr=5e-3, steps=3000,
                           cluster_halo_hops=1),
}
DATASET = "alipay_like"
