"""Whisper-base — encoder-decoder audio backbone; conv/mel frontend is a
stub (input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,             # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,           # MHA
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_seq=1500,         # stub frontend output frames
    cross_attention=True,
    norm_type="layernorm",
    source="arXiv:2212.04356",
)
