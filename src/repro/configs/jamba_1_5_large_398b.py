"""Jamba-1.5-Large 398B — hybrid Mamba + attention (1:7 interleave), MoE
16 experts top-2. [arXiv:2403.19887]"""
from repro.config import ArchConfig, MoEConfig, MambaConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2),
    moe_every=2,              # MoE on every 2nd layer (Jamba block design)
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      chunk=128),
    attn_every=8,             # 1 attention per 8 layers (1:7)
    source="arXiv:2403.19887",
)
