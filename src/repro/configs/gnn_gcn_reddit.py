"""The paper's Table-3 / §5.3 configuration: GCN hidden 128 on the dense
co-comment graph (Reddit stand-in), all three strategies."""
from repro.config import GNNConfig, TrainConfig

CONFIG = GNNConfig(model="gcn", num_layers=2, hidden_dim=128, num_classes=8)
TRAIN = {
    "global": TrainConfig(strategy="global", lr=1e-2, steps=500),
    "mini": TrainConfig(strategy="mini", lr=1e-2, steps=600),
    "cluster": TrainConfig(strategy="cluster", lr=1e-2, steps=600,
                           cluster_halo_hops=1),
}
DATASET = "reddit_like"
