"""DBRX-base 132B — fine-grained MoE, 16 experts top-4.
[hf:databricks/dbrx-base]"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,           # GQA
    d_ff=10752,               # per expert (fine-grained)
    vocab_size=100352,
    moe=MoEConfig(num_experts=16, top_k=4),
    rope_theta=500000.0,
    source="hf:databricks/dbrx-base",
)
