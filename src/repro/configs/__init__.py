# One module per assigned architecture (see repro.config.ASSIGNED_ARCHS)
# plus the paper's own GNN configurations (gnn_*.py).
