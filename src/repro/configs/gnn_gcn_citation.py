"""The paper's Table-2 configuration: 2-layer GCN, hidden 16, on the
citation networks (Cora/Citeseer/Pubmed stand-ins)."""
from repro.config import GNNConfig, TrainConfig

CONFIG = GNNConfig(model="gcn", num_layers=2, hidden_dim=16, num_classes=7,
                   dropout=0.5)
TRAIN = {
    "global": TrainConfig(strategy="global", lr=1e-2, weight_decay=5e-4,
                          steps=200),
    "mini": TrainConfig(strategy="mini", lr=1e-2, weight_decay=5e-4,
                        steps=300),
}
DATASETS = ("cora", "citeseer", "pubmed")
