"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.config import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, chunk=128, decay_lora=64),
    source="arXiv:2404.05892",
)
