"""Qwen2-VL-2B — VLM decoder backbone with M-RoPE; the ViT frontend is a
stub (input_specs supplies precomputed patch+text embeddings and 3-stream
position ids). [arXiv:2409.12191]"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    embed_inputs=True,        # stub multimodal frontend
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="arXiv:2409.12191",
)
