"""Test fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real (single) device; distributed-engine tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout
