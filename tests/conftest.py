"""Test fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real (single) device; distributed-engine tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


# ---------------------------------------------------------------------------
# CI-lane tiering (shared by the arch test suites): heavyweight archs run
# their expensive sweeps under ``-m slow`` so the default lane stays under
# ~5 minutes. The light archs left in the fast lane (qwen3-4b GQA dense,
# minicpm3 MLA, qwen2-vl M-RoPE) still cover the distinct cache semantics.
# ---------------------------------------------------------------------------

HEAVY_ARCHS = {"dbrx-132b", "whisper-base", "rwkv6-1.6b",
               "phi3-medium-14b", "jamba-1.5-large-398b", "qwen3-32b",
               "mixtral-8x7b"}


def arch_params():
    """ASSIGNED_ARCHS with the heavyweight ones marked slow."""
    from repro.config import ASSIGNED_ARCHS
    return [pytest.param(a, marks=pytest.mark.slow)
            if a in HEAVY_ARCHS else a for a in ASSIGNED_ARCHS]
