"""Per-architecture smoke tests (deliverable f): REDUCED variants (2
layers, d_model<=512, <=4 experts) run one forward/train step on CPU and
assert output shapes + no NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.arch.model as arch_model
from repro.arch import build_model, layer_kinds
from repro.config import ASSIGNED_ARCHS, get_arch_config

from conftest import arch_params

# heavyweight archs run train/serve smoke under ``-m slow`` (conftest);
# the cheap layer-kind / param-count checks below still sweep every arch
ARCH_PARAMS = arch_params()


def _batch(cfg, rng, B=2, S=32, train=True):
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(S)[None], (B, S))
        batch["mrope_positions"] = jnp.asarray(
            np.stack([pos, pos // 2, pos % 5]), jnp.int32)
    if cfg.encoder_layers:
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_reduced_smoke_train_step(arch):
    cfg = get_arch_config(arch).reduced().replace(dtype="float32")
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    arch_model.LOSS_CHUNK = 16
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    # one optimizer step
    from repro.optim import adamw
    opt = adamw(1e-3)
    p2, _ = opt.update(grads, opt.init(params), params)
    l2 = model.loss(p2, batch)
    assert np.isfinite(float(l2))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_reduced_smoke_serve(arch):
    cfg = get_arch_config(arch).reduced().replace(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    batch = _batch(cfg, rng, B=B, S=S, train=False)
    logits, caches, idx = model.prefill(params, batch, cache_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    db = {}
    if cfg.embed_inputs:
        db["embeds"] = batch["embeds"][:, :1]
    else:
        db["tokens"] = jnp.zeros((B, 1), jnp.int32)
    if cfg.mrope:
        db["mrope_positions"] = batch["mrope_positions"][:, :, :1]
    if cfg.encoder_layers:
        db["enc_frames"] = batch["enc_frames"]
    lo, caches, idx = model.decode_step(params, db, caches, idx)
    assert lo.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lo, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_layer_kinds_match_family(arch):
    cfg = get_arch_config(arch)
    kinds = layer_kinds(cfg)
    assert len(kinds) == cfg.num_layers
    if cfg.family == "ssm" and cfg.rwkv is not None:
        assert set(kinds) == {"rwkv"}
    if cfg.family == "hybrid":
        assert kinds.count("attn") == cfg.num_layers // cfg.attn_every
        assert kinds[0] == "attn"
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        assert set(kinds) == {"attn"}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_sane(arch):
    """Analytic param count is within 25% of the actual reduced model's
    (scaled check: exact construction is tested by init itself)."""
    cfg = get_arch_config(arch)
    n = cfg.param_count()
    # spot targets from the public cards (±40% — our configs simplify
    # e.g. per-layer MoE and tied embeddings)
    targets = {"dbrx-132b": 132e9, "mixtral-8x7b": 46.7e9,
               "qwen3-4b": 4e9, "rwkv6-1.6b": 1.6e9,
               "phi3-medium-14b": 14e9, "qwen3-32b": 32.8e9,
               "minicpm3-4b": 4e9, "jamba-1.5-large-398b": 398e9,
               "qwen2-vl-2b": 2.2e9}
    if arch in targets:
        assert 0.5 * targets[arch] < n < 1.7 * targets[arch], (arch, n)
    a = cfg.active_param_count()
    assert a <= n
    if cfg.moe is not None:
        assert a < 0.75 * n
