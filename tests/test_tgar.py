"""NN-TGAR invariants + the paper's App. A.1 spectral equivalence.

The hypothesis property sweeps live in test_tgar_properties.py (guarded
by ``pytest.importorskip`` — hypothesis is a dev-only extra).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GNNConfig
from repro.core.mpgnn import loss_block
from repro.core.strategies import mini_batch_views
from repro.graph import build_block, sbm_graph
from repro.graph.csr import Graph
from repro.models import make_gnn


def _small_graph(seed=0, n=200):
    return sbm_graph(num_nodes=n, num_classes=3, feature_dim=16,
                     p_in=0.05, p_out=0.01, seed=seed)


# ---------------------------------------------------------------------------
# spectral equivalence (paper App. A.1): message-propagation GCN == L·X·W
# ---------------------------------------------------------------------------


def test_gcn_equals_sparse_matmul():
    g = _small_graph().add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=1, hidden_dim=8, num_classes=3,
                    feature_dim=16)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), 16)
    block = build_block(g)
    h = model.encode(params, block)[: g.num_nodes]
    # dense reference: h = L_hat @ X @ W + b with L_hat(i,j) the GCN
    # normalization — the propagation/spectral equivalence of App. A.1
    # (the single layer is the model's last, so no activation)
    N = g.num_nodes
    L = np.zeros((N, N), np.float32)
    L[g.dst, g.src] = g.gcn_norm()
    W = np.asarray(params["layers"][0]["w"])
    b = np.asarray(params["layers"][0]["b"])
    ref = L @ (g.node_features @ W) + b
    np.testing.assert_allclose(np.asarray(h), ref, rtol=2e-4, atol=2e-4)


def test_isolated_node_gets_zero_messages():
    # node with no in-edges: aggregation must be exactly zero for GCN
    # (single layer = last layer = no activation, so h = b exactly)
    src = np.array([0, 1], np.int32)
    dst = np.array([1, 0], np.int32)
    feats = np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32)
    g = Graph(src, dst, 3, feats, np.zeros(3, np.int32))
    cfg = GNNConfig(model="gcn", num_layers=1, hidden_dim=4, num_classes=2,
                    feature_dim=4)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(1), 4)
    h = model.encode(params, build_block(g))
    b = np.asarray(params["layers"][0]["b"])
    np.testing.assert_allclose(np.asarray(h)[2], b, atol=1e-6)


# ---------------------------------------------------------------------------
# active sets: mini-batch view == computation on the extracted subgraph
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model_name", ["gcn", "gat", "sage"])
def test_active_set_equals_extracted_subgraph(model_name):
    g = _small_graph(seed=3, n=150)
    if model_name == "gcn":
        g = g.add_self_loops()
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=8,
                    num_classes=3, feature_dim=16, num_heads=2)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), 16)
    view = next(mini_batch_views(g, 2, batch_nodes=10, seed=4))
    gcn_norm = model_name == "gcn"
    loss_masked = float(loss_block(model, params,
                                   view.as_block(gcn_norm=gcn_norm)))

    # build the physical subgraph containing all touched nodes and edges
    # (including pure feature-source nodes at the deepest hop, which never
    # appear in node_active but feed layer-0 messages)
    touched = view.node_active.max(axis=0) > 0
    eact_all = view.edge_active.max(axis=0) > 0
    touched[g.src[eact_all]] = True
    touched[g.dst[eact_all]] = True
    keep_nodes = np.where(touched | (view.loss_mask > 0))[0]
    remap = -np.ones(g.num_nodes, np.int64)
    remap[keep_nodes] = np.arange(len(keep_nodes))
    eact = view.edge_active.max(axis=0) > 0
    es = remap[g.src[eact]]
    ed = remap[g.dst[eact]]
    sub = Graph(es.astype(np.int32), ed.astype(np.int32), len(keep_nodes),
                g.node_features[keep_nodes], g.labels[keep_nodes],
                edge_weights=(g.gcn_norm()[eact] if gcn_norm else None))
    sub_block = build_block(sub, loss_mask=view.loss_mask[keep_nodes] > 0,
                            gcn_norm=False)
    if gcn_norm:
        # reuse the full-graph normalization for identical semantics
        ew = np.zeros(sub_block.edge_weight.shape, np.float32)
        ew[: len(es)] = g.gcn_norm()[eact]
        sub_block.edge_weight = ew
    # the subgraph must reproduce the view's per-layer active sets
    na = view.node_active[:, keep_nodes]
    ea = view.edge_active[:, eact]
    sub_block.node_active = na
    sub_block.edge_active = ea
    loss_sub = float(loss_block(model, params, sub_block))
    assert abs(loss_masked - loss_sub) < 2e-4


def test_deeper_exploration_monotone():
    """K+1-hop neighborhoods contain K-hop ones (subgraph growth, §4.2)."""
    g = _small_graph(seed=5)
    from repro.core.subgraph import bfs_layers
    targets = np.arange(5)
    hops3, _ = bfs_layers(g, targets, 3)
    for a, b in zip(hops3[:-1], hops3[1:]):
        assert np.all(np.isin(a, b))


# ---------------------------------------------------------------------------
# segment primitives
# ---------------------------------------------------------------------------


def test_segment_mean_multi_head_messages():
    """Regression: (E, H, D) messages used to hit a broadcast shape error
    (the (N, 1) count against (N, H, D) totals); the count must broadcast
    over every trailing axis."""
    from repro.core.tgar import segment_mean
    rng = np.random.default_rng(0)
    E, N, H, D = 60, 10, 3, 5
    ids = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    data = jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32)
    out = segment_mean(data, ids, N)
    assert out.shape == (N, H, D)
    total = jax.ops.segment_sum(data, ids, N)
    count = jax.ops.segment_sum(jnp.ones(E, jnp.float32), ids, N)
    ref = np.asarray(total) / np.maximum(np.asarray(count), 1e-9)[:, None,
                                                                  None]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6, atol=1e-6)
    # the 2-D contract is unchanged
    d2 = data[:, 0, :]
    out2 = segment_mean(d2, ids, N)
    np.testing.assert_allclose(np.asarray(out2), ref[:, 0, :], rtol=1e-6,
                               atol=1e-6)
