"""Gradient accumulation == full-batch gradients (mean loss)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.arch.model as arch_model
from repro.arch import build_model
from repro.config import get_arch_config
from repro.launch.microbatch import microbatched_value_and_grad, split_batch


# CI-lane audit: the unrolled 4-microbatch sweep is the expensive cell;
# it runs under ``-m slow`` (the scan path and the 2-way unroll keep the
# equivalence covered in the fast lane).
@pytest.mark.parametrize("n_micro", [2, pytest.param(
    4, marks=pytest.mark.slow)])
@pytest.mark.parametrize("unroll", [False, True])
def test_microbatched_grads_match_full_batch(n_micro, unroll):
    arch_model.LOSS_CHUNK = 16
    cfg = get_arch_config("qwen3-4b").reduced().replace(
        dtype="float32", vocab_size=256)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (8, 16)),
                                   jnp.int32)}
    l0, g0 = jax.value_and_grad(model.loss)(params, batch)
    l1, g1 = microbatched_value_and_grad(model.loss, n_micro,
                                         unroll)(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_split_batch_handles_mrope_axis():
    batch = {"tokens": jnp.zeros((8, 4), jnp.int32),
             "mrope_positions": jnp.zeros((3, 8, 4), jnp.int32)}
    mb = split_batch(batch, 4)
    assert mb["tokens"].shape == (4, 2, 4)
    assert mb["mrope_positions"].shape == (4, 3, 2, 4)


def test_microbatch_with_vlm_inputs():
    arch_model.LOSS_CHUNK = 16
    cfg = get_arch_config("qwen2-vl-2b").reduced().replace(
        dtype="float32", vocab_size=256)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S = 4, 16
    pos = np.broadcast_to(np.arange(S)[None], (B, S))
    batch = {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                   jnp.float32),
             "labels": jnp.asarray(rng.integers(0, 256, (B, S)), jnp.int32),
             "mrope_positions": jnp.asarray(np.stack([pos, pos, pos]),
                                            jnp.int32)}
    l0, g0 = jax.value_and_grad(model.loss)(params, batch)
    l1, g1 = microbatched_value_and_grad(model.loss, 2)(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
