"""Property-based partition invariants (paper §4.1) — needs hypothesis.

Kept separate from test_partition.py so the deterministic invariants run
on clean environments; this module skips cleanly when hypothesis is not
installed (``pip install -e .[dev]`` enables it).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partition import build_partitions
from repro.graph import sbm_graph


def _graph(seed, n=120):
    return sbm_graph(num_nodes=n, num_classes=3, feature_dim=8,
                     p_in=0.06, p_out=0.02, seed=seed)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4, 8]),
       st.sampled_from(["1d_src", "1d_dst", "vertex_cut"]))
def test_partition_invariants(seed, P, method):
    g = _graph(seed)
    sg = build_partitions(g, P, method=method)
    plan = sg.plan

    # every node is master in exactly one partition
    owners = np.zeros(g.num_nodes, np.int32)
    for p in range(P):
        valid = plan.master_mask[p] > 0
        owners[plan.masters[p][valid]] += 1
    assert np.all(owners == 1)

    # every edge appears exactly once across partitions
    total_edges = int(plan.edge_mask.sum())
    assert total_edges == g.num_edges
    seen = np.zeros(g.num_edges, np.int32)
    for p in range(P):
        valid = plan.edge_mask[p] > 0
        seen[plan.edge_orig[p][valid]] += 1
    assert np.all(seen == 1)

    # local endpoints reference the correct global node
    n_m_pad = plan.n_m_pad
    for p in range(P):
        valid = plan.edge_mask[p] > 0
        eids = plan.edge_orig[p][valid]
        for loc, glob in ((plan.src_local[p][valid], g.src[eids]),
                          (plan.dst_local[p][valid], g.dst[eids])):
            is_master = loc < n_m_pad
            got = np.where(is_master, plan.masters[p][np.minimum(
                loc, n_m_pad - 1)], plan.mirrors[p][np.minimum(
                    np.maximum(loc - n_m_pad, 0),
                    plan.n_mir_pad - 1)])
            assert np.array_equal(got, glob)

    # exchange plan: send/recv pairs reference matching global ids
    for p in range(P):
        for q in range(P):
            k = int(plan.send_mask[p, q].sum())
            assert k == int(plan.recv_mask[q, p].sum())
            sm = plan.masters[p][plan.send_idx[p, q, :k]]
            rm = plan.mirrors[q][plan.recv_slot[q, p, :k]]
            assert np.array_equal(sm, rm)

    # 1d_src: the source of every local edge is a local master
    if method == "1d_src":
        for p in range(P):
            valid = plan.edge_mask[p] > 0
            assert np.all(plan.src_local[p][valid] < n_m_pad)
