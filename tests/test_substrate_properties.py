"""Property-based substrate invariants — needs hypothesis (dev extra)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticLMDataset


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1_000_000), st.integers(0, 50))
def test_data_deterministic_resume(seed, index):
    ds = SyntheticLMDataset(vocab_size=128, seq_len=32, global_batch=4,
                            seed=seed)
    a = ds.batch(index)
    b = ds.batch(index)
    assert np.array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    assert np.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
