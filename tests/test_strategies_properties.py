"""Property-based strategy invariants — needs hypothesis (dev extra)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (hash_clusters,
                                   label_propagation_clusters)
from repro.core.subgraph import (bfs_layers, bfs_layers_loop,
                                 khop_subgraph_view)
from repro.core.views import (ClusterViewCache, ViewBuilder,
                              cluster_view_recompute)
from repro.graph import sbm_graph


def _g(seed=0, n=300):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8, p_in=0.05,
                     p_out=0.005, seed=seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_cluster_split_bounds_size(seed):
    g = _g(seed % 17)
    cl = label_propagation_clusters(g, max_cluster_size=40, iters=3,
                                    seed=seed)
    sizes = np.bincount(cl)
    assert sizes.max() <= 40
    assert sizes.sum() == g.num_nodes


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(0, 24))
def test_bfs_vectorized_matches_loop(seed, depth, n_targets):
    """Vectorized CSR-segment frontier expansion is bit-exact with the
    per-node loop oracle — hop sets, dtypes, visited — for random graphs,
    depths and target sets (including the empty set)."""
    g = _g(seed % 13, n=150)
    rng = np.random.default_rng(seed)
    targets = rng.choice(g.num_nodes, size=n_targets, replace=False)
    hops_v, vis_v = bfs_layers(g, targets, depth)
    hops_l, vis_l = bfs_layers_loop(g, targets, depth)
    assert len(hops_v) == len(hops_l) == depth + 1
    for a, b in zip(hops_v, hops_l):
        assert a.dtype == b.dtype
        assert np.array_equal(a, b)
    assert np.array_equal(vis_v, vis_l)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3))
def test_khop_builder_masks_match_loop_oracle(seed, K):
    """ViewBuilder's buffer-reusing k-hop masks == the allocating
    loop-BFS path, bit-exact on every mask."""
    g = _g(seed % 13, n=150)
    rng = np.random.default_rng(seed)
    targets = rng.choice(g.num_nodes, size=10, replace=False)
    na, ea, lm, _ = khop_subgraph_view(g, targets, K,
                                       _bfs=bfs_layers_loop)
    vb = ViewBuilder(g, K)
    vb.khop_view(rng.choice(g.num_nodes, 5))   # dirty the buffers first
    v = vb.khop_view(targets)
    assert np.array_equal(v.node_active, na)
    assert np.array_equal(v.edge_active, ea)
    assert np.array_equal(v.loss_mask, lm)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 2), st.integers(1, 5))
def test_cluster_cache_matches_recompute(seed, halo, picks):
    """Composed cached member/halo sets == per-step isin+halo recompute,
    bit-exact on all masks (halo distributes over cluster unions)."""
    g = _g(seed % 13, n=150)
    clusters = hash_clusters(g, 8, seed=seed % 7)
    cache = ClusterViewCache(g, clusters, halo)
    rng = np.random.default_rng(seed)
    chosen = rng.choice(cache.num_clusters, size=min(picks, 8),
                        replace=False)
    train = g.train_mask
    member, active, loss = cluster_view_recompute(g, clusters, chosen,
                                                  halo, train)
    vb = ViewBuilder(g, 2)
    v = vb.cluster_view(chosen, cache, train)
    assert np.array_equal(
        v.node_active,
        np.broadcast_to(active.astype(np.float32), (2, g.num_nodes)))
    assert np.array_equal(
        v.edge_active,
        np.broadcast_to((active[g.src] & active[g.dst])
                        .astype(np.float32), (2, g.num_edges)))
    assert np.array_equal(v.loss_mask, loss)
