"""Property-based strategy invariants — needs hypothesis (dev extra)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.clustering import label_propagation_clusters
from repro.graph import sbm_graph


def _g(seed=0, n=300):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8, p_in=0.05,
                     p_out=0.005, seed=seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_cluster_split_bounds_size(seed):
    g = _g(seed % 17)
    cl = label_propagation_clusters(g, max_cluster_size=40, iters=3,
                                    seed=seed)
    sizes = np.bincount(cl)
    assert sizes.max() <= 40
    assert sizes.sum() == g.num_nodes
