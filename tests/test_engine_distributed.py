"""Hybrid-parallel engine == single-block reference (subprocess, 8 fake
devices). This is the core claim of the paper's execution model: one batch
computed by a worker group gives the same model as one worker."""
import pytest

from conftest import run_with_devices

_EQUIV = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.graph import make_dataset
from repro.config import GNNConfig
from repro.models import make_gnn
from repro.core.mpgnn import loss_block
from repro.core.strategies import (global_batch_view, mini_batch_views,
                                   cluster_batch_views, shard_view)
from repro.core.partition import build_partitions
from repro.core.engine import HybridParallelEngine
from repro.core.clustering import label_propagation_clusters
from repro.optim import adam

g = make_dataset("cora", seed=0).add_self_loops()
cfgs = [
    ("gcn", "1d_src"), ("gcn", "1d_dst"), ("gcn", "vertex_cut"),
    ("sage", "1d_src"), ("gat", "1d_src"), ("gat", "vertex_cut"),
]
for model_name, method in cfgs:
    gcn_norm = model_name == "gcn"
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=16,
                    num_classes=7, feature_dim=g.node_features.shape[1],
                    num_heads=4)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    sg = build_partitions(g, 8, method=method, gcn_norm=gcn_norm)
    eng = HybridParallelEngine(model, sg)
    lg = eng.make_loss_and_grad()
    views = [global_batch_view(g, 2),
             next(mini_batch_views(g, 2, batch_nodes=24, seed=1))]
    cl = label_propagation_clusters(g, max_cluster_size=150, iters=2)
    views.append(next(cluster_batch_views(g, 2, cl, clusters_per_batch=4,
                                          halo_hops=1, seed=2)))
    for view in views:
        ref_l, ref_g = jax.value_and_grad(
            lambda p: loss_block(model, p,
                                 view.as_block(gcn_norm=gcn_norm)))(params)
        loss, grads = lg(params, eng._device_data,
                         eng.stage_view(shard_view(sg.plan, view)))
        assert abs(float(ref_l) - float(loss)) < 1e-4, \
            (model_name, method, view.strategy, float(ref_l), float(loss))
        err = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(ref_g),
            jax.tree_util.tree_leaves(grads)))
        assert err < 1e-4, (model_name, method, view.strategy, err)
    print(model_name, method, "ok")

# edge-attributed GAT-E on the alipay-like graph
from repro.graph import make_dataset as mk
ga = mk("alipay_like", num_nodes=600, seed=0)
cfg = GNNConfig(model="gat_e", num_layers=2, hidden_dim=16, num_classes=2,
                feature_dim=ga.node_features.shape[1], num_heads=4,
                edge_feature_dim=ga.edge_features.shape[1])
model = make_gnn(cfg)
params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
sg = build_partitions(ga, 8, gcn_norm=False)
eng = HybridParallelEngine(model, sg)
view = global_batch_view(ga, 2)
ref = float(loss_block(model, params, view.as_block(gcn_norm=False)))
loss, _ = eng.make_loss_and_grad()(params, eng._device_data,
                                   eng.stage_view(shard_view(sg.plan, view)))
assert abs(ref - float(loss)) < 1e-4, (ref, float(loss))
print("gat_e ok")

# distributed training converges
opt = adam(1e-2)
cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16, num_classes=7,
                feature_dim=g.node_features.shape[1])
model = make_gnn(cfg)
params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
sg = build_partitions(g, 8)
eng = HybridParallelEngine(model, sg)
step = eng.make_train_step(opt)
st_ = opt.init(params)
va = shard_view(sg.plan, global_batch_view(g, 2))
first = None
for i in range(40):
    params, st_, loss = step(params, st_, va)
    if first is None:
        first = float(loss)
assert float(loss) < first * 0.25, (first, float(loss))
print("train ok", first, float(loss))
print("ALL_OK")
"""


@pytest.mark.slow
def test_engine_equivalence_8workers():
    out = run_with_devices(_EQUIV, n_devices=8, timeout=900)
    assert "ALL_OK" in out


_SCALE = r"""
import numpy as np, jax
from repro.graph import sbm_graph
from repro.config import GNNConfig
from repro.models import make_gnn
from repro.core.mpgnn import loss_block
from repro.core.strategies import global_batch_view, shard_view
from repro.core.partition import build_partitions
from repro.core.engine import HybridParallelEngine

g = sbm_graph(num_nodes=500, num_classes=4, feature_dim=16, p_in=0.05,
              p_out=0.01, seed=2).add_self_loops()
cfg = GNNConfig(model="gcn", num_layers=3, hidden_dim=16, num_classes=4,
                feature_dim=16)
model = make_gnn(cfg)
params = model.init(jax.random.PRNGKey(0), 16)
view = global_batch_view(g, 3)
ref = float(loss_block(model, params, view.as_block()))
for P in (1, 2, 4, 8):
    sg = build_partitions(g, P)
    import jax as j
    mesh = j.sharding.Mesh(np.array(j.devices()[:P]), ("graph",))
    eng = HybridParallelEngine(model, sg, mesh=mesh)
    loss, _ = eng.make_loss_and_grad()(
        params, eng._device_data, eng.stage_view(shard_view(sg.plan, view)))
    assert abs(ref - float(loss)) < 1e-4, (P, ref, float(loss))
    print("P", P, "ok")
print("ALL_OK")
"""


@pytest.mark.slow
def test_engine_worker_count_invariance():
    """Same loss for any worker-group size (incl. P=1) — 3-layer GNN."""
    out = run_with_devices(_SCALE, n_devices=8, timeout=900)
    assert "ALL_OK" in out
