"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (build_csc_plan, segment_sum_op, wkv6_op,
                               flash_attention_op)
from repro.kernels.ref import segment_sum_ref, wkv6_ref, mha_ref


@pytest.mark.parametrize("E,N,D", [(64, 16, 8), (777, 300, 48),
                                   (1500, 97, 16), (33, 500, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_sweep(E, N, D, dtype):
    rng = np.random.default_rng(E * N)
    ids = rng.integers(0, N, E).astype(np.int32)
    data = rng.normal(size=(E, D)).astype(np.float32)
    plan = build_csc_plan(ids, N, block_n=64, block_e=128)
    out = segment_sum_op(jnp.asarray(data, dtype), plan, interpret=True)
    ref = segment_sum_ref(jnp.asarray(data, dtype), jnp.asarray(ids), N)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("blocks", [(32, 64), (64, 256), (128, 128)])
def test_segment_sum_block_shapes(blocks):
    bn, be = blocks
    rng = np.random.default_rng(bn)
    E, N, D = 513, 211, 24
    ids = rng.integers(0, N, E).astype(np.int32)
    data = rng.normal(size=(E, D)).astype(np.float32)
    plan = build_csc_plan(ids, N, block_n=bn, block_e=be)
    out = segment_sum_op(jnp.asarray(data), plan, interpret=True)
    ref = segment_sum_ref(jnp.asarray(data), jnp.asarray(ids), N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_empty_segments():
    ids = np.array([5, 5, 5], np.int32)          # most segments empty
    data = np.ones((3, 4), np.float32)
    plan = build_csc_plan(ids, 64, block_n=16, block_e=16)
    out = np.asarray(segment_sum_op(jnp.asarray(data), plan,
                                    interpret=True))
    assert out[5].sum() == 12.0 and np.abs(out).sum() == 12.0


@pytest.mark.parametrize("T,chunk", [(64, 32), (96, 32), (100, 32),
                                     (128, 64)])
@pytest.mark.parametrize("KV", [(16, 16), (32, 48)])
def test_wkv6_sweep(T, chunk, KV):
    K, V = KV
    B, H = 2, 2
    rng = np.random.default_rng(T + K)
    r = rng.normal(size=(B, T, H, K)).astype(np.float32) * 0.5
    k = rng.normal(size=(B, T, H, K)).astype(np.float32) * 0.5
    v = rng.normal(size=(B, T, H, V)).astype(np.float32)
    w = (0.5 + 0.49 * rng.random((B, T, H, K))).astype(np.float32)
    u = (rng.normal(size=(H, K)) * 0.2).astype(np.float32)
    o = wkv6_op(*map(jnp.asarray, (r, k, v, w, u)), chunk=chunk,
                interpret=True)
    ref, _ = wkv6_ref(*map(jnp.asarray, (r, k, v, w, u)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_bf16_inputs():
    B, T, H, K = 1, 64, 2, 16
    rng = np.random.default_rng(7)
    mk = lambda *s: jnp.asarray(rng.normal(size=s) * 0.3, jnp.bfloat16)
    r, k = mk(B, T, H, K), mk(B, T, H, K)
    v = mk(B, T, H, K)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.bfloat16)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    o = wkv6_op(r, k, v, w, u, chunk=32, interpret=True)
    ref, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("T,bq,bk", [(128, 32, 32), (128, 64, 32),
                                     (256, 64, 64)])
@pytest.mark.parametrize("window", [0, 48, 128])
def test_flash_attention_sweep(T, bq, bk, window):
    B, H, D = 2, 2, 32
    rng = np.random.default_rng(T + window)
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    o = flash_attention_op(q, k, v, causal=True, sliding_window=window,
                           block_q=bq, block_k=bk, interpret=True)
    ref = mha_ref(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_and_bf16():
    B, T, Hq, Hkv, D = 1, 128, 4, 2, 16
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(B, T, Hq, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.bfloat16)
    o = flash_attention_op(q, k, v, block_q=32, block_k=32, interpret=True)
    kr = jnp.repeat(k, 2, axis=2)
    vr = jnp.repeat(v, 2, axis=2)
    ref = mha_ref(q, kr, vr, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.1, atol=0.1)


def test_wkv6_kernel_matches_model_chunked_path():
    """kernels/wkv6 (serving) == arch chunked train path (same math)."""
    from repro.arch.rwkv6_block import wkv_chunked
    B, T, H, K = 2, 64, 2, 16
    rng = np.random.default_rng(11)
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.4, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.4, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    o_kernel = wkv6_op(r, k, v, w, u, chunk=32, interpret=True)
    o_model, _ = wkv_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# edge softmax kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,N,D", [(100, 30, 8), (777, 300, 48),
                                   (1500, 97, 16)])
@pytest.mark.parametrize("blocks", [(32, 64), (64, 256)])
def test_edge_softmax_sweep(E, N, D, blocks):
    from repro.kernels.ops import edge_softmax_op
    from repro.kernels.ref import edge_softmax_ref
    bn, be = blocks
    rng = np.random.default_rng(E + bn)
    ids = rng.integers(0, N, E).astype(np.int32)
    logits = rng.normal(size=(E,)).astype(np.float32) * 4
    vals = rng.normal(size=(E, D)).astype(np.float32)
    plan = build_csc_plan(ids, N, block_n=bn, block_e=be)
    out = edge_softmax_op(jnp.asarray(logits), jnp.asarray(vals), plan,
                          interpret=True)
    ref = edge_softmax_ref(jnp.asarray(logits), jnp.asarray(vals),
                           jnp.asarray(ids), N)
    # empty segments produce 0 in the kernel (denominator clamp) and 0 in
    # the ref (num=0); compare everywhere
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_edge_softmax_matches_gat_sum_stage():
    """Kernel == the model's segment_softmax Sum stage (single head)."""
    from repro.core.tgar import segment_softmax
    from repro.kernels.ops import edge_softmax_op
    rng = np.random.default_rng(5)
    E, N, D = 400, 120, 16
    ids = rng.integers(0, N, E).astype(np.int32)
    logits = rng.normal(size=(E,)).astype(np.float32)
    vals = rng.normal(size=(E, D)).astype(np.float32)
    plan = build_csc_plan(ids, N, block_n=64, block_e=128)
    out = edge_softmax_op(jnp.asarray(logits), jnp.asarray(vals), plan,
                          interpret=True)
    ref = segment_softmax(jnp.asarray(logits)[:, None],
                          jnp.asarray(vals)[:, None, :],
                          jnp.asarray(ids), N,
                          jnp.ones(E, np.float32))[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal_odd_length():
    """Regression: T not a multiple of the block size, causal=False. The
    wrapper pads T up to the block; the padded keys carry zero logits, so
    without the true-length mask every real query's softmax denominator
    was inflated (causal masking used to hide this for pad keys > q_pos).
    """
    B, H, D = 2, 2, 16
    rng = np.random.default_rng(9)
    for T in (7, 33, 100):
        q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
        o = flash_attention_op(q, k, v, causal=False, block_q=32,
                               block_k=32, interpret=True)
        ref = mha_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5, err_msg=f"T={T}")


def test_flash_attention_unequal_blocks_odd_length():
    """Padding must target a common multiple of both block sizes: with
    unequal clamped blocks, padding to max(bq, bk) used to trip the
    kernel's divisibility assert."""
    B, T, H, D = 1, 100, 2, 16
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    o = flash_attention_op(q, k, v, causal=False, block_q=128, block_k=32,
                           interpret=True)
    ref = mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_causal_odd_length():
    """Padded tail must stay harmless in the causal path too."""
    B, T, H, D = 1, 45, 2, 16
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    o = flash_attention_op(q, k, v, causal=True, block_q=32, block_k=32,
                           interpret=True)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_edge_softmax_multi_head_single_launch():
    """(E, H, D) logits/values run as ONE fused kernel launch (heads on
    the grid) and match the per-head reference."""
    from repro.kernels.ops import edge_softmax_op
    from repro.kernels.ref import edge_softmax_ref
    rng = np.random.default_rng(12)
    E, N, H, D = 500, 120, 3, 16
    ids = rng.integers(0, N // 2, E).astype(np.int32)   # empty tail too
    logits = jnp.asarray(rng.normal(size=(E, H)) * 3, jnp.float32)
    vals = jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    out = edge_softmax_op(logits, vals, plan, interpret=True)
    assert out.shape == (N, H, D)
    for h in range(H):
        ref = edge_softmax_ref(logits[:, h], vals[:, h, :],
                               jnp.asarray(ids), N)
        np.testing.assert_allclose(np.asarray(out[:, h, :]),
                                   np.asarray(ref), rtol=2e-5, atol=2e-5,
                                   err_msg=f"head {h}")


def test_segment_max_d_tiled_wide_features():
    """D > the VMEM cap exercises the d-tile grid axis of the fused max
    kernel (the (BE, BN, BD) candidate tensor stays bounded)."""
    from repro.kernels.ops import segment_max_op
    from repro.kernels.segment_sum import _pick_block_d
    assert _pick_block_d(48) == 48
    assert _pick_block_d(160) == 40            # largest divisor <= 64
    assert _pick_block_d(128) == 64
    rng = np.random.default_rng(13)
    E, N, D = 700, 90, 160
    ids = rng.integers(0, N, E).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    out = segment_max_op(data, plan, interpret=True)
    # empty segments: kernel yields NEG, the jnp oracle -inf — same clamp
    # the combine engine applies
    from repro.kernels.segment_sum import NEG
    ref = jnp.maximum(jax.ops.segment_max(data, jnp.asarray(ids), N), NEG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# fused backward kernels (kernels/backward.py) vs the reference bwd math
# ---------------------------------------------------------------------------


def test_plan_edge_dst_inverts_the_plan():
    """The plan's inverse map: lane e of edge_dst is the destination row
    of edge e (pad lanes hold num_segments), derived from
    gather_idx/local_ids on the host."""
    rng = np.random.default_rng(21)
    E, N = 530, 140
    ids = rng.integers(0, N, E).astype(np.int32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    assert plan.edge_dst.shape[0] % plan.block_e == 0
    np.testing.assert_array_equal(plan.edge_dst[:E], ids)
    assert np.all(plan.edge_dst[E:] == N)


@pytest.mark.parametrize("E,N,D,blocks", [(400, 90, 8, (32, 64)),
                                          (777, 300, 48, (64, 128)),
                                          (300, 64, 160, (16, 64))])
def test_segment_sum_bwd_kernel(E, N, D, blocks):
    """d_data[e] = g[dst[e]] via the plan-driven gather kernel (D=160
    exercises the backward d-tiling)."""
    from repro.kernels.ops import segment_sum_bwd_op
    rng = np.random.default_rng(E + D)
    ids = rng.integers(0, N, E).astype(np.int32)
    g = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    plan = build_csc_plan(ids, N, block_n=blocks[0], block_e=blocks[1])
    out = segment_sum_bwd_op(g, plan, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g)[ids],
                               rtol=1e-6, atol=1e-6)


def test_segment_max_bwd_kernel_hit_mask():
    """The argmax-hit mask runs inside the kernel: cotangent lands only
    on edges attaining their segment max (ties share, like
    jax.ops.segment_max)."""
    from repro.kernels.ops import (segment_max_bwd_op, segment_max_op)
    rng = np.random.default_rng(31)
    E, N, D = 450, 100, 12
    ids = rng.integers(0, N // 2, E).astype(np.int32)   # empty tail
    data = jnp.asarray(
        rng.integers(-4, 4, size=(E, D)).astype(np.float32))  # force ties
    g = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    fwd = segment_max_op(data, plan, interpret=True)
    out = segment_max_bwd_op(g, fwd, data, plan, interpret=True)
    hit = (np.asarray(data) == np.asarray(fwd)[ids]).astype(np.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g)[ids] * hit,
                               rtol=1e-6, atol=1e-6)


def test_edge_softmax_fwd_op_stats_match_reference():
    """The forward launch's extra (m, den) outputs equal the reference
    per-destination softmax stats the backward rebuilds p_e from."""
    from repro.kernels.ops import edge_softmax_fwd_op
    from repro.kernels.segment_sum import NEG
    rng = np.random.default_rng(41)
    E, N, H, D = 500, 120, 2, 16
    ids = rng.integers(0, N // 2, E).astype(np.int32)
    logits = jnp.asarray(rng.normal(size=(E, H)) * 3, jnp.float32)
    vals = jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    _, m, den = edge_softmax_fwd_op(logits, vals, plan, interpret=True)
    seg_max = jnp.maximum(
        jax.ops.segment_max(logits, jnp.asarray(ids), N), NEG)
    ex = jnp.exp(logits - seg_max[jnp.asarray(ids)])
    den_ref = jax.ops.segment_sum(ex, jnp.asarray(ids), N)
    np.testing.assert_allclose(np.asarray(m), np.asarray(seg_max),
                               rtol=1e-6, atol=1e-6)
    # empty segments: kernel den is 0, reference sum is 0 too
    np.testing.assert_allclose(np.asarray(den), np.asarray(den_ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("H,D", [(1, 8), (3, 16)])
def test_edge_softmax_bwd_kernel_matches_reference(H, D):
    """The recompute-in-kernel softmax backward == the reference-math
    jacobian (kept in aggregate.reference_edge_softmax_bwd), including
    masked edges nulled to NEG."""
    from repro.core.aggregate import reference_edge_softmax_bwd
    from repro.kernels.ops import edge_softmax_bwd_op, edge_softmax_fwd_op
    from repro.kernels.segment_sum import NEG
    rng = np.random.default_rng(51 + H)
    E, N = 480, 110
    ids = rng.integers(0, N // 2, E).astype(np.int32)
    mask = rng.random(E) > 0.3
    logits = np.where(mask[:, None], rng.normal(size=(E, H)) * 3,
                      NEG).astype(np.float32)
    vals = (rng.normal(size=(E, H, D)).astype(np.float32)
            * mask[:, None, None])
    g = jnp.asarray(rng.normal(size=(N, H, D)), jnp.float32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    out, m, den = edge_softmax_fwd_op(jnp.asarray(logits),
                                      jnp.asarray(vals), plan,
                                      interpret=True)
    d_logits, d_values = edge_softmax_bwd_op(
        g, jnp.asarray(logits), jnp.asarray(vals), out, m, den, plan,
        interpret=True)
    ref_dl, ref_dv = reference_edge_softmax_bwd(
        g, jnp.asarray(logits), jnp.asarray(vals), out, jnp.asarray(ids),
        N)
    np.testing.assert_allclose(np.asarray(d_logits), np.asarray(ref_dl),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(d_values), np.asarray(ref_dv),
                               rtol=1e-5, atol=1e-6)
