"""Beyond-paper serving features: rolling-cache prefill, jamba MoE
interleave, SWA variants, whisper encoder-memory reuse."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import build_model, layer_kinds
from repro.config import get_arch_config


def test_rolling_prefill_matches_full_cache_decode():
    """prefill into a rolling cache + decode == full-cache prefill+decode
    (prompt longer than the window)."""
    cfg = get_arch_config("mixtral-8x7b").reduced().replace(
        dtype="float32", sliding_window=8)
    rng = np.random.default_rng(0)
    B, P, N = 2, 20, 6           # prompt 20 >> window 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P + N)),
                       jnp.int32)

    def run(rolling):
        model = build_model(cfg, remat=False, rolling_window_decode=rolling)
        params = model.init(jax.random.PRNGKey(1))
        lo, caches, idx = model.prefill(params, {"tokens": toks[:, :P]},
                                        cache_len=P + N)
        outs = [lo]
        for t in range(P, P + N):
            lo, caches, idx = model.decode_step(
                params, {"tokens": toks[:, t:t + 1]}, caches, idx)
            outs.append(lo)
        return jnp.concatenate(outs, axis=1)

    full = run(False)
    roll = run(True)
    err = float(jnp.abs(full - roll).max())
    assert err < 2e-3, err


def test_batched_mixed_length_prompts_match_solo():
    """BatchServer pad-invariance: left-padded mixed-length prompts in
    one lockstep batch generate exactly what each request generates solo
    (the validity mask keeps pad K/Vs out of causal attention, per-row
    positions keep RoPE aligned)."""
    from repro.launch.serve import BatchServer, Request

    rng = np.random.default_rng(0)
    srv = BatchServer("qwen3-4b", batch_size=3, cache_len=24,
                      reduced=True, rolling=False)
    V = srv.cfg.vocab_size
    prompts = [rng.integers(0, V, n).astype(np.int32) for n in (4, 9, 6)]
    reqs = [Request(i, p, 4) for i, p in enumerate(prompts)]
    srv.run(reqs)
    for i, p in enumerate(prompts):
        solo = BatchServer("qwen3-4b", batch_size=1, cache_len=24,
                           reduced=True, rolling=False)
        solo.params = srv.params       # same weights, no pad
        r = Request(0, p, 4)
        solo.run([r])
        assert r.out == reqs[i].out, (i, r.out, reqs[i].out)


def test_jamba_moe_interleave():
    """jamba: MoE on every 2nd layer only; param structure reflects it."""
    cfg = get_arch_config("jamba-1.5-large-398b")
    assert cfg.moe_every == 2
    red = cfg.reduced().replace(dtype="float32")
    model = build_model(red, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    blocks = params["blocks"]
    # group = [attn, mamba]; position 0 dense ffn, position 1 moe ffn
    assert "router" not in blocks[0]["ffn"]
    assert "router" in blocks[1]["ffn"]
    # hybrid interleave 1:7 at full depth
    kinds = layer_kinds(cfg)
    assert kinds.count("attn") == 9 and kinds.count("mamba") == 63


def test_swa_variant_changes_only_masking():
    """Adding a sliding window to a dense arch keeps params identical and
    changes logits only for long-range positions."""
    base = get_arch_config("qwen3-4b").reduced().replace(dtype="float32")
    swa = base.replace(sliding_window=4)
    m1 = build_model(base, remat=False)
    m2 = build_model(swa, remat=False)
    p1 = m1.init(jax.random.PRNGKey(0))
    p2 = m2.init(jax.random.PRNGKey(0))
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (1, 12)), jnp.int32)
    l1, _, _ = m1.prefill(p1, {"tokens": toks}, cache_len=12)
    l2, _, _ = m2.prefill(p2, {"tokens": toks}, cache_len=12)
    # last-token logits differ (window truncated context)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_whisper_decode_uses_cached_encoder_memory():
    cfg = get_arch_config("whisper-base").reduced().replace(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B = 2
    frames = jnp.asarray(rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 4)), jnp.int32)
    lo, caches, idx = model.prefill(
        params, {"tokens": toks, "enc_frames": frames}, cache_len=8)
    enc = model._encoder(params, frames)
    # decode via recompute vs via cached enc_memory: identical
    a, _, _ = model.decode_step(params, {"tokens": toks[:, :1],
                                         "enc_frames": frames}, caches, idx)
    b, _, _ = model.decode_step(params, {"tokens": toks[:, :1],
                                         "enc_memory": enc}, caches, idx)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_reduced_jamba_ep_equals_dense_train_loss():
    """EP and dense MoE give the same loss for the hybrid arch too
    (single-device mesh: all_to_all degenerates but the code path runs).
    Capacity is made generous so no tokens drop — EP == dense only holds
    drop-free; an untrained router easily overflows the 1.25 factor."""
    import dataclasses
    from jax.sharding import Mesh
    cfg = get_arch_config("jamba-1.5-large-398b").reduced().replace(
        dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
    import repro.arch.model as am
    am.LOSS_CHUNK = 16
    md = build_model(cfg, moe_impl="dense", remat=False)
    params = md.init(jax.random.PRNGKey(0))
    l_dense = float(md.loss(params, batch))
    mep = build_model(cfg, moe_impl="ep", mesh=mesh, remat=False)
    l_ep = float(mep.loss(params, batch))
    assert abs(l_dense - l_ep) < 1e-4, (l_dense, l_ep)
