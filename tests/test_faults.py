"""Fault-tolerant runtime (PR 8): chaos contract and recovery semantics.

The load-bearing invariant: every supervised unit (view build, device
staging, step dispatch, checkpoint save) is a pure function of its
inputs, so a run with injected faults — killed prefetch workers, failed
builds, failed saves — must produce a loss trajectory **bit-identical**
to the fault-free run, for both trainers and both aggregate backends,
without breaking the compiled-once / compiled-per-bucket contracts.

Divergence recovery (skip_view / rollback) changes the trajectory by
design; those tests check the recovery semantics instead: the poison
update is discarded, rollback restores the newest *valid* checkpoint
(walking past a corrupted latest file), the stream cursor moves past
the poison view, and training completes without a retrace.
"""
import threading
import time

import numpy as np
import pytest

from repro.config import GNNConfig
from repro.core.engine import HybridParallelEngine
from repro.core.partition import build_partitions
from repro.core.strategies import shard_view, strategy_views
from repro.core.trainer import CompactTrainer, Trainer
from repro.graph import sbm_graph
from repro.models import make_gnn
from repro.optim import adam
from repro.runtime import (DivergenceError, FaultInjector, FaultPolicy,
                           FaultRetriesExceeded, InjectedFault,
                           PrefetchShutdownError, Retrier,
                           StepTimeoutError, StreamPrefetcher,
                           TransientError, ViewPrefetcher, WorkerKilled,
                           sync_with_timeout)

# no real sleeping in tests
FAST = dict(backoff_base=0.0, backoff_cap=0.0, jitter=0.0)

# the chaos plan of the acceptance contract: a killed worker, failed
# view builds, a failed device staging, a failed checkpoint save
CHAOS_PLAN = {
    "worker_kill": {1},
    "view_build": {0, 2},
    "device_put": {0},
    "checkpoint_save": {0},
}


def _graph(n=160, seed=0):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8,
                     p_in=0.05, p_out=0.005, seed=seed).add_self_loops()


@pytest.fixture(scope="module")
def g():
    return _graph()


def _engine_trainer(g, **kw):
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)
    engine = HybridParallelEngine(make_gnn(cfg), build_partitions(g, 1))
    return Trainer(engine, adam(1e-2), seed=0, **kw)


def _compact_trainer(g, backend="reference", **kw):
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8,
                    aggregate_backend=backend)
    return CompactTrainer(make_gnn(cfg), g, adam(1e-2), seed=0, **kw)


def _views(g, compact=False, seed=0):
    return strategy_views(g, "mini", K=2, seed=seed, batch_nodes=24,
                          compact=compact)


# ---------------------------------------------------------------------------
# policy / injector / retrier units
# ---------------------------------------------------------------------------


def test_policy_backoff_deterministic_capped():
    p = FaultPolicy(backoff_base=0.1, backoff_factor=2.0, backoff_cap=0.3,
                    jitter=0.1, seed=7)
    d = [p.delay("s", a) for a in range(6)]
    assert d == [p.delay("s", a) for a in range(6)]     # deterministic
    assert all(x <= 0.3 * 1.1 + 1e-9 for x in d)        # capped (+jitter)
    assert d[1] > d[0] * 0.8                            # roughly growing


def test_policy_validates_divergence_action():
    with pytest.raises(ValueError, match="on_divergence"):
        FaultPolicy(on_divergence="explode")


def test_injector_unknown_point_rejected():
    with pytest.raises(ValueError, match="unknown injection point"):
        FaultInjector({"bogus": {0}})


def test_injector_occurrences_and_keys_deterministic():
    inj = FaultInjector({"view_build": {1, 3}}, seed=0)
    fired = [inj.fires("view_build") for _ in range(5)]
    assert fired == [False, True, False, True, False]
    inj2 = FaultInjector({"view_build": {1, 3}}, seed=0)
    # keyed decisions ignore call order entirely
    assert [inj2.fires("view_build", key=k) for k in (3, 0, 1)] \
        == [True, False, True]
    assert sorted(inj2.fired["view_build"]) == [1, 3]


def test_injector_rate_mode_pure_function_of_seed():
    a = FaultInjector({"step": 0.5}, seed=1)
    b = FaultInjector({"step": 0.5}, seed=1)
    assert [a.fires("step") for _ in range(64)] \
        == [b.fires("step") for _ in range(64)]
    assert 0 < a.total_fired() < 64


def test_retrier_retries_transients_then_succeeds():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientError("flake")
        return "ok"

    rt = Retrier(FaultPolicy(max_retries=3, **FAST))
    assert rt("stage", flaky) == "ok"
    assert len(calls) == 3
    assert [e["stage"] for e in rt.events] == ["stage", "stage"]


def test_retrier_exhaustion_raises_typed_error():
    rt = Retrier(FaultPolicy(max_retries=2, **FAST))

    def always():
        raise TransientError("nope")

    with pytest.raises(FaultRetriesExceeded, match="3 consecutive"):
        rt("stage", always)


def test_retrier_does_not_retry_programming_errors():
    rt = Retrier(FaultPolicy(max_retries=3, **FAST))
    calls = []

    def broken():
        calls.append(1)
        raise KeyError("bug")

    with pytest.raises(KeyError):
        rt("stage", broken)
    assert len(calls) == 1


def test_retrier_keyed_injection_fires_once():
    inj = FaultInjector({"view_build": {5}})
    rt = Retrier(FaultPolicy(max_retries=2, **FAST), inj)
    # the keyed occurrence fails on attempt 0 and is retried clean
    assert rt("view_build", lambda: "v5", key=5) == "v5"
    assert inj.fired["view_build"] == [5]
    # with no retry budget the injected fault exhausts the stage
    with pytest.raises(FaultRetriesExceeded) as ei:
        Retrier(FaultPolicy(max_retries=0, **FAST),
                FaultInjector({"view_build": {5}}))(
            "view_build", lambda: "v5", key=5)
    assert isinstance(ei.value.__cause__, InjectedFault)


def test_sync_with_timeout_passthrough_and_timeout():
    assert sync_with_timeout(lambda: 3.5, None) == 3.5
    assert sync_with_timeout(lambda: 3.5, 5.0) == 3.5
    with pytest.raises(StepTimeoutError):
        sync_with_timeout(lambda: time.sleep(10) or 0.0, 0.05)
    with pytest.raises(RuntimeError, match="boom"):
        sync_with_timeout(lambda: (_ for _ in ()).throw(
            RuntimeError("boom")), 5.0)


# ---------------------------------------------------------------------------
# supervised prefetchers
# ---------------------------------------------------------------------------


def test_view_prefetcher_close_joins_thread():
    pf = ViewPrefetcher(iter(range(100)), lambda v: v, depth=2)
    assert next(pf) == 0
    pf.close()
    assert not pf._thread.is_alive()


def test_view_prefetcher_close_raises_on_stuck_thread():
    release = threading.Event()

    def prepare(v):
        if v == 1:
            release.wait(30)   # non-cancellable blocking user code
        return v

    pf = ViewPrefetcher(iter(range(10)), prepare, depth=1)
    assert next(pf) == 0
    with pytest.raises(PrefetchShutdownError, match="still alive"):
        pf.close(timeout=0.3)
    release.set()              # let the daemon die for real


def test_stream_prefetcher_worker_kill_respawns_and_preserves_order(g):
    stream = _views(g)
    inj = FaultInjector({"worker_kill": {1, 3}})
    rt = Retrier(FaultPolicy(max_retries=2, **FAST), inj)
    # prepare detaches (ring-buffer views must be consumed immediately)
    pf = StreamPrefetcher(stream, lambda v: np.array(v.loss_mask),
                          steps=8, workers=3, runtime=rt)
    got = list(pf)
    pf.close()
    ref = [np.array(_views(g).build(i).loss_mask) for i in range(8)]
    assert len(got) == 8
    for i, (a, b) in enumerate(zip(got, ref)):
        assert np.array_equal(a, b), f"view {i} not bit-identical"
    assert sorted(inj.fired["worker_kill"]) == [1, 3]
    assert all(not t.is_alive() for t in pf._threads)


def test_stream_prefetcher_respawn_cap_aborts(g):
    stream = _views(g)
    # every index kills its worker; cap of 2 respawns must abort the pool
    inj = FaultInjector({"worker_kill": 0.999})
    rt = Retrier(FaultPolicy(max_worker_respawns=2, **FAST), inj)
    pf = StreamPrefetcher(stream, lambda v: v, steps=8, workers=2,
                          runtime=rt)
    with pytest.raises(RuntimeError, match="max_worker_respawns"):
        list(pf)
    pf.close()


def test_stream_prefetcher_hang_reassigned_by_watchdog(g):
    stream = _views(g)
    inj = FaultInjector({"view_hang": {2}}, hang_seconds=10.0)
    rt = Retrier(FaultPolicy(timeouts={"view_build": 0.2}, **FAST), inj)
    pf = StreamPrefetcher(stream, lambda v: np.array(v.loss_mask),
                          steps=6, workers=2, runtime=rt)
    got = list(pf)
    assert len(got) == 6           # the hung index was rebuilt elsewhere
    assert inj.fired["view_hang"] == [2]
    pf.close()                     # wakes the hung waiter via the event


def test_stream_prefetcher_close_verifies_exit(g):
    pf = StreamPrefetcher(_views(g), lambda v: v, steps=64, workers=4)
    next(pf)
    pf.close()
    assert all(not t.is_alive() for t in pf._threads)


# ---------------------------------------------------------------------------
# the chaos contract: injected faults, bit-identical trajectory
# ---------------------------------------------------------------------------


def _chaos_run(make, make_views, tmp_path, steps=8, **fit_kw):
    base = make()
    ref = base.fit(make_views(), **fit_kw, steps=steps)["losses"]
    inj = FaultInjector(CHAOS_PLAN, seed=0)
    tr = make(fault_policy=FaultPolicy(**FAST), injector=inj)
    got = tr.fit(make_views(), **fit_kw, steps=steps,
                 checkpoint_dir=str(tmp_path),
                 checkpoint_every=3)["losses"]
    assert inj.total_fired() >= 3, inj.fired
    assert "worker_kill" in inj.fired
    assert "view_build" in inj.fired
    assert "checkpoint_save" in inj.fired
    assert list(map(float, got)) == list(map(float, ref))
    return tr


def test_chaos_trajectory_invariance_engine_trainer(g, tmp_path):
    def make(**kw):
        return _engine_trainer(g, **kw)

    tr = _chaos_run(make, lambda: _views(g), tmp_path,
                    prefetch_workers=3)
    tr.assert_compiled_once()


@pytest.mark.parametrize("backend", ["reference", "csc"])
def test_chaos_trajectory_invariance_compact_trainer(g, tmp_path, backend):
    def make(**kw):
        return _compact_trainer(g, backend=backend, **kw)

    tr = _chaos_run(make, lambda: _views(g, compact=True), tmp_path,
                    prefetch_workers=3)
    tr.assert_compiled_per_bucket()


def test_chaos_invariance_without_prefetch(g, tmp_path):
    """The inline (no-prefetch) path retries view builds too."""
    base = _engine_trainer(g)
    ref = base.fit(_views(g), steps=6, prefetch=False)["losses"]
    inj = FaultInjector({"view_build": {1, 4}, "device_put": {0}})
    tr = _engine_trainer(g, fault_policy=FaultPolicy(**FAST), injector=inj)
    got = tr.fit(_views(g), steps=6, prefetch=False)["losses"]
    assert inj.total_fired() >= 2
    assert list(map(float, got)) == list(map(float, ref))
    tr.assert_compiled_once()


# ---------------------------------------------------------------------------
# divergence recovery
# ---------------------------------------------------------------------------


def test_divergence_raise_restores_prestep_state(g):
    inj = FaultInjector({"diverge": {2}})
    tr = _engine_trainer(
        g, fault_policy=FaultPolicy(check_finite=True, **FAST),
        injector=inj)
    with pytest.raises(DivergenceError, match="non-finite"):
        tr.fit(_views(g), steps=6)
    assert tr.step_num == 2        # the poison update was discarded


def test_divergence_skip_view_completes_and_logs_event(g):
    inj = FaultInjector({"diverge": {2}})
    tr = _engine_trainer(
        g, fault_policy=FaultPolicy(on_divergence="skip_view", **FAST),
        injector=inj)
    out = tr.fit(_views(g), steps=6)
    assert tr.step_num == 5        # 6 views, one poisoned and skipped
    assert all(np.isfinite(out["losses"]))
    ev = [e for e in out["events"] if e.get("stage") == "diverge"]
    assert len(ev) == 1 and ev[0]["action"] == "skip_view"
    tr.assert_compiled_once()


@pytest.mark.parametrize("kind", ["engine", "compact"])
def test_divergence_rollback_restores_checkpoint_and_skips_view(
        g, tmp_path, kind):
    """Rollback e2e: non-finite loss -> restore last valid checkpoint,
    continue past the poison view via the stream cursor, complete."""
    make = _engine_trainer if kind == "engine" else _compact_trainer
    inj = FaultInjector({"diverge": {4}})
    tr = make(g, fault_policy=FaultPolicy(on_divergence="rollback",
                                          **FAST), injector=inj)
    out = tr.fit(_views(g, compact=(kind == "compact")), steps=8,
                 checkpoint_dir=str(tmp_path), checkpoint_every=2)
    ev = [e for e in out["events"] if e.get("stage") == "diverge"]
    assert len(ev) == 1 and ev[0]["action"] == "rollback"
    assert all(np.isfinite(out["losses"]))
    # rolled back to the step-4 checkpoint, then trained the remaining
    # 3 views (the poison view is never replayed)
    assert tr.step_num == 7
    if kind == "engine":
        tr.assert_compiled_once()
    else:
        tr.assert_compiled_per_bucket()


def test_divergence_rollback_without_checkpoint_raises(g):
    inj = FaultInjector({"diverge": {1}})
    tr = _engine_trainer(
        g, fault_policy=FaultPolicy(on_divergence="rollback", **FAST),
        injector=inj)
    with pytest.raises(DivergenceError, match="no valid checkpoint"):
        tr.fit(_views(g), steps=4)


def test_rollback_walks_past_corrupted_latest_checkpoint(g, tmp_path):
    """Corrupt the newest checkpoint: rollback's restore must detect it
    by checksum and fall back to the previous valid step."""
    from repro.checkpoint import checkpoint_steps
    # seed the directory: checkpoints at steps 2 and 4
    seeder = _engine_trainer(g, fault_policy=FaultPolicy(**FAST))
    seeder.fit(_views(g), steps=5, checkpoint_dir=str(tmp_path),
               checkpoint_every=2)
    steps = checkpoint_steps(str(tmp_path))
    assert steps == [2, 4]
    newest = tmp_path / f"step_{steps[-1]:08d}.npz"
    newest.write_bytes(newest.read_bytes()[:-40])   # truncate -> corrupt

    inj = FaultInjector({"diverge": {1}})
    tr = _engine_trainer(
        g, fault_policy=FaultPolicy(on_divergence="rollback", **FAST),
        injector=inj)
    out = tr.fit(_views(g), steps=4, checkpoint_dir=str(tmp_path))
    ev = [e for e in out["events"] if e.get("stage") == "diverge"]
    assert len(ev) == 1
    # poison at view idx 1 (step 2): rollback restores the newest VALID
    # checkpoint — step 2, because step 4's file fails its checksum —
    # then trains the remaining 2 views: 2 + 2 = 4 (a step-4 restore
    # would have ended at 6)
    assert tr.step_num == 4
    assert all(np.isfinite(out["losses"]))


def test_resume_true_restores_newest_valid_and_fast_forwards(g, tmp_path):
    stream = _views(g)
    tr = _engine_trainer(g, fault_policy=FaultPolicy(**FAST))
    tr.fit(stream, steps=6, checkpoint_dir=str(tmp_path),
           checkpoint_every=3)
    assert tr.view_cursor == 6

    tr2 = _engine_trainer(g, fault_policy=FaultPolicy(**FAST))
    stream2 = _views(g)
    out = tr2.fit(stream2, steps=2, checkpoint_dir=str(tmp_path),
                  resume=True)
    # resumed from step 6's checkpoint, stream fast-forwarded to view 6
    assert tr2.step_num == 8
    assert stream2.cursor == 8
    assert len(out["losses"]) == 2


def test_resume_with_empty_dir_is_fresh_start(g, tmp_path):
    tr = _engine_trainer(g, fault_policy=FaultPolicy(**FAST))
    out = tr.fit(_views(g), steps=3, checkpoint_dir=str(tmp_path),
                 resume=True)
    assert tr.step_num == 3 and len(out["losses"]) == 3


# ---------------------------------------------------------------------------
# step watchdog
# ---------------------------------------------------------------------------


def test_step_timeout_policy_fires_on_hung_pull(g):
    """A step timeout arms the watchdog around the loss sync; a fast
    normal fit passes untouched."""
    tr = _engine_trainer(
        g, fault_policy=FaultPolicy(timeouts={"step": 30.0}, **FAST))
    out = tr.fit(_views(g), steps=3)
    assert len(out["losses"]) == 3
    tr.assert_compiled_once()


# ---------------------------------------------------------------------------
# production path stays zero-overhead
# ---------------------------------------------------------------------------


def test_no_policy_means_no_runtime(g):
    tr = _engine_trainer(g)
    assert tr.runtime is None
    out = tr.fit(_views(g), steps=3)
    assert out["events"] == []
