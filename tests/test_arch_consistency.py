"""Deep consistency checks: cache semantics, MLA absorption, chunked-scan
equivalence, rolling-window decode, MoE expert-parallel == dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import arch_params, run_with_devices
from repro.arch import build_model
from repro.config import get_arch_config, MambaConfig

ARCH_PARAMS = arch_params()   # heavyweight archs marked slow (conftest)


def _batch_for(cfg, rng, B, S, train=False):
    b = {}
    if cfg.embed_inputs:
        b["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32)
    else:
        b["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)
    if train:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(S)[None], (B, S))
        b["mrope_positions"] = jnp.asarray(np.stack([pos, pos, pos]),
                                           jnp.int32)
    if cfg.encoder_layers:
        b["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_matches_prefill(arch):
    """prefill(S/2) + S/2 decode steps == prefill(S): exact cache carry
    for attention, MLA, Mamba state, RWKV state."""
    cfg = get_arch_config(arch).reduced().replace(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    batch = _batch_for(cfg, rng, B, S)
    lo_full, _, _ = model.prefill(params, batch, cache_len=S)
    half = S // 2
    pb = {k: (v[:, :half] if k in ("tokens",) else v)
          for k, v in batch.items()}
    if cfg.embed_inputs:
        pb["embeds"] = batch["embeds"][:, :half]
    if cfg.mrope:
        pb["mrope_positions"] = batch["mrope_positions"][:, :, :half]
    lo, caches, idx = model.prefill(params, pb, cache_len=S)
    for t in range(half, S):
        db = {}
        if cfg.embed_inputs:
            db["embeds"] = batch["embeds"][:, t:t + 1]
        else:
            db["tokens"] = batch["tokens"][:, t:t + 1]
        if cfg.mrope:
            db["mrope_positions"] = batch["mrope_positions"][:, :, t:t + 1]
        if cfg.encoder_layers:
            db["enc_frames"] = batch["enc_frames"]
        lo, caches, idx = model.decode_step(params, db, caches, idx)
    err = float(jnp.abs(lo - lo_full).max())
    assert err < 2e-3, (arch, err)


def test_chunk_size_invariance_mamba():
    """SSD chunked scan result independent of chunk size."""
    from repro.arch.mamba import mamba_init, mamba_apply
    mc16 = MambaConfig(d_state=8, head_dim=16, chunk=16)
    mc4 = MambaConfig(d_state=8, head_dim=16, chunk=4)
    p = mamba_init(jax.random.PRNGKey(0), 32, mc16, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, 32)),
                    jnp.float32)
    y16, _ = mamba_apply(p, x, mc16)
    y4, _ = mamba_apply(p, x, mc4)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y4),
                               rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance_rwkv():
    from repro.arch.rwkv6_block import wkv_chunked
    rng = np.random.default_rng(0)
    B, T, H, K = 2, 64, 2, 16
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    o8, s8 = wkv_chunked(r, k, v, w, u, chunk=8)
    o32, s32 = wkv_chunked(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(o8), np.asarray(o32),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s32),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_rolling_window_decode_matches_full_cache():
    """O(window) rolling cache == full cache for a SWA model."""
    cfg = get_arch_config("mixtral-8x7b").reduced().replace(
        dtype="float32", sliding_window=8)
    rng = np.random.default_rng(2)
    B, S = 1, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    def run(rolling):
        model = build_model(cfg, remat=False, rolling_window_decode=rolling)
        params = model.init(jax.random.PRNGKey(3))
        # decode from scratch token by token
        caches = model.init_cache(B, S)
        idx = jnp.zeros((), jnp.int32)
        outs = []
        for t in range(S):
            lo, caches, idx = model.decode_step(
                params, {"tokens": toks[:, t:t + 1]}, caches, idx)
            outs.append(lo)
        return jnp.concatenate(outs, axis=1)

    full = run(False)
    roll = run(True)
    err = float(jnp.abs(full - roll).max())
    assert err < 2e-3, err


_MOE_EP = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.arch.moe import moe_init, moe_ffn_dense, moe_ffn_ep
from repro.config import MoEConfig

mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
for E, topk in [(4, 2), (2, 1), (8, 2)]:
    moe = MoEConfig(num_experts=E, top_k=topk, capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), 32, 64, E, jnp.float32)
    x = jnp.asarray(np.random.default_rng(E).normal(size=(4, 8, 32)),
                    jnp.float32)
    y_dense, aux_d = moe_ffn_dense(p, x, moe)
    y_ep, aux_e = moe_ffn_ep(p, x, moe, mesh, axis="model", dp_axis="data")
    err = float(jnp.abs(y_dense - y_ep).max())
    scale = float(jnp.abs(y_dense).max())
    assert err < 1e-4 * max(scale, 1.0), (E, topk, err, scale)
    assert abs(float(aux_d) - float(aux_e)) < 1e-5
    print("E", E, "topk", topk, "err", err)
print("ALL_OK")
"""


@pytest.mark.slow
def test_moe_expert_parallel_equals_dense():
    out = run_with_devices(_MOE_EP, n_devices=4, timeout=600)
    assert "ALL_OK" in out


def test_mla_absorbed_decode_equals_prefill():
    from repro.nn.attention import mla_init, mla_apply
    from repro.config import MLAConfig
    mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                    qk_rope_head_dim=8, v_head_dim=8)
    key = jax.random.PRNGKey(0)
    p = mla_init(key, 64, 4, mla)
    x = jax.random.normal(key, (2, 8, 64))
    full = mla_apply(p, x, num_heads=4, mla=mla,
                     positions=jnp.arange(8)[None])
    cache = {"c_kv": jnp.zeros((2, 8, 16)), "k_rope": jnp.zeros((2, 8, 8))}
    outs = []
    for t in range(8):
        o, cache = mla_apply(p, x[:, t:t + 1], num_heads=4, mla=mla,
                             positions=jnp.full((1, 1), t, jnp.int32),
                             cache=cache, cache_index=jnp.asarray(t))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_mamba_parallel_scan_vs_naive_recurrence():
    """Chunked/associative-scan SSD == step-by-step recurrence oracle."""
    from repro.arch.mamba import mamba_init, mamba_apply, mamba_init_cache
    mc = MambaConfig(d_state=8, head_dim=16, chunk=8)
    d = 32
    p = mamba_init(jax.random.PRNGKey(5), d, mc, jnp.float32)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 16, d)), jnp.float32)
    y_par, _ = mamba_apply(p, x, mc)
    cache = mamba_init_cache(p, 1, mc, d, jnp.float32)
    outs = []
    for t in range(16):
        o, cache = mamba_apply(p, x[:, t:t + 1], mc, cache=cache)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=3e-4, atol=3e-4)
