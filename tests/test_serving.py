"""Online GNN serving: cache parity, bucket contract, concurrency, facade.

The load-bearing claim is the first test: at ``staleness=0`` the
historical-embedding fast path is **bit-exact** with the full K-hop
recompute (hop ordering makes the cached rows the true full-graph
h^{K-1}, and the 1-hop view aggregates the same edges in the same CSC
order). Everything else — stale drift bounds, compiled-once-per-bucket,
client-count invariance, the train -> checkpoint -> serve round trip —
leans on that.
"""
import threading
import time

import jax
import numpy as np
import pytest

import repro.api as api


@pytest.fixture(scope="module")
def trained():
    return api.train(api.TrainJob(dataset="cora", steps=30, hidden=32,
                                  eval_every=29))


def _server(trained, **kw):
    kw.setdefault("max_batch", 8)
    return api.serve(trained, api.ServeConfig(**kw))


def test_cache_hit_bitexact_vs_full_recompute(trained):
    rng = np.random.default_rng(0)
    targets = rng.choice(trained.graph.num_nodes, 12, replace=False)
    cached = _server(trained)
    plain = _server(trained, cache=False)
    first = cached.submit(targets)          # all misses; warms the cache
    again = cached.submit(targets)          # covered targets now hit
    assert cached.cache.stats()["hits"] > 0
    np.testing.assert_array_equal(first, again)
    np.testing.assert_array_equal(first, plain.submit(targets))
    # and both match the offline oracle
    np.testing.assert_array_equal(first, api.infer(trained, targets))


def test_stale_cache_bounded_drift(trained):
    rng = np.random.default_rng(1)
    targets = rng.choice(trained.graph.num_nodes, 12, replace=False)
    srv = _server(trained, staleness=1)
    srv.submit(targets)                     # cache under the old params
    # a small online update to the *bottom* layer (so the true h^{K-1}
    # moves): staleness=1 keeps serving the pre-update embeddings
    # through the new top layer
    layers = list(trained.params["layers"])
    layers[0] = jax.tree_util.tree_map(lambda a: a + 1e-3, layers[0])
    bumped = {**trained.params, "layers": layers}
    srv.update_params(bumped)
    h0 = srv.cache.stats()["hits"]
    served = srv.submit(targets)
    assert srv.cache.stats()["hits"] > h0   # stale entries still admit
    oracle = _server(trained, cache=False)
    oracle.update_params(bumped)
    exact = oracle.submit(targets)
    drift = np.abs(served - exact).max()
    assert 0 < drift < 0.1, drift           # bounded by the perturbation
    # staleness=0 rejects the aged entries and recovers exactness
    strict = _server(trained)
    strict.submit(targets)
    strict.update_params(bumped)
    h0 = strict.cache.stats()["hits"]
    np.testing.assert_array_equal(strict.submit(targets), exact)
    assert strict.cache.stats()["hits"] == h0


def test_compiled_once_per_bucket_over_mixed_trace(trained):
    srv = _server(trained)
    rng = np.random.default_rng(2)
    n = trained.graph.num_nodes
    for size in (1, 3, 7, 2, 8, 1, 5, 8, 3):    # mixed batch sizes
        srv.submit(rng.integers(0, n, size))
    srv.assert_compiled_per_bucket()
    tr = srv.server_stats()["trace"]
    assert tr["full"]["traces"] == len(tr["full"]["buckets"])
    if tr["hit"]["traces"]:
        assert tr["hit"]["traces"] == len(tr["hit"]["buckets"])


def test_feature_update_invalidates_dependents(trained):
    rng = np.random.default_rng(3)
    g = trained.graph
    targets = rng.choice(g.num_nodes, 10, replace=False)
    srv = _server(trained)
    srv.submit(targets)
    node = int(targets[0])
    srv.update_features(np.array([node]),
                        g.node_features[node] + 0.5)
    served = srv.submit(targets)
    # oracle over the *updated* graph — fresh full recompute
    oracle = _server(trained, cache=False)
    np.testing.assert_array_equal(served, oracle.submit(targets))
    srv.assert_compiled_per_bucket()


def test_concurrent_clients_deterministic(trained):
    from repro.launch.serve_gnn import request_trace, run_clients
    trace = request_trace(trained.graph, 60, seed=4)

    def serve_with(clients):
        srv = _server(trained, max_batch=4, max_wait_ms=1.0).start()
        try:
            out, _ = run_clients(srv, trace, clients)
        finally:
            srv.stop()
        srv.assert_compiled_per_bucket()
        return out

    np.testing.assert_array_equal(serve_with(1), serve_with(4))


def test_request_requires_start_and_submit_validates(trained):
    srv = _server(trained)
    with pytest.raises(RuntimeError):
        srv.request(0)
    with pytest.raises(ValueError):
        srv.submit([])
    with pytest.raises(ValueError):
        srv.submit([trained.graph.num_nodes])


def test_facade_train_checkpoint_serve_roundtrip(tmp_path):
    ckdir = str(tmp_path / "ck")
    result = api.train(api.TrainJob(dataset="cora", steps=10, hidden=32,
                                    eval_every=9, checkpoint_dir=ckdir,
                                    checkpoint_every=5))
    srv = api.serve(result, api.ServeConfig(checkpoint_dir=ckdir,
                                            max_batch=8))
    nodes = np.arange(8)
    np.testing.assert_array_equal(srv.submit(nodes),
                                  api.infer(result, nodes))


def test_k1_model_has_no_cache(trained):
    job = api.TrainJob(dataset="cora", steps=2, hidden=16, num_layers=1,
                       eval_every=2)
    r = api.train(job)
    srv = api.serve(r)
    assert srv.cache is None
    out = srv.submit(np.arange(5))
    assert out.shape == (5, int(r.graph.labels.max()) + 1)
    srv.assert_compiled_per_bucket()


def test_close_fails_queued_requests_and_refuses_new(trained):
    from repro.serving import ServerClosedError
    # a huge deadline + batch keeps everything queued until close()
    srv = _server(trained, max_batch=64, max_wait_ms=10_000.0).start()
    errs, n = [], 6

    def client(i):
        try:
            srv.request(i)
        except Exception as e:  # noqa: BLE001 — collected for assertion
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + 5.0
    while len(srv._queue) < n:
        assert time.monotonic() < deadline
    srv.close()
    for t in threads:
        t.join()
    # every queued future failed typed — none served, none stuck
    assert len(errs) == n
    assert all(isinstance(e, ServerClosedError) for e in errs)
    # and the server stays closed on every entry point
    with pytest.raises(ServerClosedError):
        srv.request(0)
    with pytest.raises(ServerClosedError):
        srv.submit([0])
    with pytest.raises(ServerClosedError):
        srv.start()
    srv.close()                             # idempotent


def test_bounded_queue_sheds_load_typed(trained):
    from repro.serving import ServerClosedError, ServerOverloadedError
    srv = _server(trained, max_batch=64, max_wait_ms=10_000.0,
                  max_queue=2).start()
    errs = []

    def client(i):
        try:
            srv.request(i)
        except Exception as e:  # noqa: BLE001 — collected for assertion
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(2)]
    try:
        for t in threads:
            t.start()
        deadline = time.monotonic() + 5.0
        while len(srv._queue) < 2:
            assert time.monotonic() < deadline
        with pytest.raises(ServerOverloadedError, match="back off"):
            srv.request(2)
    finally:
        srv.close()
        for t in threads:
            t.join()
    # the two admitted requests were failed typed at close, not leaked
    assert len(errs) == 2
    assert all(isinstance(e, ServerClosedError) for e in errs)


def test_param_swap_hammer_never_blends(trained):
    """Concurrent update_params swaps + submits: every response equals
    the oracle for params A or for params B — never a mix of cached
    rows from one version with the top layer of the other."""
    rng = np.random.default_rng(5)
    targets = rng.choice(trained.graph.num_nodes, 10, replace=False)
    params_a = trained.params
    params_b = jax.tree_util.tree_map(lambda x: x + 1e-2, params_a)
    oracle = _server(trained, cache=False)
    out_a = oracle.submit(targets)
    oracle.update_params(params_b)
    out_b = oracle.submit(targets)
    assert np.abs(out_a - out_b).max() > 0   # the versions are tellable

    srv = _server(trained)
    srv.submit(targets)                      # warm the cache under A
    stop = threading.Event()
    mismatches = []

    def swapper():
        flip = True
        while not stop.is_set():
            srv.update_params(params_b if flip else params_a)
            flip = not flip

    def hammer():
        for _ in range(25):
            out = srv.submit(targets)
            if not (np.array_equal(out, out_a)
                    or np.array_equal(out, out_b)):
                mismatches.append(out)

    sw = threading.Thread(target=swapper)
    hs = [threading.Thread(target=hammer) for _ in range(3)]
    sw.start()
    for h in hs:
        h.start()
    for h in hs:
        h.join()
    stop.set()
    sw.join()
    assert not mismatches, "served a blend of two param versions"
    srv.assert_compiled_per_bucket()


def test_queue_batches_concurrent_requests(trained):
    srv = _server(trained, max_batch=16, max_wait_ms=20.0).start()
    try:
        outs = {}

        def client(i):
            outs[i] = srv.request(i % trained.graph.num_nodes)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        srv.stop()
    s = srv.server_stats()
    assert s["requests"] == 8
    assert s["batches"] < 8                 # the deadline coalesced them
    for i, out in outs.items():
        np.testing.assert_array_equal(
            out, api.infer(trained, [i % trained.graph.num_nodes])[0])
