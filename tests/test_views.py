"""Vectorized view-construction engine (repro.core.views + the vectorized
``bfs_layers``): parity against the loop/recompute oracles, buffer-ring
reuse, neighbor-cap sampling semantics, and ViewStream index stability.

PR 6 adds the compact sampled-subgraph path: CompactView-vs-dense parity
(bit-exact node/edge sets from the same stream index), size-bucketed
padding (BucketSpec / CompactBlockBuilder), sharding parity, and loss
parity through both aggregate backends.

The hypothesis sweep lives in test_strategies_properties.py (dev extra).
"""
import warnings

import numpy as np
import pytest

from repro.core.clustering import (cluster_members, hash_clusters,
                                   label_propagation_clusters)
from repro.core.strategies import (cluster_batch_views, mini_batch_views,
                                   shard_view, strategy_views)
from repro.core.subgraph import (bfs_layers, bfs_layers_loop,
                                 khop_subgraph_view)
from repro.core.views import (BucketSpec, ClusterViewCache,
                              ClusterViewStream, CompactBlockBuilder,
                              CompactView, GlobalViewStream, GraphView,
                              MiniBatchViewStream, ViewBuilder,
                              cluster_view_recompute)
from repro.graph import sbm_graph


def _g(seed=0, n=300):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8, p_in=0.05,
                     p_out=0.005, seed=seed)


def _assert_hops_equal(a, b):
    assert len(a[0]) == len(b[0])
    for ha, hb in zip(a[0], b[0]):
        assert ha.dtype == hb.dtype
        assert np.array_equal(ha, hb)
    assert np.array_equal(a[1], b[1])   # visited


# ---------------------------------------------------------------------------
# vectorized bfs_layers == per-node loop oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("depth", [1, 2, 4])
def test_bfs_parity(seed, depth):
    g = _g(seed)
    rng = np.random.default_rng(seed)
    targets = rng.choice(g.num_nodes, size=12, replace=False)
    _assert_hops_equal(bfs_layers(g, targets, depth),
                       bfs_layers_loop(g, targets, depth))


def test_bfs_parity_edge_cases():
    g = _g(3)
    # empty target set
    empty = np.zeros(0, np.int64)
    _assert_hops_equal(bfs_layers(g, empty, 3), bfs_layers_loop(g, empty, 3))
    # disconnected targets: a node with no in-edges stalls the frontier
    indeg = g.in_degree()
    isolated = np.where(indeg == 0)[0]
    targets = (isolated[:2] if len(isolated)
               else np.array([int(np.argmin(indeg))]))
    _assert_hops_equal(bfs_layers(g, targets, 3),
                       bfs_layers_loop(g, targets, 3))
    # duplicated targets collapse identically
    dup = np.array([5, 5, 7, 7, 7, 9])
    _assert_hops_equal(bfs_layers(g, dup, 2), bfs_layers_loop(g, dup, 2))


def test_khop_masks_parity_loop_vs_vectorized():
    g = _g(4)
    targets = np.random.default_rng(0).choice(g.num_nodes, 20, replace=False)
    for K in (1, 2, 3):
        a = khop_subgraph_view(g, targets, K)
        b = khop_subgraph_view(g, targets, K, _bfs=bfs_layers_loop)
        for x, y in zip(a, b):
            assert np.array_equal(x, y)


# ---------------------------------------------------------------------------
# neighbor-cap sampling
# ---------------------------------------------------------------------------


def test_neighbor_cap_requires_rng():
    """The old bare ``assert rng is not None`` vanished under python -O;
    both implementations now raise ValueError up front."""
    g = _g(5)
    with pytest.raises(ValueError, match="Generator"):
        bfs_layers(g, np.arange(4), 2, neighbor_cap=3)
    with pytest.raises(ValueError, match="Generator"):
        bfs_layers_loop(g, np.arange(4), 2, neighbor_cap=3)
    with pytest.raises(ValueError, match="Generator"):
        khop_subgraph_view(g, np.arange(4), 2, neighbor_cap=3)


def test_neighbor_cap_semantics():
    g = _g(6)
    targets = np.arange(6)
    full_hops, full_visited = bfs_layers(g, targets, 2)
    capped_hops, capped_visited = bfs_layers(
        g, targets, 2, neighbor_cap=2, rng=np.random.default_rng(0))
    # capped exploration is a subset of the full BFS
    assert np.all(full_visited[capped_visited])
    for hc, hf in zip(capped_hops, full_hops):
        assert np.all(np.isin(hc, hf))
    # a cap at/above the max in-degree is a no-op (bit-exact with full)
    big = int(g.in_degree().max())
    relaxed = bfs_layers(g, targets, 2, neighbor_cap=big,
                         rng=np.random.default_rng(1))
    _assert_hops_equal(relaxed, (full_hops, full_visited))
    # same seed -> same sample (the vectorized draw is deterministic)
    a = bfs_layers(g, targets, 2, neighbor_cap=2,
                   rng=np.random.default_rng(7))
    b = bfs_layers(g, targets, 2, neighbor_cap=2,
                   rng=np.random.default_rng(7))
    _assert_hops_equal(a, b)


def test_neighbor_cap_bounds_per_node_fanin():
    """Each frontier node contributes at most ``cap`` in-neighbors: hop 1
    from a single target can never exceed cap new nodes."""
    g = _g(7)
    deg = g.in_degree()
    u = int(np.argmax(deg))
    assert deg[u] > 3
    hops, _ = bfs_layers(g, np.array([u]), 1, neighbor_cap=3,
                         rng=np.random.default_rng(0))
    # hop set includes the target itself
    assert len(hops[1]) <= 1 + 3


# ---------------------------------------------------------------------------
# ViewBuilder: parity + buffer-ring reuse
# ---------------------------------------------------------------------------


def test_builder_khop_parity_and_ring_reuse():
    g = _g(8)
    vb = ViewBuilder(g, 2, slots=2)
    buffer_ids = set()
    for seed in range(5):
        t = np.random.default_rng(seed).choice(g.num_nodes, 16,
                                               replace=False)
        na, ea, lm, _ = khop_subgraph_view(g, t, 2)
        v = vb.khop_view(t)
        assert np.array_equal(v.node_active, na)
        assert np.array_equal(v.edge_active, ea)
        assert np.array_equal(v.loss_mask, lm)
        buffer_ids.add(id(v.node_active))
    # no fresh (K, N) allocations: the ring's 2 slots were reused
    assert len(buffer_ids) == 2
    assert vb.builds == 5


@pytest.mark.parametrize("halo", [0, 1, 2])
def test_cluster_cache_parity(halo):
    g = _g(9)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    cache = ClusterViewCache(g, clusters, halo)
    vb = ViewBuilder(g, 2)
    train = g.train_mask
    rng = np.random.default_rng(halo)
    for _ in range(5):
        chosen = rng.choice(cache.num_clusters, size=3, replace=False)
        member, active, loss = cluster_view_recompute(g, clusters, chosen,
                                                      halo, train)
        v = vb.cluster_view(chosen, cache, train)
        assert np.array_equal(
            v.node_active,
            np.broadcast_to(active.astype(np.float32),
                            (2, g.num_nodes)))
        assert np.array_equal(
            v.edge_active,
            np.broadcast_to((active[g.src] & active[g.dst])
                            .astype(np.float32), (2, g.num_edges)))
        assert np.array_equal(v.loss_mask, loss)


def test_cluster_cache_loss_fallback_parity():
    """When no chosen member is labeled, loss falls back to all members —
    in both the cached and the recompute path."""
    g = _g(10, n=120)
    clusters = hash_clusters(g, 6, seed=0)
    no_train = np.zeros(g.num_nodes, bool)
    cache = ClusterViewCache(g, clusters, 1)
    vb = ViewBuilder(g, 2)
    chosen = np.array([0, 3])
    member, active, loss = cluster_view_recompute(g, clusters, chosen, 1,
                                                  no_train)
    v = vb.cluster_view(chosen, cache, no_train)
    assert loss.sum() > 0
    assert np.array_equal(v.loss_mask, loss)


def test_cluster_members_partition():
    labels = np.array([2, 0, 1, 0, 2, 2, 1])
    members = cluster_members(labels)
    assert [m.tolist() for m in members] == [[1, 3], [2, 6], [0, 4, 5]]


# ---------------------------------------------------------------------------
# ViewStreams: index-stable, order-independent construction
# ---------------------------------------------------------------------------


def test_mini_stream_order_independent():
    g = _g(11)
    s = MiniBatchViewStream(g, 2, batch_nodes=16, seed=3)
    out_of_order = [s.build(i).copy_masks() for i in (4, 0, 2)]
    in_order = {i: s.build(i).copy_masks() for i in range(5)}
    for v, i in zip(out_of_order, (4, 0, 2)):
        assert np.array_equal(v.node_active, in_order[i].node_active)
        assert np.array_equal(v.loss_mask, in_order[i].loss_mask)
    # iterator protocol walks the same indices and tracks the cursor
    it = iter(s)
    assert s.cursor == 0
    first = next(it).copy_masks()
    assert s.cursor == 1
    assert np.array_equal(first.edge_active, in_order[0].edge_active)
    s.seek(4)
    assert np.array_equal(next(it).copy_masks().loss_mask,
                          in_order[4].loss_mask)


def test_cluster_stream_order_independent():
    g = _g(12)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    s = ClusterViewStream(g, 2, clusters, clusters_per_batch=2,
                          halo_hops=1, seed=5)
    a = s.build(9).copy_masks()
    b = s.build(9, ViewBuilder(g, 2)).copy_masks()  # private builder, same i
    assert np.array_equal(a.node_active, b.node_active)
    assert a.meta["clusters"] == b.meta["clusters"]


def test_stream_length_exhausts():
    g = _g(13)
    s = strategy_views(g, "mini", 2, seed=0, steps=3, batch_nodes=8)
    assert len(list(s)) == 3
    with pytest.raises(StopIteration):
        next(s)


def test_stream_iterator_yields_detached_views():
    """next() on a stream detaches from the builder ring (the legacy
    generator contract) — buffering several views is safe."""
    g = _g(20)
    s = strategy_views(g, "mini", 2, seed=0, batch_nodes=8)
    buffered = [next(s) for _ in range(4)]
    assert len({id(v.node_active) for v in buffered}) == 4
    replay = [s.build(i).copy_masks() for i in range(4)]
    for v, r in zip(buffered, replay):
        assert np.array_equal(v.node_active, r.node_active)
        assert np.array_equal(v.loss_mask, r.loss_mask)


def test_global_stream_is_static():
    g = _g(14)
    s = strategy_views(g, "global", 2)
    assert isinstance(s, GlobalViewStream)
    assert s.build(0) is s.build(99)
    assert s.make_builder() is None


def test_mini_stream_empty_labeled_raises():
    g = _g(15, n=60)
    g.train_mask = np.zeros(g.num_nodes, bool)
    with pytest.raises(ValueError, match="no labeled nodes"):
        MiniBatchViewStream(g, 2, batch_nodes=4)


# ---------------------------------------------------------------------------
# legacy generators keep their contract (detached arrays, same semantics)
# ---------------------------------------------------------------------------


def test_generators_yield_detached_views():
    g = _g(16)
    clusters = hash_clusters(g, 8, seed=0)
    mvs = list(mini_batch_views(g, 2, batch_nodes=8, seed=0, steps=3))
    assert len({id(v.node_active) for v in mvs}) == 3
    # earlier views are not clobbered by later builds
    snap = mvs[0].node_active.copy()
    assert np.array_equal(snap, mvs[0].node_active)
    cvs = list(cluster_batch_views(g, 2, clusters, clusters_per_batch=2,
                                   halo_hops=1, seed=0, steps=3))
    assert len({id(v.edge_active) for v in cvs}) == 3


# ---------------------------------------------------------------------------
# compact sampled-subgraph views: bit-exact parity with the dense oracle
# ---------------------------------------------------------------------------


def _assert_compact_matches_dense(cv, dv):
    """to_dense() is the bit-parity bridge: identical node/edge/loss masks
    from the same stream index, plus the compact structural invariants."""
    assert isinstance(cv, CompactView)
    cd = cv.to_dense()
    assert np.array_equal(cd.node_active, dv.node_active)
    assert np.array_equal(cd.edge_active, dv.edge_active)
    assert np.array_equal(cd.loss_mask, dv.loss_mask)
    assert cv.active_counts() == dv.active_counts()
    # structural invariants the bucketed block fill relies on
    assert int(cv.hop_offsets[-1]) == cv.num_nodes
    assert np.all(np.diff(cv.hop_offsets) >= 0)
    assert np.all(np.diff(cv.dst_local) >= 0)          # CSC-sorted
    assert len(np.unique(cv.nodes)) == cv.num_nodes    # relabeling is 1:1
    g = cv.graph
    assert np.array_equal(cv.nodes[cv.src_local], g.src[cv.edge_ids])
    assert np.array_equal(cv.nodes[cv.dst_local], g.dst[cv.edge_ids])


@pytest.mark.parametrize("neighbor_cap", [0, 5])
def test_compact_mini_parity_bit_exact(neighbor_cap):
    g = _g(30)
    kw = dict(batch_nodes=16, neighbor_cap=neighbor_cap, seed=3)
    dense = strategy_views(g, "mini", 2, **kw)
    comp = strategy_views(g, "mini", 2, compact=True, **kw)
    for i in (0, 1, 4):
        _assert_compact_matches_dense(comp.build(i),
                                      dense.build(i).copy_masks())


@pytest.mark.parametrize("halo", [0, 1])
def test_compact_cluster_parity_bit_exact(halo):
    g = _g(31)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    kw = dict(clusters=clusters, clusters_per_batch=2, halo_hops=halo,
              seed=halo)
    dense = strategy_views(g, "cluster", 2, **kw)
    comp = strategy_views(g, "cluster", 2, compact=True, **kw)
    for i in (0, 2):
        cv = comp.build(i)
        _assert_compact_matches_dense(cv, dense.build(i).copy_masks())
        # cluster ordering is flat: every node active in every layer
        assert np.all(cv.hop_offsets == cv.num_nodes)


def test_compact_stream_iterator_detaches():
    """next() on a compact stream honors the detached-view contract."""
    g = _g(40)
    s = strategy_views(g, "mini", 2, seed=0, batch_nodes=8, compact=True)
    buffered = [next(s) for _ in range(3)]
    replay = [s.build(i) for i in range(3)]
    for v, r in zip(buffered, replay):
        assert isinstance(v, CompactView)
        assert np.array_equal(v.nodes, r.nodes)
        assert np.array_equal(v.edge_ids, r.edge_ids)


def test_compact_shard_parity():
    """_shard_compact's O(view) scatter == dense shard of to_dense()."""
    from repro.core.partition import build_partitions
    g = _g(32)
    plan = build_partitions(g, 3).plan
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    for strategy in ("mini", "cluster"):
        comp = strategy_views(g, strategy, 2, seed=9, batch_nodes=20,
                              clusters=clusters, clusters_per_batch=2,
                              halo_hops=1, compact=True)
        for i in range(3):
            cv = comp.build(i)
            a = shard_view(plan, cv)
            b = shard_view(plan, cv.to_dense())
            assert set(a) == set(b)
            for k in a:
                assert a[k].shape == b[k].shape
                assert np.array_equal(a[k], b[k]), (strategy, k)


def test_compact_view_nbytes_scales_with_view():
    """The memory model the tentpole claims: compact host bytes are
    O(view), so a small mini view is far below the dense (K,N)+(K,E)
    footprint on the same graph."""
    g = _g(41)
    s = strategy_views(g, "mini", 2, seed=0, batch_nodes=4,
                       neighbor_cap=3, compact=True)
    cv = s.build(0)
    dense_bytes = 4 * (2 * g.num_nodes + 2 * g.num_edges + g.num_nodes)
    assert cv.nbytes() < dense_bytes / 4


# ---------------------------------------------------------------------------
# size-bucketed padding: BucketSpec + CompactBlockBuilder
# ---------------------------------------------------------------------------


def test_bucket_spec_pick_and_overflow():
    spec = BucketSpec(((64, 256), (128, 1024), (32, 128)))
    assert spec.shapes == ((32, 128), (64, 256), (128, 1024))
    assert len(spec) == 3
    assert spec.pick(10, 100) == (32, 128)    # smallest fit
    assert spec.pick(33, 100) == (64, 256)    # node side promotes
    assert spec.pick(10, 300) == (128, 1024)  # edge side promotes too
    with pytest.raises(ValueError, match="overflows every bucket"):
        spec.pick(200, 10)
    with pytest.raises(ValueError):
        BucketSpec(())


def test_bucket_spec_for_graph_fits_worst_case():
    g = _g(42)
    spec = BucketSpec.for_graph(g)
    # the largest bucket always fits the whole graph (no overflow possible
    # for any view) and the ladder is strictly sorted
    n_top, e_top = spec.shapes[-1]
    assert n_top >= g.num_nodes and e_top >= g.num_edges
    assert spec.pick(g.num_nodes, g.num_edges) == (n_top, e_top)


def test_compact_block_builder_ring_reuse_and_overflow():
    g = _g(33)
    comp = strategy_views(g, "mini", 2, seed=1, batch_nodes=12,
                          compact=True)
    bb = CompactBlockBuilder(g, 2, slots=2)
    ids, shapes = set(), set()
    for i in range(6):
        cv = comp.build(i)
        assert bb.bucket_for(cv) in bb.buckets.shapes
        blk = bb.stage(cv)
        shapes.add((blk.x.shape[0], blk.src.shape[0]))
        ids.add(id(blk.x))
    assert shapes <= set(bb.buckets.shapes)
    # per-bucket rings: at most ``slots`` buffer sets per touched bucket,
    # and untouched buckets allocate nothing (the empty-bucket case)
    assert len(ids) <= 2 * len(shapes)
    assert set(bb._rings) == shapes
    assert bb.stages == 6
    # a spec too small for the view degrades gracefully: escalate to a
    # covering power-of-two shape (capped at graph capacity), warn once,
    # count the overflow — a long run is never killed by one big cluster
    tiny = CompactBlockBuilder(g, 2, buckets=BucketSpec(((2, 2),)))
    cv = comp.build(0)
    with pytest.warns(RuntimeWarning, match="overflows every bucket"):
        blk = tiny.stage(cv)
    assert blk.x.shape[0] >= cv.num_nodes
    assert blk.src.shape[0] >= cv.num_edges
    assert blk.x.shape[0] <= g.num_nodes
    assert tiny.overflows == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # the warning fires only once
        tiny.stage(comp.build(1))
    assert tiny.overflows == 2


def test_compact_block_fill_matches_to_dense_block():
    """The bucket-padded block carries exactly the view's data: pad lanes
    inert (mask 0, src=dst=0), prefix lanes equal to the gathered graph
    data, per-layer actives equal to the dense masks in local order."""
    g = _g(43)
    comp = strategy_views(g, "mini", 2, seed=2, batch_nodes=10,
                          compact=True)
    cv = comp.build(0)
    n, e = cv.num_nodes, cv.num_edges
    blk = cv.as_block(bucket=BucketSpec.for_graph(g).pick(n, e))
    assert blk.node_mask[:n].all() and not blk.node_mask[n:].any()
    assert blk.edge_mask[:e].all() and not blk.edge_mask[e:].any()
    assert np.array_equal(blk.x[:n], g.node_features[cv.nodes])
    assert np.array_equal(blk.y[:n], g.labels[cv.nodes])
    assert np.array_equal(blk.edge_weight[:e], g.gcn_norm()[cv.edge_ids])
    assert not blk.edge_weight[e:].any()
    dv = cv.to_dense()
    for k in range(2):
        assert np.array_equal(blk.node_active[k, :n],
                              dv.node_active[k, cv.nodes])
        assert np.array_equal(blk.edge_active[k, :e],
                              dv.edge_active[k, cv.edge_ids])
        assert not blk.node_active[k, n:].any()
        assert not blk.edge_active[k, e:].any()


@pytest.mark.parametrize("backend", ["reference", "csc"])
def test_compact_block_loss_parity_both_backends(backend):
    """Same loss from the compact bucketed block and the dense full-graph
    block, through both aggregate backends (the CSC path exercises the
    per-bucket CSCPlan geometry)."""
    import jax
    from repro.config import GNNConfig
    from repro.core.mpgnn import loss_block
    from repro.models import make_gnn
    g = _g(34)
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=8, num_classes=4,
                    feature_dim=8, aggregate_backend=backend)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), 8)
    use_csc = backend == "csc"
    kw = dict(batch_nodes=16, seed=6)
    dense = strategy_views(g, "mini", 2, **kw)
    comp = strategy_views(g, "mini", 2, compact=True, **kw)
    spec = BucketSpec.for_graph(g)
    for i in range(2):
        dv = dense.build(i).copy_masks()
        cv = comp.build(i)
        ld = float(loss_block(model, params,
                              dv.as_block(csc_plan=use_csc)))
        bucket = spec.pick(cv.num_nodes, cv.num_edges)
        lc = float(loss_block(model, params,
                              cv.as_block(csc_plan=use_csc, bucket=bucket)))
        assert np.isclose(ld, lc, atol=1e-5), (backend, i, ld, lc)


@pytest.mark.parametrize("strategy", ["mini", "cluster"])
def test_compact_trainer_loss_trajectory_matches_dense(strategy):
    """End-to-end fp parity: the bucketed CompactTrainer over a compact
    stream tracks the same trainer over the dense stream step for step."""
    import jax
    from repro.config import GNNConfig
    from repro.core.trainer import CompactTrainer
    from repro.models import make_gnn
    from repro.optim import adam
    g = _g(37)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=8, num_classes=4,
                    feature_dim=8)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), 8)
    losses = {}
    for compact in (False, True):
        trainer = CompactTrainer(model, g, adam(1e-2), params=params)
        views = strategy_views(g, strategy, 2, seed=5, steps=4,
                               batch_nodes=16, clusters=clusters,
                               clusters_per_batch=2, halo_hops=1,
                               compact=compact)
        losses[compact] = trainer.fit(views, prefetch=False)["losses"]
    assert len(losses[True]) == 4
    assert np.allclose(losses[False], losses[True], atol=2e-4), losses


# ---------------------------------------------------------------------------
# active_counts + base-block cache (PR 6 satellites)
# ---------------------------------------------------------------------------


def test_active_counts_meta_fast_path_matches_scan():
    g = _g(35)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    for strategy in ("mini", "cluster"):
        v = strategy_views(g, strategy, 2, seed=0, batch_nodes=16,
                           clusters=clusters,
                           clusters_per_batch=2).build(0).copy_masks()
        fast = v.active_counts()
        # hand-built view without the recorded meta keys -> mask scan
        stripped = GraphView(v.graph, v.K, v.strategy, v.node_active,
                             v.edge_active, v.loss_mask, {})
        assert stripped.active_counts() == fast
    # None masks (the global view) fall back to graph totals
    gv = GraphView(g, 2, "global", None, None,
                   np.ones(g.num_nodes, np.float32), {})
    c = gv.active_counts()
    assert c["active_nodes"] == g.num_nodes
    assert c["active_edges"] == g.num_edges


def test_base_block_cached_and_masks_stamped():
    from repro.graph.csr import base_block
    g = _g(36)
    b1 = base_block(g, gcn_norm=True)
    assert base_block(g, gcn_norm=True) is b1        # cached per graph
    assert base_block(g, gcn_norm=False) is not b1   # keyed on flags
    v = strategy_views(g, "mini", 2, seed=0, batch_nodes=8).build(0)
    blk = v.as_block()
    # strategy-invariant arrays are shared, not rebuilt per view
    assert blk.x is b1.x and blk.src is b1.src
    assert blk.edge_weight is b1.edge_weight
    # per-view masks are stamped onto the shallow copy
    assert blk.node_active is v.node_active
    assert blk.loss_mask is not b1.loss_mask
    assert np.array_equal(blk.loss_mask, (v.loss_mask > 0))
