"""Vectorized view-construction engine (repro.core.views + the vectorized
``bfs_layers``): parity against the loop/recompute oracles, buffer-ring
reuse, neighbor-cap sampling semantics, and ViewStream index stability.

The hypothesis sweep lives in test_strategies_properties.py (dev extra).
"""
import numpy as np
import pytest

from repro.core.clustering import (cluster_members, hash_clusters,
                                   label_propagation_clusters)
from repro.core.strategies import (cluster_batch_views, mini_batch_views,
                                   strategy_views)
from repro.core.subgraph import (bfs_layers, bfs_layers_loop,
                                 khop_subgraph_view)
from repro.core.views import (ClusterViewCache, ClusterViewStream,
                              GlobalViewStream, MiniBatchViewStream,
                              ViewBuilder, cluster_view_recompute)
from repro.graph import sbm_graph


def _g(seed=0, n=300):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8, p_in=0.05,
                     p_out=0.005, seed=seed)


def _assert_hops_equal(a, b):
    assert len(a[0]) == len(b[0])
    for ha, hb in zip(a[0], b[0]):
        assert ha.dtype == hb.dtype
        assert np.array_equal(ha, hb)
    assert np.array_equal(a[1], b[1])   # visited


# ---------------------------------------------------------------------------
# vectorized bfs_layers == per-node loop oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("depth", [1, 2, 4])
def test_bfs_parity(seed, depth):
    g = _g(seed)
    rng = np.random.default_rng(seed)
    targets = rng.choice(g.num_nodes, size=12, replace=False)
    _assert_hops_equal(bfs_layers(g, targets, depth),
                       bfs_layers_loop(g, targets, depth))


def test_bfs_parity_edge_cases():
    g = _g(3)
    # empty target set
    empty = np.zeros(0, np.int64)
    _assert_hops_equal(bfs_layers(g, empty, 3), bfs_layers_loop(g, empty, 3))
    # disconnected targets: a node with no in-edges stalls the frontier
    indeg = g.in_degree()
    isolated = np.where(indeg == 0)[0]
    targets = (isolated[:2] if len(isolated)
               else np.array([int(np.argmin(indeg))]))
    _assert_hops_equal(bfs_layers(g, targets, 3),
                       bfs_layers_loop(g, targets, 3))
    # duplicated targets collapse identically
    dup = np.array([5, 5, 7, 7, 7, 9])
    _assert_hops_equal(bfs_layers(g, dup, 2), bfs_layers_loop(g, dup, 2))


def test_khop_masks_parity_loop_vs_vectorized():
    g = _g(4)
    targets = np.random.default_rng(0).choice(g.num_nodes, 20, replace=False)
    for K in (1, 2, 3):
        a = khop_subgraph_view(g, targets, K)
        b = khop_subgraph_view(g, targets, K, _bfs=bfs_layers_loop)
        for x, y in zip(a, b):
            assert np.array_equal(x, y)


# ---------------------------------------------------------------------------
# neighbor-cap sampling
# ---------------------------------------------------------------------------


def test_neighbor_cap_requires_rng():
    """The old bare ``assert rng is not None`` vanished under python -O;
    both implementations now raise ValueError up front."""
    g = _g(5)
    with pytest.raises(ValueError, match="Generator"):
        bfs_layers(g, np.arange(4), 2, neighbor_cap=3)
    with pytest.raises(ValueError, match="Generator"):
        bfs_layers_loop(g, np.arange(4), 2, neighbor_cap=3)
    with pytest.raises(ValueError, match="Generator"):
        khop_subgraph_view(g, np.arange(4), 2, neighbor_cap=3)


def test_neighbor_cap_semantics():
    g = _g(6)
    targets = np.arange(6)
    full_hops, full_visited = bfs_layers(g, targets, 2)
    capped_hops, capped_visited = bfs_layers(
        g, targets, 2, neighbor_cap=2, rng=np.random.default_rng(0))
    # capped exploration is a subset of the full BFS
    assert np.all(full_visited[capped_visited])
    for hc, hf in zip(capped_hops, full_hops):
        assert np.all(np.isin(hc, hf))
    # a cap at/above the max in-degree is a no-op (bit-exact with full)
    big = int(g.in_degree().max())
    relaxed = bfs_layers(g, targets, 2, neighbor_cap=big,
                         rng=np.random.default_rng(1))
    _assert_hops_equal(relaxed, (full_hops, full_visited))
    # same seed -> same sample (the vectorized draw is deterministic)
    a = bfs_layers(g, targets, 2, neighbor_cap=2,
                   rng=np.random.default_rng(7))
    b = bfs_layers(g, targets, 2, neighbor_cap=2,
                   rng=np.random.default_rng(7))
    _assert_hops_equal(a, b)


def test_neighbor_cap_bounds_per_node_fanin():
    """Each frontier node contributes at most ``cap`` in-neighbors: hop 1
    from a single target can never exceed cap new nodes."""
    g = _g(7)
    deg = g.in_degree()
    u = int(np.argmax(deg))
    assert deg[u] > 3
    hops, _ = bfs_layers(g, np.array([u]), 1, neighbor_cap=3,
                         rng=np.random.default_rng(0))
    # hop set includes the target itself
    assert len(hops[1]) <= 1 + 3


# ---------------------------------------------------------------------------
# ViewBuilder: parity + buffer-ring reuse
# ---------------------------------------------------------------------------


def test_builder_khop_parity_and_ring_reuse():
    g = _g(8)
    vb = ViewBuilder(g, 2, slots=2)
    buffer_ids = set()
    for seed in range(5):
        t = np.random.default_rng(seed).choice(g.num_nodes, 16,
                                               replace=False)
        na, ea, lm, _ = khop_subgraph_view(g, t, 2)
        v = vb.khop_view(t)
        assert np.array_equal(v.node_active, na)
        assert np.array_equal(v.edge_active, ea)
        assert np.array_equal(v.loss_mask, lm)
        buffer_ids.add(id(v.node_active))
    # no fresh (K, N) allocations: the ring's 2 slots were reused
    assert len(buffer_ids) == 2
    assert vb.builds == 5


@pytest.mark.parametrize("halo", [0, 1, 2])
def test_cluster_cache_parity(halo):
    g = _g(9)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    cache = ClusterViewCache(g, clusters, halo)
    vb = ViewBuilder(g, 2)
    train = g.train_mask
    rng = np.random.default_rng(halo)
    for _ in range(5):
        chosen = rng.choice(cache.num_clusters, size=3, replace=False)
        member, active, loss = cluster_view_recompute(g, clusters, chosen,
                                                      halo, train)
        v = vb.cluster_view(chosen, cache, train)
        assert np.array_equal(
            v.node_active,
            np.broadcast_to(active.astype(np.float32),
                            (2, g.num_nodes)))
        assert np.array_equal(
            v.edge_active,
            np.broadcast_to((active[g.src] & active[g.dst])
                            .astype(np.float32), (2, g.num_edges)))
        assert np.array_equal(v.loss_mask, loss)


def test_cluster_cache_loss_fallback_parity():
    """When no chosen member is labeled, loss falls back to all members —
    in both the cached and the recompute path."""
    g = _g(10, n=120)
    clusters = hash_clusters(g, 6, seed=0)
    no_train = np.zeros(g.num_nodes, bool)
    cache = ClusterViewCache(g, clusters, 1)
    vb = ViewBuilder(g, 2)
    chosen = np.array([0, 3])
    member, active, loss = cluster_view_recompute(g, clusters, chosen, 1,
                                                  no_train)
    v = vb.cluster_view(chosen, cache, no_train)
    assert loss.sum() > 0
    assert np.array_equal(v.loss_mask, loss)


def test_cluster_members_partition():
    labels = np.array([2, 0, 1, 0, 2, 2, 1])
    members = cluster_members(labels)
    assert [m.tolist() for m in members] == [[1, 3], [2, 6], [0, 4, 5]]


# ---------------------------------------------------------------------------
# ViewStreams: index-stable, order-independent construction
# ---------------------------------------------------------------------------


def test_mini_stream_order_independent():
    g = _g(11)
    s = MiniBatchViewStream(g, 2, batch_nodes=16, seed=3)
    out_of_order = [s.build(i).copy_masks() for i in (4, 0, 2)]
    in_order = {i: s.build(i).copy_masks() for i in range(5)}
    for v, i in zip(out_of_order, (4, 0, 2)):
        assert np.array_equal(v.node_active, in_order[i].node_active)
        assert np.array_equal(v.loss_mask, in_order[i].loss_mask)
    # iterator protocol walks the same indices and tracks the cursor
    it = iter(s)
    assert s.cursor == 0
    first = next(it).copy_masks()
    assert s.cursor == 1
    assert np.array_equal(first.edge_active, in_order[0].edge_active)
    s.seek(4)
    assert np.array_equal(next(it).copy_masks().loss_mask,
                          in_order[4].loss_mask)


def test_cluster_stream_order_independent():
    g = _g(12)
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    s = ClusterViewStream(g, 2, clusters, clusters_per_batch=2,
                          halo_hops=1, seed=5)
    a = s.build(9).copy_masks()
    b = s.build(9, ViewBuilder(g, 2)).copy_masks()  # private builder, same i
    assert np.array_equal(a.node_active, b.node_active)
    assert a.meta["clusters"] == b.meta["clusters"]


def test_stream_length_exhausts():
    g = _g(13)
    s = strategy_views(g, "mini", 2, seed=0, steps=3, batch_nodes=8)
    assert len(list(s)) == 3
    with pytest.raises(StopIteration):
        next(s)


def test_stream_iterator_yields_detached_views():
    """next() on a stream detaches from the builder ring (the legacy
    generator contract) — buffering several views is safe."""
    g = _g(20)
    s = strategy_views(g, "mini", 2, seed=0, batch_nodes=8)
    buffered = [next(s) for _ in range(4)]
    assert len({id(v.node_active) for v in buffered}) == 4
    replay = [s.build(i).copy_masks() for i in range(4)]
    for v, r in zip(buffered, replay):
        assert np.array_equal(v.node_active, r.node_active)
        assert np.array_equal(v.loss_mask, r.loss_mask)


def test_global_stream_is_static():
    g = _g(14)
    s = strategy_views(g, "global", 2)
    assert isinstance(s, GlobalViewStream)
    assert s.build(0) is s.build(99)
    assert s.make_builder() is None


def test_mini_stream_empty_labeled_raises():
    g = _g(15, n=60)
    g.train_mask = np.zeros(g.num_nodes, bool)
    with pytest.raises(ValueError, match="no labeled nodes"):
        MiniBatchViewStream(g, 2, batch_nodes=4)


# ---------------------------------------------------------------------------
# legacy generators keep their contract (detached arrays, same semantics)
# ---------------------------------------------------------------------------


def test_generators_yield_detached_views():
    g = _g(16)
    clusters = hash_clusters(g, 8, seed=0)
    mvs = list(mini_batch_views(g, 2, batch_nodes=8, seed=0, steps=3))
    assert len({id(v.node_active) for v in mvs}) == 3
    # earlier views are not clobbered by later builds
    snap = mvs[0].node_active.copy()
    assert np.array_equal(snap, mvs[0].node_active)
    cvs = list(cluster_batch_views(g, 2, clusters, clusters_per_batch=2,
                                   halo_hops=1, seed=0, steps=3))
    assert len({id(v.edge_active) for v in cvs}) == 3
