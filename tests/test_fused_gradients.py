"""jax.grad parity of the fused-gather CSC kernels vs the reference
backend, plus the fused-path memory contract (no (nb, L_pad, D)
pre-gather tensor in the jaxpr) and the mini-batch empty-labeled guard.

Covers what ISSUE 2 names: multi-head messages, empty segments, masked
edges, and D > 64 (the d-tiled segment-max grid axis), for every combine
mode the kernels accelerate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import combine
from repro.kernels.ops import (assert_pregather_free, build_csc_plan,
                               edge_softmax_op, segment_max_op,
                               segment_sum_op)

KERNEL_MODES = ["sum", "max", "softmax"]


def _problem(seed, E=400, N=90, H=2, D=8, mask_frac=0.3):
    """Messages with masked edges and a run of empty destinations."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, N // 2, E).astype(np.int32)   # empty tail
    msg = {"value": jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32),
           "logit": jnp.asarray(rng.normal(size=(E, H)) * 3, jnp.float32)}
    mask = jnp.asarray(rng.random(E) > mask_frac, jnp.float32)
    return msg, jnp.asarray(ids), ids, mask


@pytest.mark.parametrize("mode", KERNEL_MODES)
@pytest.mark.parametrize("H,D", [(1, 8), (3, 16), (2, 80)])
def test_fused_kernel_gradient_parity(mode, H, D):
    """csc grads == reference grads for multi-head messages, masked edges,
    empty segments; (2, 80) folds to lane width 160 > 64, exercising the
    d-tiled max kernel (both the max combine and softmax's max pass)."""
    msg, dst, ids_np, mask = _problem(seed=11 + H + D, H=H, D=D)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def loss(value, logit, backend, pln):
        out = combine(mode, {"value": value, "logit": logit}, dst, N, mask,
                      backend=backend, plan=pln)
        return jnp.sum(jnp.sin(out) * out)

    g_ref = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"],
                                           "reference", None)
    g_csc = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"],
                                           "csc", plan)
    for name, a, b in zip(("value", "logit"), g_ref, g_csc):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"{mode}/{name}")


@pytest.mark.parametrize("mode", KERNEL_MODES)
def test_fused_kernel_gradient_all_masked(mode):
    """Gradients through a fully masked combine stay finite (no NaN from
    empty-segment softmax or NEG max identities)."""
    msg, dst, ids_np, _ = _problem(seed=5, H=2, D=8)
    N = 90
    mask = jnp.zeros(ids_np.shape[0], jnp.float32)
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def loss(value, logit):
        out = combine(mode, {"value": value, "logit": logit}, dst, N, mask,
                      backend="csc", plan=plan)
        return jnp.sum(out * out)

    g = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"])
    for arr in g:
        assert np.all(np.isfinite(np.asarray(arr))), mode


# ---------------------------------------------------------------------------
# the fused-gather memory contract
# ---------------------------------------------------------------------------


def test_forward_jaxpr_has_no_pregather_tensor():
    """The tentpole claim: none of the kernel wrappers materializes the
    (nb, L_pad, D) pre-gathered message layout."""
    msg, dst, ids_np, mask = _problem(seed=3, H=2, D=8)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)
    flat = msg["value"].reshape(msg["value"].shape[0], -1)

    assert_pregather_free(
        jax.make_jaxpr(lambda d: segment_sum_op(d, plan))(flat), plan)
    assert_pregather_free(
        jax.make_jaxpr(lambda d: segment_max_op(d, plan))(flat), plan)
    assert_pregather_free(
        jax.make_jaxpr(lambda l, v: edge_softmax_op(l, v, plan))(
            msg["logit"], msg["value"]), plan)


def test_grad_jaxpr_has_no_pregather_tensor():
    """...and neither does the backward pass through the combine engine."""
    msg, dst, ids_np, mask = _problem(seed=4, H=2, D=8)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    for mode in KERNEL_MODES:
        def loss(value, logit):
            out = combine(mode, {"value": value, "logit": logit}, dst, N,
                          mask, backend="csc", plan=plan)
            return jnp.sum(out * out)

        jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1)))(
            msg["value"], msg["logit"])
        assert_pregather_free(jaxpr, plan)


def test_assert_pregather_free_catches_materialization():
    """The assertion helper itself must flag a pre-gathered layout."""
    msg, dst, ids_np, mask = _problem(seed=6, H=1, D=8)
    plan = build_csc_plan(ids_np, 90, block_n=32, block_e=64)
    flat = msg["value"].reshape(msg["value"].shape[0], -1)

    def pregather(d):
        return jnp.concatenate([d, jnp.zeros((1, 8), d.dtype)])[
            jnp.asarray(plan.gather_idx)]

    with pytest.raises(AssertionError, match="pre-gather"):
        assert_pregather_free(jax.make_jaxpr(pregather)(flat), plan)

    # the 2-D *float* layout (the old edge-softmax gathered logits) must
    # be flagged too, while the int32 plan arrays themselves are allowed
    def pregather_logits(l):
        return jnp.concatenate([l, jnp.full((1,), -1.0, l.dtype)])[
            jnp.asarray(plan.gather_idx)]

    logits = flat[:, 0]
    with pytest.raises(AssertionError, match="pre-gather"):
        assert_pregather_free(jax.make_jaxpr(pregather_logits)(logits),
                              plan)


# ---------------------------------------------------------------------------
# mini-batch strategy guard
# ---------------------------------------------------------------------------


def test_mini_batch_views_empty_labeled_set_raises():
    """A graph whose train_mask selects nothing must fail loudly instead
    of yielding empty (zero-target) views forever."""
    from repro.core.strategies import mini_batch_views
    from repro.graph import sbm_graph

    g = sbm_graph(num_nodes=40, num_classes=2, feature_dim=4,
                  p_in=0.1, p_out=0.02, seed=0)
    g.train_mask = np.zeros(g.num_nodes, bool)
    with pytest.raises(ValueError, match="no labeled"):
        next(mini_batch_views(g, 2, batch_nodes=4))
