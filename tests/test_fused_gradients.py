"""jax.grad parity of the fused-gather CSC kernels vs the reference
backend, plus the fused-path memory contracts:

- no (nb, L_pad, D) pre-gather tensor in the jaxpr (forward AND backward)
- no reference segment scatter / g[segment_ids] backward gather on the
  csc path (the fused backward kernels of kernels/backward.py), asserted
  via ``assert_sum_stage_fused`` on value_and_grad jaxprs

Covers multi-head messages, empty segments, masked edges, and D > 64
(the d-tiled grid axes), for every combine mode the kernels accelerate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import combine
from repro.kernels.ops import (assert_pregather_free,
                               assert_sum_stage_fused, build_csc_plan,
                               count_segment_scatters, edge_softmax_op,
                               segment_max_op, segment_sum_op)

KERNEL_MODES = ["sum", "max", "softmax"]


def _problem(seed, E=400, N=90, H=2, D=8, mask_frac=0.3):
    """Messages with masked edges and a run of empty destinations."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, N // 2, E).astype(np.int32)   # empty tail
    msg = {"value": jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32),
           "logit": jnp.asarray(rng.normal(size=(E, H)) * 3, jnp.float32)}
    mask = jnp.asarray(rng.random(E) > mask_frac, jnp.float32)
    return msg, jnp.asarray(ids), ids, mask


@pytest.mark.parametrize("mode", KERNEL_MODES)
@pytest.mark.parametrize("H,D", [(1, 8), (3, 16), (2, 80)])
def test_fused_kernel_gradient_parity(mode, H, D):
    """csc grads == reference grads for multi-head messages, masked edges,
    empty segments; (2, 80) folds to lane width 160 > 64, exercising the
    d-tiled max kernel (both the max combine and softmax's max pass)."""
    msg, dst, ids_np, mask = _problem(seed=11 + H + D, H=H, D=D)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def loss(value, logit, backend, pln):
        out = combine(mode, {"value": value, "logit": logit}, dst, N, mask,
                      backend=backend, plan=pln)
        return jnp.sum(jnp.sin(out) * out)

    g_ref = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"],
                                           "reference", None)
    g_csc = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"],
                                           "csc", plan)
    for name, a, b in zip(("value", "logit"), g_ref, g_csc):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"{mode}/{name}")


@pytest.mark.parametrize("mode", KERNEL_MODES)
def test_fused_kernel_gradient_all_masked(mode):
    """Gradients through a fully masked combine stay finite (no NaN from
    empty-segment softmax or NEG max identities)."""
    msg, dst, ids_np, _ = _problem(seed=5, H=2, D=8)
    N = 90
    mask = jnp.zeros(ids_np.shape[0], jnp.float32)
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def loss(value, logit):
        out = combine(mode, {"value": value, "logit": logit}, dst, N, mask,
                      backend="csc", plan=plan)
        return jnp.sum(out * out)

    g = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"])
    for arr in g:
        assert np.all(np.isfinite(np.asarray(arr))), mode


# ---------------------------------------------------------------------------
# the fused-gather memory contract
# ---------------------------------------------------------------------------


def test_forward_jaxpr_has_no_pregather_tensor():
    """The tentpole claim: none of the kernel wrappers materializes the
    (nb, L_pad, D) pre-gathered message layout."""
    msg, dst, ids_np, mask = _problem(seed=3, H=2, D=8)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)
    flat = msg["value"].reshape(msg["value"].shape[0], -1)

    assert_pregather_free(
        jax.make_jaxpr(lambda d: segment_sum_op(d, plan))(flat), plan)
    assert_pregather_free(
        jax.make_jaxpr(lambda d: segment_max_op(d, plan))(flat), plan)
    assert_pregather_free(
        jax.make_jaxpr(lambda l, v: edge_softmax_op(l, v, plan))(
            msg["logit"], msg["value"]), plan)


def test_grad_jaxpr_has_no_pregather_tensor():
    """...and neither does the backward pass through the combine engine."""
    msg, dst, ids_np, mask = _problem(seed=4, H=2, D=8)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    for mode in KERNEL_MODES:
        def loss(value, logit):
            out = combine(mode, {"value": value, "logit": logit}, dst, N,
                          mask, backend="csc", plan=plan)
            return jnp.sum(out * out)

        jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1)))(
            msg["value"], msg["logit"])
        assert_pregather_free(jaxpr, plan)


@pytest.mark.parametrize("mode", KERNEL_MODES + ["mean"])
@pytest.mark.parametrize("H,D", [(2, 8), (2, 80)])
def test_value_and_grad_jaxpr_backward_contract(mode, H, D):
    """The full fused contract of the tentpole: the value_and_grad jaxpr
    of the csc combine path contains no (nb, L_pad, ...) float tensor, no
    reference segment scatter, and no g[segment_ids] backward gather —
    the backward runs through the Pallas kernels, not reference math.
    (2, 80) folds to lane width 160 > the d-tile caps of both the
    forward max and the backward gather kernels."""
    msg, dst, ids_np, mask = _problem(seed=13 + H + D, H=H, D=D)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def loss(value, logit):
        out = combine(mode, {"value": value, "logit": logit}, dst, N,
                      mask, backend="csc", plan=plan)
        return jnp.sum(jnp.sin(out) * out)

    jaxpr = jax.make_jaxpr(jax.value_and_grad(loss, argnums=(0, 1)))(
        msg["value"], msg["logit"])
    assert_sum_stage_fused(jaxpr, plan)


def test_backward_contract_ignores_in_kernel_gathers():
    """Regression: when E == block_e the kernels' own on-chip block
    gathers have edge-sized outputs; the contract must skip pallas
    bodies rather than flag them as reference fallbacks."""
    rng = np.random.default_rng(17)
    E, N, H, D = 64, 32, 2, 8
    ids = rng.integers(0, N, E).astype(np.int32)
    dst = jnp.asarray(ids)
    msg = {"value": jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32),
           "logit": jnp.asarray(rng.normal(size=(E, H)), jnp.float32)}
    mask = jnp.ones(E, jnp.float32)
    plan = build_csc_plan(ids, N, block_n=32, block_e=64)
    assert plan.num_edges == plan.block_e

    for mode in KERNEL_MODES:
        def loss(value, logit):
            out = combine(mode, {"value": value, "logit": logit}, dst, N,
                          mask, backend="csc", plan=plan)
            return jnp.sum(out * out)

        jaxpr = jax.make_jaxpr(jax.value_and_grad(loss, argnums=(0, 1)))(
            msg["value"], msg["logit"])
        assert_sum_stage_fused(jaxpr, plan)


def test_backward_contract_catches_reference_fallback():
    """assert_sum_stage_fused must flag the reference path (which runs
    segment scatters and, under grad, the g[segment_ids] gather)."""
    msg, dst, ids_np, mask = _problem(seed=14, H=2, D=8)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def ref_loss(value, logit):
        out = combine("sum", {"value": value, "logit": logit}, dst, N,
                      mask, backend="reference")
        return jnp.sum(out * out)

    jaxpr = jax.make_jaxpr(jax.value_and_grad(ref_loss, argnums=(0,)))(
        msg["value"], msg["logit"])
    assert count_segment_scatters(jaxpr, plan) > 0
    with pytest.raises(AssertionError, match="reference"):
        assert_sum_stage_fused(jaxpr, plan)


@pytest.mark.parametrize("model_name,heads", [("gcn", 1), ("gat", 2)])
def test_model_value_and_grad_pregather_free_and_fewer_scatters(
        model_name, heads):
    """End-to-end train-step certificate: value_and_grad of the block
    loss on the csc path stays pre-gather-free, and its segment-scatter
    count sits strictly below the reference backend's (the only
    remaining edge-axis scatters are the NN-Gather transposes, which
    both backends share — the Sum-stage fallbacks are gone)."""
    import dataclasses

    from repro.config import GNNConfig
    from repro.core.mpgnn import loss_block
    from repro.core.strategies import global_batch_view
    from repro.graph import sbm_graph
    from repro.models import make_gnn

    g = sbm_graph(num_nodes=150, num_classes=3, feature_dim=8,
                  p_in=0.06, p_out=0.02, seed=3).add_self_loops()
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=8,
                    num_classes=3, feature_dim=8, num_heads=heads)
    model_ref = make_gnn(cfg)
    model_csc = dataclasses.replace(model_ref, aggregate_backend="csc")
    params = model_ref.init(jax.random.PRNGKey(0), 8)
    view = global_batch_view(g, 2)
    gcn_norm = model_name == "gcn"
    block_csc = view.as_block(gcn_norm=gcn_norm, csc_plan=True)
    block_ref = view.as_block(gcn_norm=gcn_norm)
    plan = block_csc.csc_plan

    jaxpr_csc = jax.make_jaxpr(jax.value_and_grad(
        lambda p: loss_block(model_csc, p, block_csc)))(params)
    jaxpr_ref = jax.make_jaxpr(jax.value_and_grad(
        lambda p: loss_block(model_ref, p, block_ref)))(params)
    assert_pregather_free(jaxpr_csc, plan)
    n_csc = count_segment_scatters(jaxpr_csc, plan)
    n_ref = count_segment_scatters(jaxpr_ref, plan)
    assert n_csc < n_ref, (n_csc, n_ref)


def test_assert_pregather_free_catches_materialization():
    """The assertion helper itself must flag a pre-gathered layout."""
    msg, dst, ids_np, mask = _problem(seed=6, H=1, D=8)
    plan = build_csc_plan(ids_np, 90, block_n=32, block_e=64)
    flat = msg["value"].reshape(msg["value"].shape[0], -1)

    def pregather(d):
        return jnp.concatenate([d, jnp.zeros((1, 8), d.dtype)])[
            jnp.asarray(plan.gather_idx)]

    with pytest.raises(AssertionError, match="pre-gather"):
        assert_pregather_free(jax.make_jaxpr(pregather)(flat), plan)

    # the 2-D *float* layout (the old edge-softmax gathered logits) must
    # be flagged too, while the int32 plan arrays themselves are allowed
    def pregather_logits(l):
        return jnp.concatenate([l, jnp.full((1,), -1.0, l.dtype)])[
            jnp.asarray(plan.gather_idx)]

    logits = flat[:, 0]
    with pytest.raises(AssertionError, match="pre-gather"):
        assert_pregather_free(jax.make_jaxpr(pregather_logits)(logits),
                              plan)


# ---------------------------------------------------------------------------
# mini-batch strategy guard
# ---------------------------------------------------------------------------


def test_mini_batch_views_empty_labeled_set_raises():
    """A graph whose train_mask selects nothing must fail loudly instead
    of yielding empty (zero-target) views forever."""
    from repro.core.strategies import mini_batch_views
    from repro.graph import sbm_graph

    g = sbm_graph(num_nodes=40, num_classes=2, feature_dim=4,
                  p_in=0.1, p_out=0.02, seed=0)
    g.train_mask = np.zeros(g.num_nodes, bool)
    with pytest.raises(ValueError, match="no labeled"):
        next(mini_batch_views(g, 2, batch_nodes=4))
