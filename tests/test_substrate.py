"""Substrate tests: optimizers, checkpointing, data pipeline, NN layers.

The hypothesis property sweep lives in test_substrate_properties.py
(guarded by ``pytest.importorskip`` — hypothesis is a dev-only extra).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint, latest_step
from repro.data import SyntheticLMDataset
from repro.nn.attention import apply_rope, attention_apply, attention_init
from repro.nn.layers import (layernorm_apply, layernorm_init, rmsnorm_apply,
                             rmsnorm_init, softmax_cross_entropy)
from repro.optim import adam, adamw, sgd, warmup_cosine_schedule


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1, momentum=0.9),
                                      lambda: adam(0.1),
                                      lambda: adamw(0.1)])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    f = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(200):
        grads = jax.grad(f)(params)
        params, state = opt.update(grads, state, params)
    assert float(f(params)) < 1e-3


def test_adamw_decays_without_gradient():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    zero = {"w": jnp.zeros(1)}
    for _ in range(20):
        params, state = opt.update(zero, state, params)
    assert float(params["w"][0]) < 10.0


def test_warmup_cosine_schedule_shape():
    s = warmup_cosine_schedule(1.0, warmup=10, total_steps=100)
    assert float(s(0)) < 0.11
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(99)) < float(s(50)) < float(s(10))


def test_grad_clip():
    from repro.optim import clip_by_global_norm
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                       "layers": [{"b": np.ones(2)}, {"b": np.zeros(2)}]},
            "step": np.asarray(7)}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 9, tree)
    assert latest_step(d) == 9
    back = load_checkpoint(d, 7)
    assert np.array_equal(back["params"]["w"], tree["params"]["w"])
    assert isinstance(back["params"]["layers"], list)
    np.testing.assert_array_equal(back["params"]["layers"][0]["b"],
                                  np.ones(2))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_has_learnable_structure():
    """Planted n-gram structure: successor bigrams occur far more often
    than chance."""
    ds = SyntheticLMDataset(vocab_size=256, seq_len=512, global_batch=8,
                            seed=0)
    b = ds.batch(0)
    toks = b["tokens"]
    follows = 0
    for row in toks:
        follows += np.mean(ds._succ[row[:-1]] == row[1:])
    assert follows / len(toks) > 0.3     # ~0.5 planted vs ~1/256 chance


# ---------------------------------------------------------------------------
# NN layers
# ---------------------------------------------------------------------------


def test_rope_preserves_norm_and_relative_position():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(k)k'> depends only on p-k
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]))
        kr = apply_rope(k, jnp.asarray([[pk]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot(5, 3) - dot(12, 10)) < 1e-4


def test_norms_normalize():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 10 + 3
    y = rmsnorm_apply(rmsnorm_init(32), x)
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
    z = layernorm_apply(layernorm_init(32), x)
    np.testing.assert_allclose(np.mean(np.asarray(z), -1), 0.0, atol=1e-4)


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    labels = jnp.asarray([0, 1])
    got = float(softmax_cross_entropy(logits, labels))
    p = jax.nn.log_softmax(logits)
    want = -float(p[0, 0] + p[1, 1]) / 2
    assert abs(got - want) < 1e-6


def test_sliding_window_attention_masks_old_tokens():
    p = attention_init(jax.random.PRNGKey(0), 32, 2, 2, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
    pos = jnp.arange(12)[None]
    full = attention_apply(p, x, num_heads=2, num_kv_heads=2, head_dim=16,
                           positions=pos)
    sw = attention_apply(p, x, num_heads=2, num_kv_heads=2, head_dim=16,
                         positions=pos, sliding_window=4)
    # first 4 tokens see identical context; later ones differ
    np.testing.assert_allclose(np.asarray(full)[:, :4],
                               np.asarray(sw)[:, :4], atol=1e-5)
    assert np.abs(np.asarray(full)[:, 8:] - np.asarray(sw)[:, 8:]).max() \
        > 1e-4
