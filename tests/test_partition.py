"""Distributed graph representation invariants (paper §4.1).

The hypothesis property sweep lives in test_partition_properties.py
(guarded by ``pytest.importorskip`` — hypothesis is a dev-only extra).
"""
import numpy as np
import pytest

from repro.core.partition import build_partitions, partition_stats
from repro.graph import sbm_graph, powerlaw_graph


def _graph(seed, n=120):
    return sbm_graph(num_nodes=n, num_classes=3, feature_dim=8,
                     p_in=0.06, p_out=0.02, seed=seed)


def test_replica_factor_ordering():
    """On skewed graphs vertex-cut replicates more nodes than 1D-edge but
    balances edges better (the trade-off in §5.4)."""
    g = powerlaw_graph(num_nodes=2000, avg_degree=8, seed=0)
    s1 = partition_stats(build_partitions(g, 8, method="1d_src"))
    s2 = partition_stats(build_partitions(g, 8, method="vertex_cut"))
    assert s2["replica_factor"] >= s1["replica_factor"] * 0.9
    assert s2["edge_balance"] <= s1["edge_balance"] + 0.5


def test_mirror_is_never_owner():
    g = _graph(1)
    sg = build_partitions(g, 4)
    plan = sg.plan
    for p in range(4):
        valid = plan.mirror_mask[p] > 0
        mids = plan.mirrors[p][valid]
        assert np.all(plan.owner[mids] != p)
