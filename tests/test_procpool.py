"""Process-pool sampler service (PR 10): supervision and recovery.

The contract under test extends PR 8's to real OS processes: a view is
pure in ``(seed, i)``, so a sampler process SIGKILLed mid-build, hung
without heartbeats, or handing back a corrupted shared-memory slot must
all recover into a loss trajectory **bit-identical** to the fault-free
(and to the thread-mode) run — and a clean ``close()`` must leave zero
child processes behind.
"""
import multiprocessing
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.config import GNNConfig
from repro.core.strategies import strategy_views
from repro.core.trainer import CompactTrainer
from repro.graph import sbm_graph
from repro.models import make_gnn
from repro.optim import adam
from repro.runtime import (FaultInjector, FaultPolicy,
                           FaultRetriesExceeded, ProcessViewService,
                           StreamPrefetcher, shared_memory_available)
from repro.runtime import procpool

# no real sleeping between retries
FAST = dict(backoff_base=0.0, backoff_cap=0.0, jitter=0.0)

pytestmark = pytest.mark.skipif(
    not shared_memory_available(),
    reason="multiprocessing.shared_memory unavailable on this platform")


def _graph(n=120, seed=0):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8,
                     p_in=0.06, p_out=0.006, seed=seed).add_self_loops()


@pytest.fixture(scope="module")
def g():
    return _graph()


def _trainer(g, **kw):
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)
    return CompactTrainer(make_gnn(cfg), g, adam(1e-2), seed=0, **kw)


def _views(g, compact=True, seed=0):
    return strategy_views(g, "mini", K=2, seed=seed, batch_nodes=24,
                          compact=compact)


def _fit(g, steps=6, mode="thread", workers=2, plan=None, policy_kw=None,
         hang_seconds=0.5, **kw):
    tkw = {}
    if plan is not None:
        tkw["fault_policy"] = FaultPolicy(**{**FAST, **(policy_kw or {})})
        tkw["injector"] = FaultInjector(plan, seed=0,
                                        hang_seconds=hang_seconds)
    tr = _trainer(g, **tkw)
    out = tr.fit(_views(g), steps=steps, prefetch_workers=workers,
                 prefetch_mode=mode, **kw)
    return tr, out


def _no_children():
    # reap any zombies first, then require an empty nursery
    deadline = time.monotonic() + 5.0
    while multiprocessing.active_children() and time.monotonic() < deadline:
        time.sleep(0.05)
    return multiprocessing.active_children() == []


# ---------------------------------------------------------------------------
# service-level parity (no trainer in the loop)
# ---------------------------------------------------------------------------


def test_service_emits_bit_identical_views(g):
    def run(cls, workers):
        stream = _views(g)
        it = cls(stream, lambda v: v, 6, workers=workers)
        try:
            return list(it)
        finally:
            it.close()

    ref = run(StreamPrefetcher, 1)
    for workers in (1, 4):
        got = run(ProcessViewService, workers)
        assert len(got) == len(ref)
        for va, vb in zip(ref, got):
            for f in ("nodes", "hop_offsets", "src_local", "dst_local",
                      "edge_ids", "loss_local"):
                assert np.array_equal(getattr(va, f), getattr(vb, f)), f
    assert _no_children()


def test_service_cursor_tracks_emission(g):
    stream = _views(g)
    it = ProcessViewService(stream, lambda v: v, 5, workers=2)
    try:
        assert stream.cursor == 0
        next(it)
        assert stream.cursor == 1   # cursor counts *emitted* views only
        next(it)
        assert stream.cursor == 2
    finally:
        it.close()
    assert _no_children()


# ---------------------------------------------------------------------------
# trainer matrix: every (mode, workers) cell bit-identical
# ---------------------------------------------------------------------------


def test_mode_worker_matrix_bit_identical(g):
    _, ref = _fit(g, mode="thread", workers=1)
    for mode in ("thread", "process"):
        for workers in (1, 4):
            tr, out = _fit(g, mode=mode, workers=workers)
            assert out["losses"] == ref["losses"], (mode, workers)
            tr.assert_compiled_per_bucket()
    assert _no_children()


# ---------------------------------------------------------------------------
# fault recovery: kill -9, hang, corrupt — all invisible in the stream
# ---------------------------------------------------------------------------


def test_proc_kill_recovers_bit_identical(g):
    _, ref = _fit(g)
    tr, out = _fit(g, mode="process", plan={"proc_kill": {1}})
    assert out["losses"] == ref["losses"]
    assert any(e.get("stage") == "proc_kill" for e in out["events"])
    tr.assert_compiled_per_bucket()
    assert _no_children()


def test_proc_hang_watchdog_respawns(g):
    _, ref = _fit(g)
    # the child sleeps 30s WITHOUT heartbeats; the claim-age watchdog
    # must kill + respawn it well before the sleep would end
    t0 = time.monotonic()
    tr, out = _fit(g, mode="process", plan={"proc_hang": {1}},
                   hang_seconds=30.0,
                   policy_kw={"worker_heartbeat_s": 0.6})
    elapsed = time.monotonic() - t0
    assert out["losses"] == ref["losses"]
    assert any(e.get("stage") == "proc_hang" for e in out["events"])
    assert elapsed < 25.0, "watchdog waited the hang out instead of killing"
    tr.assert_compiled_per_bucket()
    assert _no_children()


def test_slot_corruption_detected_and_rebuilt(g):
    _, ref = _fit(g)
    tr, out = _fit(g, mode="process", plan={"slot_corrupt": {1}})
    assert out["losses"] == ref["losses"]
    corrupt = [e for e in out["events"]
               if e.get("stage") == "slot_corrupt"]
    assert corrupt and corrupt[0]["view"] == 1
    assert "crc" in corrupt[0]["error"]
    tr.assert_compiled_per_bucket()
    assert _no_children()


def test_respawn_cap_exceeded_raises_typed(g):
    with pytest.raises(FaultRetriesExceeded):
        _fit(g, mode="process", plan={"proc_kill": {0, 1, 2}},
             policy_kw={"max_proc_respawns": 1})
    assert _no_children()


def test_thread_mode_analogs_fire_and_recover(g):
    # the same process-fault plan drives StreamPrefetcher's in-process
    # analogs, so one chaos plan covers both prefetch modes
    _, ref = _fit(g)
    for plan in ({"proc_kill": {1}}, {"proc_hang": {1}},
                 {"slot_corrupt": {1}}):
        tr = _trainer(g, fault_policy=FaultPolicy(**FAST),
                      injector=FaultInjector(plan, seed=0,
                                             hang_seconds=0.2))
        out = tr.fit(_views(g), steps=6, prefetch_workers=2,
                     prefetch_mode="thread")
        assert out["losses"] == ref["losses"], plan
        assert tr.runtime.injector.total_fired() > 0, plan


# ---------------------------------------------------------------------------
# degradation + argument validation
# ---------------------------------------------------------------------------


def test_degrades_to_threads_with_one_warning(g, monkeypatch):
    _, ref = _fit(g)
    monkeypatch.setattr(procpool, "shared_memory_available",
                        lambda: False)
    monkeypatch.setattr(procpool, "_DEGRADE_WARNED", False)
    with pytest.warns(RuntimeWarning, match="degrading"):
        _, out = _fit(g, mode="process")
    assert out["losses"] == ref["losses"]
    # second degrade is silent (one-time warning)
    _, out2 = _fit(g, mode="process")
    assert out2["losses"] == ref["losses"]


def test_unknown_prefetch_mode_rejected(g):
    with pytest.raises(ValueError, match="prefetch_mode"):
        _fit(g, mode="fibers")


# ---------------------------------------------------------------------------
# SIGTERM mid-fit: checkpoint saved, samplers drained, nonzero exit
# ---------------------------------------------------------------------------


def _spawn_helper_pids():
    """Pids of alive multiprocessing spawn children system-wide (the
    orphan detector for the signal test)."""
    pids = set()
    for pid in os.listdir("/proc"):
        if not pid.isdigit():
            continue
        try:
            with open(f"/proc/{pid}/cmdline", "rb") as f:
                cmd = f.read()
        except OSError:
            continue
        if b"multiprocessing.spawn" in cmd:
            pids.add(int(pid))
    return pids


@pytest.mark.slow
def test_sigterm_saves_checkpoint_and_resumes(tmp_path):
    ckpt = tmp_path / "ckpt"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    args = [sys.executable, "-m", "repro.launch.train", "gnn",
            "--dataset", "cora", "--strategy", "mini", "--compact",
            "--steps", "5000", "--prefetch-mode", "process",
            "--prefetch-workers", "2",
            "--checkpoint-dir", str(ckpt), "--checkpoint-every", "5"]
    orphans_before = _spawn_helper_pids()
    proc = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    try:
        # wait for training to be genuinely underway (first checkpoint)
        deadline = time.monotonic() + 240
        while time.monotonic() < deadline:
            if ckpt.is_dir() and any(ckpt.glob("step_*.npz")):
                break
            if proc.poll() is not None:
                break
            time.sleep(0.2)
        assert proc.poll() is None, (
            f"run ended before first checkpoint:\n{proc.stderr.read()}")
        proc.send_signal(signal.SIGTERM)
        out, err = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 128 + signal.SIGTERM, (out, err)
    assert "interrupted by signal" in err
    # the final checkpoint is valid and resumable
    from repro.checkpoint import load_checkpoint
    state = load_checkpoint(str(ckpt))
    assert state["params"] is not None
    # no orphaned sampler processes survived the interrupt
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        leaked = _spawn_helper_pids() - orphans_before
        if not leaked:
            break
        time.sleep(0.2)
    assert not leaked, f"orphaned sampler processes: {leaked}"
    # and a --resume run picks the work back up and exits cleanly
    resumed = subprocess.run(
        args[:args.index("--steps") + 1] + ["3"]
        + args[args.index("--steps") + 2:] + ["--resume"],
        env=env, capture_output=True, text=True, timeout=240)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    assert "final test acc" in resumed.stdout
