"""The paper's backward equivalence (App. A.2): the explicitly-scheduled
reverse NN-TGAR passes produce the same gradients as jax.grad."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GNNConfig
from repro.core.autodiff import explicit_loss_and_grad
from repro.core.mpgnn import loss_block
from repro.core.strategies import global_batch_view, mini_batch_views
from repro.graph import make_dataset
from repro.models import make_gnn


@pytest.mark.parametrize("model_name", ["gcn", "sage", "gat", "gat_e"])
def test_explicit_backward_equals_autodiff(model_name):
    if model_name == "gat_e":
        g = make_dataset("alipay_like", num_nodes=500, seed=0)
        edim = g.edge_features.shape[1]
        nc = 2
    else:
        g = make_dataset("cora", seed=0).add_self_loops()
        edim, nc = 0, 7
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=16,
                    num_classes=nc, feature_dim=g.node_features.shape[1],
                    num_heads=4, edge_feature_dim=edim)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    for view in [global_batch_view(g, 2),
                 next(mini_batch_views(g, 2, batch_nodes=16, seed=1))]:
        block = view.as_block(gcn_norm=(model_name == "gcn"))
        loss, grads = explicit_loss_and_grad(model, params, block)
        ref_l, ref_g = jax.value_and_grad(
            lambda p: loss_block(model, p, block))(params)
        assert abs(float(loss) - float(ref_l)) < 1e-6
        for a, b in zip(jax.tree_util.tree_leaves(grads),
                        jax.tree_util.tree_leaves(ref_g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


def test_gradient_flows_along_reversed_edges():
    """App. A.2's structural claim: in a directed chain a->b, the loss on b
    produces a gradient on a's features (via the reversed edge), and the
    loss on a produces NO gradient on b (no edge b->a)."""
    from repro.graph.csr import Graph, build_block
    feats = np.eye(2, 4, dtype=np.float32)
    g = Graph(np.array([0], np.int32), np.array([1], np.int32), 2,
              feats, np.array([0, 1], np.int32))
    cfg = GNNConfig(model="gcn", num_layers=1, hidden_dim=4, num_classes=2,
                    feature_dim=4)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), 4)

    def loss_on(node):
        block = build_block(g, loss_mask=np.arange(2) == node,
                            gcn_norm=False)

        def f(x):
            blk = block
            blk.x = x
            return loss_block(model, params, blk)
        return jax.grad(f)(jnp.asarray(feats))

    g_b = np.asarray(loss_on(1))      # loss on b: grad must reach a
    assert np.abs(g_b[0]).max() > 0
    g_a = np.asarray(loss_on(0))      # loss on a: no in-edges => no grads
    assert np.abs(g_a).max() == 0
