"""Auto-FSDP sharding rules: every produced spec must divide its dim, and
the roofline helpers must parse HLO collectives correctly."""
import numpy as np
import pytest

from repro.config import ASSIGNED_ARCHS, get_arch_config
from repro.launch.roofline import parse_collective_bytes, model_flops
from repro.config import INPUT_SHAPES


def _axis_sizes(mesh_shape):
    return dict(mesh_shape)


class FakeMesh:
    """Shape-only stand-in (no devices needed for spec derivation)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def _check_specs(shapes, specs, mesh):
    import jax
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, (leaf.shape, spec)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divisible(arch, multi):
    import jax
    from repro.arch import build_model
    from repro.launch import sharding as sh

    cfg = get_arch_config(arch)
    model = build_model(cfg)
    shapes = model.param_shapes()
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi
                    else {"data": 16, "model": 16})
    specs = sh.param_specs(shapes, mesh, ("data",))
    _check_specs(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["qwen3-4b", "jamba-1.5-large-398b",
                                  "minicpm3-4b", "rwkv6-1.6b"])
def test_cache_specs_divisible(arch):
    import jax
    from repro.arch import build_model
    from repro.launch import sharding as sh

    cfg = get_arch_config(arch)
    model = build_model(cfg)
    mesh = FakeMesh({"data": 16, "model": 16})
    shapes = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = sh.cache_specs(shapes, mesh, ("data",))
    _check_specs(shapes, specs, mesh)


def test_parse_collective_bytes():
    hlo = """
  %ag = bf16[16,4096,2560]{2,1,0} all-gather(bf16[1,4096,2560]{2,1,0} %x), replica_groups=...
  %ar = f32[100,10] all-reduce(f32[100,10] %y), to_apply=%sum
  %rs.1 = f32[4,10]{1,0} reduce-scatter(f32[64,10]{1,0} %z), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %w)
  %nothing = f32[2,2] add(f32[2,2] %a, f32[2,2] %b)
"""
    got = parse_collective_bytes(hlo)
    assert got["all-gather"] == 16 * 4096 * 2560 * 2
    assert got["all-reduce"] == 100 * 10 * 4
    assert got["reduce-scatter"] == 64 * 10 * 4        # operand bigger
    assert got["collective-permute"] == 8 * 4
    assert got["total"] == sum(got[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_model_flops_scaling():
    cfg = get_arch_config("qwen3-4b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"], 256)
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"], 256)
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"], 256)
    # train is 3x prefill per token; decode is tiny
    assert tr / (256 * 4096) == pytest.approx(3 * pf / (32 * 32768),
                                              rel=1e-6)
    assert de < pf / 1000
    # MoE active < total flops basis
    moe = get_arch_config("dbrx-132b")
    assert moe.active_param_count() < 0.5 * moe.param_count()
