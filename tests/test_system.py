"""End-to-end system behaviour: the paper's headline claims, scaled down.

- All three training strategies learn the same task to comparable accuracy
  (Tables 2/3 analogue).
- Cluster-batch touches fewer nodes per step than mini-batch on a
  community-structured graph (the redundancy argument of §2.3/Fig 9).
- The unified implementation serves inference from the same engine.
- LM end-to-end: a reduced assigned arch trains on the synthetic corpus
  and beats the unigram entropy floor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters
from repro.core.mpgnn import accuracy_block, forward_block, loss_block
from repro.core.strategies import (cluster_batch_views, global_batch_view,
                                   mini_batch_views)
from repro.graph import make_dataset
from repro.models import make_gnn
from repro.optim import adam


def _train(model, params, views, steps, opt, gcn_norm):
    state = opt.init(params)

    @jax.jit
    def step(params, state, block):
        loss, grads = jax.value_and_grad(
            lambda p: loss_block(model, p, block))(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    for i in range(steps):
        view = next(views)
        params, state, loss = step(params, state,
                                   view.as_block(gcn_norm=gcn_norm))
    return params, float(loss)


@pytest.mark.slow
def test_three_strategies_reach_comparable_accuracy():
    g = make_dataset("cora", seed=0).add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=32, num_classes=7,
                    feature_dim=g.node_features.shape[1])
    model = make_gnn(cfg)
    test_mask = g.test_mask.astype(np.float32)
    accs = {}
    for strategy in ("global", "mini", "cluster"):
        params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
        if strategy == "global":
            views = iter(lambda: global_batch_view(g, 2), None)
            steps = 60
        elif strategy == "mini":
            views = mini_batch_views(g, 2, batch_nodes=64, seed=0)
            steps = 120
        else:
            cl = label_propagation_clusters(g, max_cluster_size=150,
                                            iters=3, seed=0)
            views = cluster_batch_views(g, 2, cl, clusters_per_batch=30,
                                        halo_hops=1, seed=0)
            steps = 120
        params, _ = _train(model, params, views, steps, adam(1e-2),
                           gcn_norm=True)
        gb = global_batch_view(g, 2).as_block()
        accs[strategy] = float(accuracy_block(model, params, gb,
                                              mask=test_mask))
    assert all(a > 0.7 for a in accs.values()), accs
    assert max(accs.values()) - min(accs.values()) < 0.2, accs


def test_cluster_batch_reduces_redundancy():
    """On a community graph, cluster-batch touches fewer unique nodes per
    target than random mini-batching (paper §2.3's motivation)."""
    g = make_dataset("reddit_like", num_nodes=1500, seed=0)
    cl = label_propagation_clusters(g, max_cluster_size=200, iters=4,
                                    seed=0)
    mb = next(mini_batch_views(g, 2, batch_nodes=60, seed=1))
    cb = next(cluster_batch_views(g, 2, cl, clusters_per_batch=2,
                                  halo_hops=0, seed=1))
    mb_cost = mb.active_counts()["active_nodes"] / max(
        mb.active_counts()["targets"], 1)
    cb_cost = cb.active_counts()["active_nodes"] / max(
        cb.active_counts()["targets"], 1)
    assert cb_cost < mb_cost, (cb_cost, mb_cost)


def test_unified_training_and_inference():
    """§4.3: inference runs through the same forward implementation —
    predictions from forward_block match training-time logits."""
    g = make_dataset("cora", seed=0).add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16, num_classes=7,
                    feature_dim=g.node_features.shape[1])
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    gb = global_batch_view(g, 2).as_block()
    logits = forward_block(model, params, gb)
    assert logits.shape == (gb.num_nodes_padded, 7)
    # mini-batch view of one target reproduces the same logits row
    mv = next(mini_batch_views(g, 2, batch_nodes=1, seed=3))
    target = int(np.where(mv.loss_mask > 0)[0][0])
    logits_mb = forward_block(model, params, mv.as_block())
    np.testing.assert_allclose(np.asarray(logits)[target],
                               np.asarray(logits_mb)[target],
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_lm_end_to_end_learns():
    from repro.launch.train import train_lm
    out = train_lm("qwen3-4b", steps=60, batch=8, seq=64, reduced=True)
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_gnn_trainer_cli_path():
    from repro.launch.train import train_gnn
    out = train_gnn("cora", "gcn", "global", steps=30, hidden=32,
                    eval_every=29)
    assert out["final_acc"] > 0.6


@pytest.mark.slow
def test_checkpoint_resume_exact(tmp_path):
    """Checkpoint/restore gives bit-identical continued training."""
    from repro.checkpoint import save_checkpoint, load_checkpoint
    from repro.data import SyntheticLMDataset
    from repro.arch import build_model
    from repro.config import get_arch_config
    from repro.optim import adamw
    import repro.arch.model as am
    am.LOSS_CHUNK = 16

    cfg = get_arch_config("qwen3-4b").reduced().replace(
        dtype="float32", vocab_size=256)
    model = build_model(cfg, remat=False)
    opt = adamw(1e-3)
    ds = SyntheticLMDataset(cfg.vocab_size, 32, 4, seed=0)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    for i in range(4):
        b = ds.batch(i)
        params, state, _ = step(params, state,
                                {k: jnp.asarray(v) for k, v in b.items()})
        if i == 1:
            save_checkpoint(str(tmp_path), 2, {"p": params, "s": state})
    ck = load_checkpoint(str(tmp_path), 2)
    p2, s2 = ck["p"], ck["s"]
    for i in range(2, 4):
        b = ds.batch(i)
        p2, s2, _ = step(p2, s2, {k: jnp.asarray(v) for k, v in b.items()})
    for a, b_ in zip(jax.tree_util.tree_leaves(params),
                     jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-6, atol=1e-6)
