"""Parity of the Sum-stage aggregation backends: "csc" (Pallas CSC-blocked
kernels) == "reference" (jnp segment ops) across every registered combine
mode, on the raw combine engine, the single-block forward path, and the
4-way distributed engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.config import GNNConfig
from repro.core.aggregate import COMBINE_SPECS, combine, get_backend
from repro.core.mpgnn import loss_block
from repro.core.strategies import global_batch_view, mini_batch_views
from repro.graph import sbm_graph
from repro.kernels.ops import build_csc_plan
from repro.models import make_gnn

MODES = sorted(COMBINE_SPECS)


def _edge_problem(seed, E=400, N=90, H=2, D=8, mask_frac=0.3,
                  empty_tail=True):
    """Random messages with masked edges and (when empty_tail) a run of
    destinations receiving no edges at all."""
    rng = np.random.default_rng(seed)
    hi = N // 2 if empty_tail else N
    ids = rng.integers(0, hi, E).astype(np.int32)
    msg = {"value": jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32),
           "logit": jnp.asarray(rng.normal(size=(E, H)) * 3, jnp.float32)}
    mask = jnp.asarray(rng.random(E) > mask_frac, jnp.float32)
    return msg, jnp.asarray(ids), ids, mask


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("H,D", [(1, 16), (2, 8)])
def test_combine_parity(mode, H, D):
    # deterministic seed (str hash is randomized per process)
    seed = sum(mode.encode()) * 7 + H
    msg, dst, ids_np, mask = _edge_problem(seed=seed, H=H, D=D)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)
    ref = combine(mode, msg, dst, N, mask, backend="reference")
    csc = combine(mode, msg, dst, N, mask, backend="csc", plan=plan)
    np.testing.assert_allclose(np.asarray(csc), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", MODES)
def test_combine_gradient_parity(mode):
    msg, dst, ids_np, mask = _edge_problem(seed=7, H=2, D=8)
    N = 90
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)

    def loss(value, logit, backend, plan):
        out = combine(mode, {"value": value, "logit": logit}, dst, N, mask,
                      backend=backend, plan=plan)
        return jnp.sum(out * out)

    g_ref = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"],
                                           "reference", None)
    g_csc = jax.grad(loss, argnums=(0, 1))(msg["value"], msg["logit"],
                                           "csc", plan)
    for a, b in zip(g_ref, g_csc):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5)


def test_combine_all_edges_masked():
    """Fully masked input: every mode must produce exact zeros (and not
    NaN/inf from empty-segment softmax or -inf max identities)."""
    msg, dst, ids_np, _ = _edge_problem(seed=3, H=2, D=4)
    N = 90
    mask = jnp.zeros(ids_np.shape[0], jnp.float32)
    plan = build_csc_plan(ids_np, N, block_n=32, block_e=64)
    for mode in MODES:
        for be, pl_ in (("reference", None), ("csc", plan)):
            out = np.asarray(combine(mode, msg, dst, N, mask, backend=be,
                                     plan=pl_))
            assert np.all(np.isfinite(out)), (mode, be)
            np.testing.assert_allclose(out, 0.0, atol=1e-6,
                                       err_msg=f"{mode}/{be}")


def test_unknown_mode_and_backend_raise():
    msg, dst, ids_np, mask = _edge_problem(seed=1, H=1, D=4)
    with pytest.raises(ValueError, match="combine mode"):
        combine("median", msg, dst, 90, mask)
    with pytest.raises(ValueError, match="backend"):
        get_backend("cuda")


@pytest.mark.parametrize("model_name,heads",
                         [("gcn", 1), ("sage", 1), ("sage_max", 1),
                          ("gat", 2)])
def test_block_forward_backend_parity(model_name, heads):
    """loss + grads of the single-block path agree between backends, on
    global-batch and (masked-edge) mini-batch views."""
    g = sbm_graph(num_nodes=200, num_classes=3, feature_dim=16,
                  p_in=0.05, p_out=0.01, seed=0).add_self_loops()
    gcn_norm = model_name == "gcn"
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=8,
                    num_classes=3, feature_dim=16, num_heads=heads)
    model_ref = make_gnn(cfg)
    model_csc = dataclasses.replace(model_ref, aggregate_backend="csc")
    params = model_ref.init(jax.random.PRNGKey(0), 16)
    views = [global_batch_view(g, 2),
             next(mini_batch_views(g, 2, batch_nodes=12, seed=1))]
    for view in views:
        l_ref, g_ref = jax.value_and_grad(
            lambda p: loss_block(model_ref, p,
                                 view.as_block(gcn_norm=gcn_norm)))(params)
        l_csc, g_csc = jax.value_and_grad(
            lambda p: loss_block(model_csc, p,
                                 view.as_block(gcn_norm=gcn_norm,
                                               csc_plan=True)))(params)
        assert abs(float(l_ref) - float(l_csc)) < 1e-5, view.strategy
        err = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(g_ref),
            jax.tree_util.tree_leaves(g_csc)))
        assert err < 1e-5, (model_name, view.strategy, err)


def test_block_csc_plan_is_cached_and_reused():
    """The paper's reused-CSC-indexing claim: every view of one graph
    shares the same plan object."""
    g = sbm_graph(num_nodes=120, num_classes=3, feature_dim=8,
                  p_in=0.06, p_out=0.02, seed=4)
    b1 = global_batch_view(g, 2).as_block(csc_plan=True)
    b2 = next(mini_batch_views(g, 2, batch_nodes=10, seed=0)).as_block(
        csc_plan=True)
    assert b1.csc_plan is b2.csc_plan
    assert b1.csc_plan is g.csc_plan(b1.num_nodes_padded,
                                     b1.num_edges_padded)


_DISTRIBUTED = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.config import GNNConfig
from repro.core.mpgnn import loss_block
from repro.core.strategies import global_batch_view, mini_batch_views, \
    shard_view
from repro.core.partition import build_partitions
from repro.core.engine import HybridParallelEngine
from repro.graph import sbm_graph
from repro.models import make_gnn

g = sbm_graph(num_nodes=250, num_classes=3, feature_dim=16, p_in=0.05,
              p_out=0.01, seed=2).add_self_loops()
# one model per combine mode: sum (gcn), mean (sage), max (sage_max),
# softmax (gat, multi-head)
for model_name, heads in (("gcn", 1), ("sage", 1), ("sage_max", 1),
                          ("gat", 2)):
    gcn_norm = model_name == "gcn"
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=8,
                    num_classes=3, feature_dim=16, num_heads=heads,
                    aggregate_backend="csc")
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), 16)
    model_ref = dataclasses.replace(model, aggregate_backend="reference")
    sg = build_partitions(g, 4, gcn_norm=gcn_norm)
    eng = HybridParallelEngine(model, sg)
    assert "csc_gather" in eng._device_data    # kernels actually staged
    lg = eng.make_loss_and_grad()
    views = [global_batch_view(g, 2),
             next(mini_batch_views(g, 2, batch_nodes=24, seed=1))]
    for view in views:
        ref_l, ref_g = jax.value_and_grad(
            lambda p: loss_block(model_ref, p,
                                 view.as_block(gcn_norm=gcn_norm)))(params)
        loss, grads = lg(params, eng._device_data,
                         eng.stage_view(shard_view(sg.plan, view)))
        assert abs(float(ref_l) - float(loss)) < 1e-4, \
            (model_name, view.strategy, float(ref_l), float(loss))
        err = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(ref_g),
            jax.tree_util.tree_leaves(grads)))
        assert err < 1e-4, (model_name, view.strategy, err)
    print(model_name, "ok")
print("ALL_OK")
"""


@pytest.mark.slow
def test_distributed_csc_backend_parity_4workers():
    """P=4 hybrid-parallel engine with the csc backend == single-block
    reference, for all four combine modes, global and mini-batch views."""
    out = run_with_devices(_DISTRIBUTED, n_devices=4, timeout=900)
    assert "ALL_OK" in out


_DISTRIBUTED_GRAD = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.config import GNNConfig
from repro.core.strategies import global_batch_view, shard_view
from repro.core.partition import build_partitions
from repro.core.engine import HybridParallelEngine
from repro.graph import sbm_graph
from repro.models import make_gnn

# jax.grad THROUGH the P=4 engine, csc backend vs reference backend —
# the sharded grad path runs the fused backward kernels (plans threaded
# into the custom_vjp residuals), the reference engine runs jnp segment
# ops; gradients of the replicated params must match per combine mode.
g = sbm_graph(num_nodes=220, num_classes=3, feature_dim=12, p_in=0.05,
              p_out=0.01, seed=5).add_self_loops()
for model_name, heads in (("gcn", 1), ("sage", 1), ("sage_max", 1),
                          ("gat", 2)):
    gcn_norm = model_name == "gcn"
    cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=8,
                    num_classes=3, feature_dim=12, num_heads=heads,
                    aggregate_backend="csc")
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(1), 12)
    model_ref = dataclasses.replace(model, aggregate_backend="reference")
    sg = build_partitions(g, 4, gcn_norm=gcn_norm)
    eng_csc = HybridParallelEngine(model, sg)
    eng_ref = HybridParallelEngine(model_ref, sg)
    assert "csc_dst" in eng_csc._device_data   # backward plans staged
    view = eng_csc.stage_view(shard_view(sg.plan, global_batch_view(g, 2)))
    l_csc, g_csc = eng_csc.make_loss_and_grad()(
        params, eng_csc._device_data, view)
    l_ref, g_ref = eng_ref.make_loss_and_grad()(
        params, eng_ref._device_data, view)
    assert abs(float(l_csc) - float(l_ref)) < 1e-4, (model_name,)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(g_csc),
        jax.tree_util.tree_leaves(g_ref)))
    assert err < 1e-4, (model_name, err)
    print(model_name, "grads ok", err)
print("GRADS_OK")
"""


@pytest.mark.slow
def test_distributed_grad_parity_csc_vs_reference_4workers():
    """jax.grad through the P=4 engine: csc-backend gradients (fused
    Pallas backward kernels under shard_map) == reference-backend
    gradients for sum/mean/max/softmax."""
    out = run_with_devices(_DISTRIBUTED_GRAD, n_devices=4, timeout=900)
    assert "GRADS_OK" in out
