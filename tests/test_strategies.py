"""Training-strategy semantics (GraphView unification, §4.2/§2.3).

The hypothesis property sweep lives in test_strategies_properties.py
(guarded by ``pytest.importorskip`` — hypothesis is a dev-only extra).
"""
import numpy as np
import pytest

from repro.core.clustering import (hash_clusters, label_propagation_clusters,
                                   louvain_clusters, modularity)
from repro.core.strategies import (cluster_batch_views, global_batch_view,
                                   mini_batch_views)
from repro.core.subgraph import khop_subgraph_view, subgraph_size_stats
from repro.graph import sbm_graph


def _g(seed=0, n=300):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8, p_in=0.05,
                     p_out=0.005, seed=seed)


def test_global_view_covers_everything():
    g = _g()
    v = global_batch_view(g, 2)
    assert v.node_active is None and v.edge_active is None
    assert v.loss_mask.sum() == g.train_mask.sum()


def test_mini_batch_targets_subset_of_train():
    g = _g(1)
    for i, v in enumerate(mini_batch_views(g, 2, batch_nodes=16, seed=0,
                                           steps=5)):
        targets = np.where(v.loss_mask > 0)[0]
        assert np.all(g.train_mask[targets])
        assert len(targets) == 16
        # every active edge endpoint is active at some layer
        touched = v.node_active.max(axis=0) > 0
        eact = v.edge_active.max(axis=0) > 0
        assert np.all(touched[g.dst[eact]])


def test_active_sets_shrink_with_depth():
    """Layer-k active set (computing h^{k+1}) shrinks toward the targets
    (paper: 'minimal number of layers per node')."""
    g = _g(2)
    targets = np.arange(6)
    na, ea, lm, _ = khop_subgraph_view(g, targets, 3)
    sizes = [(na[k] > 0).sum() for k in range(3)]
    assert sizes[0] >= sizes[1] >= sizes[2]
    assert sizes[2] >= len(targets)


def test_neighbor_sampling_caps_fanin():
    g = _g(3)
    targets = np.arange(4)
    rng = np.random.default_rng(0)
    full = subgraph_size_stats(g, targets, 2)
    na, ea, _, visited = khop_subgraph_view(g, targets, 2, neighbor_cap=2,
                                            rng=rng)
    assert visited.sum() <= full["touched_nodes"]


def test_cluster_batch_respects_clusters():
    g = _g(4)
    clusters = hash_clusters(g, 10, seed=1)
    v = next(cluster_batch_views(g, 2, clusters, clusters_per_batch=2,
                                 halo_hops=0, seed=0))
    chosen = set(v.meta["clusters"])
    active = v.node_active[0] > 0
    assert set(np.unique(clusters[active])) <= chosen
    # all active edges internal to the active set
    eact = v.edge_active[0] > 0
    assert np.all(active[g.src[eact]]) and np.all(active[g.dst[eact]])


def test_cluster_halo_grows_active_set():
    g = _g(5)
    clusters = hash_clusters(g, 10, seed=2)
    v0 = next(cluster_batch_views(g, 2, clusters, 2, halo_hops=0, seed=3))
    v1 = next(cluster_batch_views(g, 2, clusters, 2, halo_hops=1, seed=3))
    v2 = next(cluster_batch_views(g, 2, clusters, 2, halo_hops=2, seed=3))
    a0 = (v0.node_active[0] > 0).sum()
    a1 = (v1.node_active[0] > 0).sum()
    a2 = (v2.node_active[0] > 0).sum()
    assert a0 <= a1 <= a2
    # loss is always restricted to cluster members
    assert np.array_equal(v0.loss_mask, v1.loss_mask)


def test_community_detection_beats_hash():
    """LPA/Louvain find the planted SBM communities; hashing doesn't
    (Table A1: cluster-batch needs community structure)."""
    g = _g(6, n=400)
    lpa = label_propagation_clusters(g, iters=6, seed=0)
    hsh = hash_clusters(g, int(lpa.max()) + 1, seed=0)
    assert modularity(g, lpa) > modularity(g, hsh) + 0.2
    lou = louvain_clusters(g, seed=0)
    assert modularity(g, lou) > modularity(g, hsh) + 0.2


def test_subgraph_explosion_stats():
    """Dense graphs: few targets touch a large graph fraction (paper §1's
    motivation for cluster-batch / hybrid parallelism)."""
    g = sbm_graph(num_nodes=400, num_classes=2, feature_dim=4, p_in=0.1,
                  p_out=0.05, seed=0)
    stats = subgraph_size_stats(g, np.arange(4), 2)
    assert stats["touched_frac"] > 0.5
