"""The repro.analysis subsystem (PR 7): rule registry, VMEM budget
checker, source lint, and the CLI gate.

Both acceptance directions are asserted here:

- every negative fixture (a pre-gathered step, a reference segment
  scatter, a backward gather, a full-graph aval in a compact step, an
  f64-promoting loss, a host transfer inside jit, a donation mismatch,
  an oversized-block kernel, a bare-assert module, a hot-path alloc)
  is flagged by its named rule;
- the real csc train/infer steps — all four combine modes, both
  trainers — and the shipped source tree produce zero findings.
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (ContractError, JaxprContext, RULES,
                            check_vmem, iter_kernel_stats, lint_source,
                            lint_tree, run_rules)
from repro.analysis.cli import (COMBINE_RULES, COMPACT_RULES, TRAIN_RULES,
                                Report, check_combine_modes,
                                check_compact_buckets, check_trainers,
                                run_analysis)
from repro.kernels.ops import build_csc_plan

SRC_ROOT = Path(__file__).resolve().parents[1] / "src" / "repro"


def _plan(E=96, N=40):
    rng = np.random.default_rng(0)
    ids = rng.integers(0, N, E).astype(np.int32)
    return ids, build_csc_plan(ids, N, block_n=16, block_e=32)


def _rule_ids(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# negative fixtures: each rule MUST flag its fixture by name
# ---------------------------------------------------------------------------


def test_pregather_fixture_flagged():
    ids, plan = _plan()
    data = jnp.ones((plan.num_edges, 8), jnp.float32)

    def pregathered(d):
        # the (nb, L_pad, D) float layout the fused kernels eliminated
        gathered = d[jnp.asarray(plan.gather_idx) % plan.num_edges]
        return jnp.sum(gathered)

    jx = jax.make_jaxpr(pregathered)(data)
    findings = run_rules(JaxprContext(jx, plan=plan),
                         ids=["jaxpr.pregather"])
    assert _rule_ids(findings) == {"jaxpr.pregather"}


def test_segment_scatter_fixture_flagged():
    ids, plan = _plan()
    data = jnp.ones((plan.num_edges, 8), jnp.float32)
    jx = jax.make_jaxpr(
        lambda d: jax.ops.segment_sum(d, jnp.asarray(ids),
                                      plan.num_segments))(data)
    findings = run_rules(JaxprContext(jx, plan=plan),
                         ids=["jaxpr.segment-scatter"])
    assert _rule_ids(findings) == {"jaxpr.segment-scatter"}


def test_backward_gather_fixture_flagged():
    ids, plan = _plan()
    g = jnp.ones((plan.num_segments, 8), jnp.float32)
    jx = jax.make_jaxpr(lambda g_: g_[jnp.asarray(ids)])(g)
    findings = run_rules(JaxprContext(jx, plan=plan),
                         ids=["jaxpr.backward-gather"])
    assert _rule_ids(findings) == {"jaxpr.backward-gather"}


def test_full_graph_aval_fixture_flagged():
    N, E = 500, 2000
    x = jnp.ones((N, 16), jnp.float32)
    jx = jax.make_jaxpr(lambda x: jnp.tanh(x).sum())(x)
    findings = run_rules(JaxprContext(jx, graph_shape=(N, E)),
                         ids=["jaxpr.full-graph-aval"])
    assert _rule_ids(findings) == {"jaxpr.full-graph-aval"}
    # an exempted (colliding) dim is not flagged
    assert run_rules(JaxprContext(jx, graph_shape=(N, E),
                                  exempt_dims=(N,)),
                     ids=["jaxpr.full-graph-aval"]) == []
    # integer avals of graph width (plan indices) are allowed
    jx_int = jax.make_jaxpr(lambda i: i + 1)(jnp.ones(N, jnp.int32))
    assert run_rules(JaxprContext(jx_int, graph_shape=(N, E)),
                     ids=["jaxpr.full-graph-aval"]) == []


def test_f64_fixture_flagged():
    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(lambda x: x * np.float64(2.0))(
            jnp.ones(4, jnp.float64))
    findings = run_rules(JaxprContext(jx), ids=["jaxpr.f64-promotion"])
    assert _rule_ids(findings) == {"jaxpr.f64-promotion"}


def test_host_transfer_fixture_flagged():
    def step(x):
        y = jax.device_put(x)
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct(x.shape, x.dtype),
            y)

    jx = jax.make_jaxpr(jax.jit(step))(jnp.ones(4, jnp.float32))
    findings = run_rules(JaxprContext(jx), ids=["jaxpr.host-transfer"])
    assert _rule_ids(findings) == {"jaxpr.host-transfer"}
    assert len(findings) >= 2        # device_put AND the callback


def test_donation_fixture_flagged():
    f = jax.jit(lambda a, b: a + b, donate_argnums=(1,))
    jx = jax.make_jaxpr(f)(jnp.ones(4), jnp.ones(4))
    # expecting 2 donated but only 1 is: mismatch finding
    findings = run_rules(JaxprContext(jx, expect_donated=2),
                         ids=["jaxpr.donation"])
    assert _rule_ids(findings) == {"jaxpr.donation"}
    # the true count verifies clean
    assert run_rules(JaxprContext(jx, expect_donated=1),
                     ids=["jaxpr.donation"]) == []
    # a trace without any pjit equation cannot be verified -> finding
    jx_plain = jax.make_jaxpr(lambda a: a + 1)(jnp.ones(4))
    assert _rule_ids(run_rules(JaxprContext(jx_plain, expect_donated=1),
                               ids=["jaxpr.donation"])) == {"jaxpr.donation"}


def test_vmem_budget_fixture_flagged():
    """segment_max_csc at the documented block geometry with an unsplit
    feature axis (block_d == d == 256) materializes a (BE, BN, BD) =
    (256, 128, 256) candidate tensor — 32 MiB, over the 16 MiB budget;
    the auto-tiled pick stays under it."""
    from repro.kernels.segment_sum import segment_max_csc
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 40, 96).astype(np.int32)
    plan = build_csc_plan(ids, 40, block_n=128, block_e=256)
    data = jnp.ones((plan.num_edges, 256), jnp.float32)
    jx = jax.make_jaxpr(lambda d: segment_max_csc(
        d, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_blocks, plan.block_n, plan.block_e, block_d=256,
        interpret=True))(data)
    findings = check_vmem(jx)
    assert _rule_ids(findings) == {"vmem.budget"}
    # the same launch passes at the default 16 MiB? not necessarily —
    # what matters is the auto-tiled geometry stays under it
    jx_auto = jax.make_jaxpr(lambda d: segment_max_csc(
        d, jnp.asarray(plan.gather_idx), jnp.asarray(plan.local_ids),
        plan.num_blocks, plan.block_n, plan.block_e,
        interpret=True))(data)
    assert check_vmem(jx_auto) == []
    # stats reconstruction is sane: every launch reports a grid and bytes
    stats = iter_kernel_stats(jx)
    assert stats and all(s.vmem_bytes > 0 and s.grid for s in stats)


def test_srclint_bare_assert_fixture_flagged():
    src = "def f(x):\n    assert x > 0\n    return x\n"
    findings = lint_source(src, "fixture.py")
    assert _rule_ids(findings) == {"src.bare-assert"}


def test_srclint_hot_path_fixtures_flagged():
    src = (
        "import numpy as np\n"
        "def hot(g, sel):\n"
        "    n = g.num_nodes\n"
        "    buf = np.zeros(n, bool)\n"
        "    mask = np.isin(np.arange(g.num_nodes), sel)\n"
        "    return buf, mask\n"
    )
    findings = lint_source(src, "fixture.py", hot={"hot"})
    assert _rule_ids(findings) == {"src.hot-full-graph-alloc",
                                   "src.hot-membership-scan"}
    # outside the hot set the same code is fine
    assert lint_source(src, "fixture.py", hot=set()) == []


def test_srclint_waiver():
    src = ("def f(x):\n"
           "    assert x > 0  # lint: waive=src.bare-assert\n"
           "    assert x < 9\n")
    findings = lint_source(src, "fixture.py")
    assert len(findings) == 1 and findings[0].location.endswith(":3")


def test_srclint_silent_except_flagged():
    src = ("def f():\n"
           "    try:\n"
           "        g()\n"
           "    except OSError:\n"
           "        pass\n"
           "    try:\n"
           "        g()\n"
           "    except Exception:\n"
           "        ...\n")
    findings = lint_source(src, "fixture.py")
    assert _rule_ids(findings) == {"src.silent-except"}
    assert len(findings) == 2
    # a handler that does anything with the error is fine
    ok = ("def f():\n"
          "    try:\n"
          "        g()\n"
          "    except OSError:\n"
          "        return None\n")
    assert lint_source(ok, "fixture.py") == []


def test_srclint_silent_except_waiver_on_pass_line():
    src = ("def f():\n"
           "    try:\n"
           "        g()\n"
           "    except OSError:\n"
           "        pass  # lint: waive=src.silent-except\n")
    assert lint_source(src, "fixture.py") == []


def test_srclint_unjoined_process_flagged():
    src = ("import multiprocessing as mp\n"
           "def launch(fn):\n"
           "    p = mp.Process(target=fn)\n"
           "    p.start()\n"
           "    return p\n")
    findings = lint_source(src, "fixture.py")
    assert _rule_ids(findings) == {"src.unjoined-process"}
    # any join/terminate/kill path anywhere in the file clears it
    supervised = src + ("def close(p):\n"
                        "    p.terminate()\n")
    assert lint_source(supervised, "fixture.py") == []
    joined = src + ("def wait(p):\n"
                    "    p.join()\n")
    assert lint_source(joined, "fixture.py") == []
    # bare-name Process() (from-import) is caught too
    bare = ("from multiprocessing import Process\n"
            "def launch(fn):\n"
            "    Process(target=fn).start()\n")
    assert _rule_ids(lint_source(bare, "fixture.py")) == {
        "src.unjoined-process"}


def test_srclint_unjoined_process_waiver():
    src = ("import multiprocessing as mp\n"
           "def launch(fn):\n"
           "    p = mp.Process(target=fn)  # lint: waive=src.unjoined-process\n"
           "    p.start()\n")
    assert lint_source(src, "fixture.py") == []


# ---------------------------------------------------------------------------
# zero findings on the real thing
# ---------------------------------------------------------------------------


def test_combine_modes_clean():
    """All four combine modes' value_and_grad jaxprs on the csc backend
    pass the full Sum-stage ruleset (incl. VMEM)."""
    report = Report(16 * 1024 * 1024)
    check_combine_modes(report)
    assert report.findings == []
    assert report.contexts == 4
    assert report.kernels        # pallas launches were actually walked


def test_trainer_steps_clean():
    """Every zoo model x backend train step + infer trace passes the
    step-hygiene rules (pregather, f64, host transfer, donation, VMEM)."""
    report = Report(16 * 1024 * 1024)
    check_trainers(report, full=False)
    assert report.findings == []
    assert report.contexts == 16      # 4 models x 2 backends x (step+infer)


def test_compact_trainer_steps_clean():
    """CompactTrainer bucketed steps honor the O(view) aval contract."""
    report = Report(16 * 1024 * 1024)
    check_compact_buckets(report, full=False)
    assert report.findings == []
    assert report.contexts >= 2


def test_srclint_tree_clean():
    assert lint_tree(SRC_ROOT) == []


def test_cli_strict_smoke(tmp_path):
    out = tmp_path / "BENCH_analysis.json"
    rc = run_analysis(strict=True, json_path=str(out),
                      out=lambda *a, **k: None)
    assert rc == 0
    import json
    report = json.loads(out.read_text())
    assert report["findings"] == []
    assert report["contexts_traced"] >= 24
    assert report["kernels"]


def test_cli_strict_fails_on_findings(tmp_path):
    """--strict exits nonzero when the lint root contains a violation."""
    bad = tmp_path / "pkg"
    bad.mkdir()
    (bad / "mod.py").write_text("def f(x):\n    assert x\n    return x\n")
    rc = run_analysis(strict=True, lint_root=str(bad),
                      out=lambda *a, **k: None)
    assert rc == 1


# ---------------------------------------------------------------------------
# registry + shims + satellites
# ---------------------------------------------------------------------------


def test_registry_is_complete():
    for rule_id in ("jaxpr.pregather", "jaxpr.segment-scatter",
                    "jaxpr.backward-gather", "jaxpr.full-graph-aval",
                    "jaxpr.f64-promotion", "jaxpr.host-transfer",
                    "jaxpr.donation", "vmem.budget"):
        assert rule_id in RULES, rule_id
        assert RULES[rule_id].description
    # the CLI rule subsets reference only registered rules
    for subset in (COMBINE_RULES, TRAIN_RULES, COMPACT_RULES):
        assert set(subset) <= set(RULES)


def test_jaxpr_walker_version_robust():
    """The walker's class collection works on this jax (satellite 1) and
    unwraps duck-typed jaxpr-likes."""
    from repro.analysis.jaxpr import (_CLOSED_TYPES, _JAXPR_TYPES,
                                      _as_jaxpr, jaxpr_eqns)
    assert _CLOSED_TYPES and _JAXPR_TYPES
    jx = jax.make_jaxpr(lambda x: x * 2 + 1)(jnp.ones(3))
    assert _as_jaxpr(jx) is jx.jaxpr
    assert len(list(jaxpr_eqns(jx))) >= 2

    class Ducky:     # a foreign ClosedJaxpr-alike
        def __init__(self, inner):
            self.jaxpr = inner

    assert _as_jaxpr(Ducky(jx.jaxpr)) is jx.jaxpr


def test_ops_shims_still_raise_assertionerror():
    """Legacy callers use pytest.raises(AssertionError): ContractError
    must satisfy them, with the historical message fragments."""
    from repro.kernels.ops import (assert_pregather_free,
                                   assert_sum_stage_fused)
    ids, plan = _plan()
    data = jnp.ones((plan.num_edges, 8), jnp.float32)
    jx = jax.make_jaxpr(
        lambda d: jax.ops.segment_sum(d, jnp.asarray(ids),
                                      plan.num_segments))(data)
    with pytest.raises(AssertionError, match="reference"):
        assert_sum_stage_fused(jx, plan)
    with pytest.raises(ContractError):
        assert_sum_stage_fused(jx, plan)
    jx_pre = jax.make_jaxpr(
        lambda d: d[jnp.asarray(plan.gather_idx) % plan.num_edges].sum())(
            data)
    with pytest.raises(AssertionError, match="pre-gather"):
        assert_pregather_free(jx_pre, plan)


def test_bare_assert_sweep_raises_valueerror():
    """The converted guards raise typed errors with messages (satellite
    2) — spot-check the kernel wrappers' preconditions."""
    from repro.kernels.ops import segment_sum_op
    ids, plan = _plan()
    with pytest.raises(ValueError, match="edge axis"):
        segment_sum_op(jnp.ones((plan.num_edges + 1, 4), jnp.float32),
                       plan)
    with pytest.raises(ValueError, match="l_pad"):
        build_csc_plan(ids, 40, block_n=16, block_e=32, l_pad=7)
