"""Hardened checkpoint store (PR 8): atomic checksummed writes, typed
corruption detection, newest-valid fallback, retention, tmp cleanup.

Every corruption mode the runtime's rollback path can meet — truncated
npz, missing manifest, flipped leaf bytes, a stale ``.tmp`` from a
crashed save — must surface as :class:`CheckpointCorruptError` (never a
bare ``zipfile``/``KeyError``), and the resume path must silently fall
back to the newest checkpoint that actually verifies.
"""
import json
import os
import zlib

import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, checkpoint_steps,
                              latest_step, load_checkpoint,
                              save_checkpoint, verify_checkpoint)


def _tree(step):
    return {"params": {"w": np.arange(6, dtype=np.float32) + step,
                       "b": np.zeros(3, np.float32)},
            "step": np.asarray(step, np.int64)}


def _path(d, step):
    return os.path.join(str(d), f"step_{step:08d}.npz")


# ---------------------------------------------------------------------------
# round-trip and format
# ---------------------------------------------------------------------------


def test_roundtrip_preserves_structure_and_values(tmp_path):
    tree = {"a": np.arange(4.0), "b": (np.ones(2), [np.zeros(1)]),
            "c": np.asarray(7)}
    save_checkpoint(str(tmp_path), 1, tree)
    got = load_checkpoint(str(tmp_path), 1)
    assert isinstance(got["b"], tuple) and isinstance(got["b"][1], list)
    assert np.array_equal(got["a"], tree["a"])
    assert np.array_equal(got["b"][0], tree["b"][0])
    assert int(got["c"]) == 7


def test_save_is_atomic_no_tmp_left_behind(tmp_path):
    p = save_checkpoint(str(tmp_path), 3, _tree(3))
    assert os.path.exists(p)
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []
    assert verify_checkpoint(p)


# ---------------------------------------------------------------------------
# corruption detection (all modes -> CheckpointCorruptError)
# ---------------------------------------------------------------------------


def test_truncated_npz_is_typed_corruption(tmp_path):
    p = save_checkpoint(str(tmp_path), 1, _tree(1))
    data = open(p, "rb").read()
    open(p, "wb").write(data[: len(data) // 2])
    with pytest.raises(CheckpointCorruptError, match="unreadable"):
        load_checkpoint(str(tmp_path), 1)
    assert not verify_checkpoint(p)


def test_not_a_zip_is_typed_corruption(tmp_path):
    p = _path(tmp_path, 2)
    open(p, "wb").write(b"this is not an npz at all")
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(str(tmp_path), 2)


def test_missing_manifest_is_typed_corruption(tmp_path):
    p = _path(tmp_path, 1)
    np.savez(open(p, "wb"), w=np.ones(3))   # npz without __manifest__
    with pytest.raises(CheckpointCorruptError, match="__manifest__"):
        load_checkpoint(str(tmp_path), 1)


def test_flipped_leaf_bytes_fail_checksum(tmp_path):
    """Rewrite the npz with one leaf's data changed but the original
    manifest: structurally valid, semantically corrupt — only the crc
    catches it."""
    p = save_checkpoint(str(tmp_path), 1, _tree(1))
    with np.load(p) as data:
        flat = {k: data[k] for k in data.files if k != "__manifest__"}
        manifest = bytes(data["__manifest__"])
    key = sorted(k for k in flat if k != "step")[0]
    flat[key] = flat[key] + 1.0   # silent bit-flip stand-in
    with open(p, "wb") as f:
        np.savez(f, __manifest__=np.frombuffer(manifest, dtype=np.uint8),
                 **flat)
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        load_checkpoint(str(tmp_path), 1)


def test_missing_leaf_vs_manifest_detected(tmp_path):
    p = save_checkpoint(str(tmp_path), 1, _tree(1))
    with np.load(p) as data:
        flat = {k: data[k] for k in data.files if k != "__manifest__"}
        manifest = bytes(data["__manifest__"])
    flat.pop(sorted(flat)[0])
    with open(p, "wb") as f:
        np.savez(f, __manifest__=np.frombuffer(manifest, dtype=np.uint8),
                 **flat)
    with pytest.raises(CheckpointCorruptError, match="missing"):
        load_checkpoint(str(tmp_path), 1)


def test_pre_hardening_bare_spec_manifest_still_loads(tmp_path):
    """Checkpoints written before the checksum format (manifest = bare
    spec) load without verification rather than erroring."""
    tree = {"w": np.arange(3.0)}
    spec = {"__kind__": "dict",
            "items": {"w": {"__kind__": "leaf"}}}
    p = _path(tmp_path, 9)
    with open(p, "wb") as f:
        np.savez(f, __manifest__=np.frombuffer(
            json.dumps(spec).encode(), dtype=np.uint8), w=tree["w"])
    got = load_checkpoint(str(tmp_path), 9)
    assert np.array_equal(got["w"], tree["w"])


# ---------------------------------------------------------------------------
# newest-valid fallback
# ---------------------------------------------------------------------------


def test_load_falls_back_to_previous_valid_step(tmp_path):
    for s in (1, 2, 3):
        save_checkpoint(str(tmp_path), s, _tree(s))
    p3 = _path(tmp_path, 3)
    open(p3, "wb").write(b"garbage")
    got = load_checkpoint(str(tmp_path))     # step=None: newest valid
    assert int(got["step"]) == 2
    # explicit step still raises
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(str(tmp_path), 3)


def test_latest_step_skips_corrupt_files(tmp_path):
    for s in (1, 2):
        save_checkpoint(str(tmp_path), s, _tree(s))
    open(_path(tmp_path, 2), "wb").write(b"junk")
    assert latest_step(str(tmp_path)) == 1
    assert latest_step(str(tmp_path), validate=False) == 2   # name scan
    open(_path(tmp_path, 1), "wb").write(b"junk")
    assert latest_step(str(tmp_path)) is None


def test_all_corrupt_raises_with_context(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(1))
    open(_path(tmp_path, 1), "wb").write(b"junk")
    with pytest.raises(CheckpointCorruptError, match="all corrupt"):
        load_checkpoint(str(tmp_path))


def test_empty_directory_raises_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path))
    assert latest_step(str(tmp_path)) is None
    assert checkpoint_steps(str(tmp_path)) == []


# ---------------------------------------------------------------------------
# tmp cleanup and retention
# ---------------------------------------------------------------------------


def test_stale_tmp_cleaned_on_next_save_and_never_loaded(tmp_path):
    stale = os.path.join(str(tmp_path), "step_00000007.npz.tmp")
    open(stale, "wb").write(b"half-written crash debris")
    save_checkpoint(str(tmp_path), 8, _tree(8))
    assert not os.path.exists(stale)
    # the stale tmp never shadowed a real step
    assert checkpoint_steps(str(tmp_path)) == [8]


def test_retention_keeps_newest_k(tmp_path):
    for s in range(1, 6):
        save_checkpoint(str(tmp_path), s, _tree(s), keep=3)
    assert checkpoint_steps(str(tmp_path)) == [3, 4, 5]
    assert int(load_checkpoint(str(tmp_path))["step"]) == 5


def test_keep_zero_retains_everything(tmp_path):
    for s in range(1, 4):
        save_checkpoint(str(tmp_path), s, _tree(s), keep=0)
    assert checkpoint_steps(str(tmp_path)) == [1, 2, 3]


def test_leaf_crc_matches_manifest(tmp_path):
    p = save_checkpoint(str(tmp_path), 1, _tree(1))
    with np.load(p) as data:
        manifest = json.loads(bytes(data["__manifest__"]).decode())
        for k, want in manifest["checksums"].items():
            got = zlib.crc32(
                np.ascontiguousarray(data[k]).tobytes()) & 0xFFFFFFFF
            assert got == int(want)


# ---------------------------------------------------------------------------
# Trainer.restore integration: corrupted latest -> previous valid step
# ---------------------------------------------------------------------------


def test_trainer_restore_falls_back_to_previous_valid(tmp_path):
    from repro.config import GNNConfig
    from repro.core.engine import HybridParallelEngine
    from repro.core.partition import build_partitions
    from repro.core.strategies import strategy_views
    from repro.core.trainer import Trainer
    from repro.graph import sbm_graph
    from repro.models import make_gnn
    from repro.optim import adam

    g = sbm_graph(num_nodes=120, num_classes=4, feature_dim=8,
                  p_in=0.05, p_out=0.005, seed=0).add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)

    def make():
        engine = HybridParallelEngine(make_gnn(cfg),
                                      build_partitions(g, 1))
        return Trainer(engine, adam(1e-2), seed=0)

    tr = make()
    tr.fit(strategy_views(g, "mini", K=2, seed=0, batch_nodes=24),
           steps=4, checkpoint_dir=str(tmp_path), checkpoint_every=2)
    assert checkpoint_steps(str(tmp_path)) == [2, 4]
    p4 = _path(tmp_path, 4)
    open(p4, "wb").write(open(p4, "rb").read()[:100])   # truncate

    tr2 = make()
    assert tr2.restore(str(tmp_path)) == 2   # fell back past step 4
    assert tr2.step_num == 2
