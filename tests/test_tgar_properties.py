"""Property-based Sum-stage invariants — needs hypothesis (dev extra).

Split out of test_tgar.py and guarded with ``pytest.importorskip`` so the
deterministic NN-TGAR tests run on clean environments.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.tgar import segment_softmax, segment_sum


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 60), st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
def test_segment_sum_permutation_invariant(n_seg, n_edges, seed):
    r = np.random.default_rng(seed)
    ids = r.integers(0, n_seg, n_edges)
    data = r.normal(size=(n_edges, 5)).astype(np.float32)
    out = segment_sum(jnp.asarray(data), jnp.asarray(ids), n_seg)
    perm = r.permutation(n_edges)
    out_p = segment_sum(jnp.asarray(data[perm]), jnp.asarray(ids[perm]),
                        n_seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 30), st.integers(1, 120), st.integers(0, 2 ** 31 - 1))
def test_segment_softmax_normalized(n_seg, n_edges, seed):
    r = np.random.default_rng(seed)
    ids = r.integers(0, n_seg, n_edges)
    logits = r.normal(size=(n_edges, 2)).astype(np.float32) * 5
    values = np.ones((n_edges, 2, 1), np.float32)
    mask = np.ones(n_edges, np.float32)
    out = segment_softmax(jnp.asarray(logits), jnp.asarray(values),
                          jnp.asarray(ids), n_seg, jnp.asarray(mask))
    # softmax weights sum to 1 => aggregating ones gives 1 per non-empty seg
    nonempty = np.bincount(ids, minlength=n_seg) > 0
    got = np.asarray(out)[nonempty, :, 0]
    np.testing.assert_allclose(got, 1.0, rtol=1e-4, atol=1e-4)
