"""Trainer contracts (PR 4): compiled-once stepping across strategy
switches, prefetch-pipeline ordering (identical results with prefetch
on/off), vectorized shard_view parity with the per-partition loop, and
checkpoint save/restore resuming mid-stream without a retrace.

The fast lane runs everything in-process on a 1-partition engine (the
single CPU device); the P=4 distributed sweep is a ``slow`` subprocess
test like the other engine suites.
"""
import itertools

import numpy as np
import pytest

from conftest import run_with_devices

from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters
from repro.core.engine import HybridParallelEngine
from repro.core.partition import build_partitions
from repro.core.strategies import (global_batch_view, shard_view,
                                   shard_view_loop, strategy_views)
from repro.core.trainer import RetraceError, Trainer
from repro.graph import sbm_graph
from repro.models import make_gnn
from repro.optim import adam


def _graph(n=220, seed=0):
    return sbm_graph(num_nodes=n, num_classes=4, feature_dim=8,
                     p_in=0.05, p_out=0.005, seed=seed).add_self_loops()


@pytest.fixture(scope="module")
def setup():
    g = _graph()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)
    engine = HybridParallelEngine(make_gnn(cfg), build_partitions(g, 1))
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    return g, engine, clusters


def _views(g, strategy, clusters, seed=0):
    return strategy_views(g, strategy, K=2, seed=seed, batch_nodes=24,
                          clusters=clusters, clusters_per_batch=2)


# ---------------------------------------------------------------------------
# vectorized shard_view == per-partition loop (multi-partition plan,
# no devices needed)
# ---------------------------------------------------------------------------


def test_shard_view_parity_all_strategies():
    g = _graph(seed=3)
    plan = build_partitions(g, 3).plan
    clusters = label_propagation_clusters(g, max_cluster_size=60, seed=0)
    for strategy in ("global", "mini", "cluster"):
        v = next(iter(_views(g, strategy, clusters, seed=5)))
        a, b = shard_view(plan, v), shard_view_loop(plan, v)
        assert set(a) == set(b)
        for k in a:
            assert a[k].shape == b[k].shape
            assert a[k].dtype == b[k].dtype
            assert np.array_equal(a[k], b[k]), (strategy, k)


def test_global_strategy_view_is_static():
    g = _graph(seed=4)
    it = strategy_views(g, "global", K=2)
    v1, v2 = next(it), next(it)
    assert v1 is v2   # the Trainer stages a static stream exactly once


# ---------------------------------------------------------------------------
# compiled-once contract
# ---------------------------------------------------------------------------


def test_compiled_once_across_strategy_switches(setup):
    g, engine, clusters = setup
    trainer = Trainer(engine, adam(1e-2), seed=0)
    for strategy in ("global", "mini", "cluster", "mini", "global"):
        trainer.fit(_views(g, strategy, clusters), steps=2)
    assert trainer.step_num == 10
    assert trainer.trace_counts["train_step"] == 1
    trainer.assert_compiled_once()


def test_assert_compiled_once_raises(setup):
    g, engine, clusters = setup
    trainer = Trainer(engine, adam(1e-2), seed=0)
    with pytest.raises(RetraceError):      # never stepped
        trainer.assert_compiled_once()
    trainer.fit(_views(g, "global", clusters), steps=1)
    trainer.assert_compiled_once()
    trainer.trace_counts["train_step"] = 2  # simulate a retrace
    with pytest.raises(RetraceError):
        trainer.assert_compiled_once()


# ---------------------------------------------------------------------------
# prefetch pipeline
# ---------------------------------------------------------------------------


def test_multi_stream_prefetch_deterministic(setup):
    """The acceptance contract of the view engine: loss trajectory with
    prefetch_workers=4 is bit-identical to workers=1 and to the
    no-prefetch path (per-index RNG streams + in-order emit)."""
    import jax
    g, engine, clusters = setup
    for strategy in ("mini", "cluster"):
        ref_losses, ref_params = None, None
        for kwargs in ({"prefetch": False},
                       {"prefetch": True, "prefetch_workers": 1},
                       {"prefetch": True, "prefetch_workers": 4}):
            trainer = Trainer(engine, adam(1e-2), seed=0)
            out = trainer.fit(_views(g, strategy, clusters, seed=13),
                              steps=6, **kwargs)
            if ref_losses is None:
                ref_losses, ref_params = out["losses"], trainer.params
                continue
            assert out["losses"] == ref_losses, (strategy, kwargs)
            for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                            jax.tree_util.tree_leaves(trainer.params)):
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_multi_stream_pool_emits_in_index_order(setup):
    """The pool path consumes the stream by index: the staged sequence
    equals sequential construction even with many workers racing."""
    g, engine, clusters = setup
    stream = _views(g, "mini", clusters, seed=21)
    # copy inside the loop: builder views alias the 2-slot buffer ring
    expected = [shard_view(engine.plan, stream.build(i).copy_masks())
                for i in range(5)]
    from repro.core.trainer import _MultiStreamPrefetcher
    stream.seek(0)
    pool = _MultiStreamPrefetcher(
        stream, lambda v: shard_view(engine.plan, v), steps=5, workers=4)
    got = list(pool)
    assert len(got) == 5
    assert stream.cursor == 5
    for a, b in zip(got, expected):
        for k in a:
            assert np.array_equal(a[k], b[k])


def test_prefetch_on_off_identical(setup):
    g, engine, clusters = setup
    outs, params = [], []
    for prefetch in (True, False):
        trainer = Trainer(engine, adam(1e-2), seed=0)
        out = trainer.fit(_views(g, "mini", clusters, seed=7), steps=6,
                          prefetch=prefetch)
        outs.append(out["losses"])
        params.append(trainer.params)
    assert outs[0] == outs[1]
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(params[0]),
                    jax.tree_util.tree_leaves(params[1])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_prefetch_propagates_iterator_errors(setup):
    g, engine, clusters = setup
    trainer = Trainer(engine, adam(1e-2), seed=0)

    def broken():
        yield from itertools.islice(_views(g, "mini", clusters), 2)
        raise RuntimeError("stream died")

    with pytest.raises(RuntimeError, match="stream died"):
        trainer.fit(broken(), steps=10)
    assert trainer.step_num == 2   # the two good views were trained on


def test_bounded_in_flight_matches_unbounded(setup):
    g, engine, clusters = setup
    losses = []
    for mif in (1, 0):
        trainer = Trainer(engine, adam(1e-2), seed=0)
        out = trainer.fit(_views(g, "cluster", clusters, seed=2), steps=4,
                          max_in_flight=mif)
        losses.append(out["losses"])
    assert losses[0] == losses[1]


# ---------------------------------------------------------------------------
# eval / infer hooks
# ---------------------------------------------------------------------------


def test_eval_hook_and_infer_compiled_once(setup):
    g, engine, clusters = setup
    trainer = Trainer(engine, adam(1e-2), seed=0)
    gv = global_batch_view(g, 2)
    out = trainer.fit(_views(g, "mini", clusters), steps=6, eval_every=3,
                      eval_view=gv)
    assert [e["step"] for e in out["evals"]] == [3, 6]
    assert all(0.0 <= e["eval_acc"] <= 1.0 for e in out["evals"])
    # a second fit reuses the compiled infer
    trainer.fit(_views(g, "global", clusters), steps=3, eval_every=3,
                eval_view=gv)
    assert trainer.trace_counts["infer"] == 1
    trainer.assert_compiled_once()


# ---------------------------------------------------------------------------
# checkpoint resume
# ---------------------------------------------------------------------------


def test_checkpoint_resume_midstream(setup, tmp_path):
    g, engine, clusters = setup
    ckdir = str(tmp_path / "ck")

    straight = Trainer(engine, adam(1e-2), seed=0)
    straight.fit(_views(g, "mini", clusters, seed=11), steps=8,
                 checkpoint_every=4, checkpoint_dir=ckdir)

    resumed = Trainer(engine, adam(1e-2), seed=99)   # different init
    assert resumed.restore(ckdir, step=4) == 4
    views = _views(g, "mini", clusters, seed=11)
    for _ in range(4):                               # fast-forward the stream
        next(views)
    resumed.fit(views, steps=4)
    resumed.assert_compiled_once()                   # restore didn't retrace

    assert resumed.step_num == straight.step_num == 8
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(straight.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_view_cursor_fast_forwards_stream(setup, tmp_path):
    """restore() records the view-stream cursor from the checkpoint, and
    the next fit() over a ViewStream fast-forwards the stream itself —
    no caller-side ``next()`` skipping (the ROADMAP item)."""
    import jax
    g, engine, clusters = setup
    ckdir = str(tmp_path / "ck")

    straight = Trainer(engine, adam(1e-2), seed=0)
    straight.fit(_views(g, "mini", clusters, seed=31), steps=8,
                 checkpoint_every=4, checkpoint_dir=ckdir)
    assert straight.view_cursor == 8

    resumed = Trainer(engine, adam(1e-2), seed=99)   # different init
    assert resumed.restore(ckdir, step=4) == 4
    assert resumed.view_cursor == 4
    # fresh stream, cursor 0 — fit seeks it to 4 automatically
    resumed.fit(_views(g, "mini", clusters, seed=31), steps=4)
    resumed.assert_compiled_once()
    assert resumed.step_num == straight.step_num == 8
    for a, b in zip(jax.tree_util.tree_leaves(straight.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_restore_cursor_consumed_by_any_fit(setup, tmp_path):
    """A fit over a plain iterator consumes a pending restore cursor —
    it must not stay armed and silently fast-forward a later stream."""
    g, engine, clusters = setup
    ckdir = str(tmp_path / "ck")
    t = Trainer(engine, adam(1e-2), seed=0)
    t.fit(_views(g, "mini", clusters, seed=41), steps=4,
          checkpoint_every=4, checkpoint_dir=ckdir)
    t2 = Trainer(engine, adam(1e-2), seed=0)
    t2.restore(ckdir)
    # legacy path: plain generator, caller fast-forwards by hand
    legacy = iter([v for v in itertools.islice(
        _views(g, "mini", clusters, seed=41), 5)][4:])
    t2.fit(legacy, steps=1)
    # a later unrelated stream must start at ITS cursor, not index 4
    fresh = _views(g, "cluster", clusters, seed=42)
    t2.fit(fresh, steps=2)
    assert fresh.cursor == 2


def test_global_stream_multiworker_staging(setup):
    """The shared staging cache is safe under the worker pool: the static
    global view never yields a half-written (None) staged batch."""
    g, engine, clusters = setup
    for _ in range(3):
        trainer = Trainer(engine, adam(1e-2), seed=0)
        out = trainer.fit(_views(g, "global", clusters), steps=5,
                          prefetch=True, prefetch_workers=4)
        assert len(out["losses"]) == 5
        assert all(np.isfinite(l) for l in out["losses"])


def test_checkpoint_latest_roundtrip(setup, tmp_path):
    g, engine, clusters = setup
    trainer = Trainer(engine, adam(1e-2), seed=0)
    trainer.fit(_views(g, "global", clusters), steps=3)
    trainer.save(str(tmp_path))
    other = Trainer(engine, adam(1e-2), seed=1)
    assert other.restore(str(tmp_path)) == 3
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(trainer.params),
                    jax.tree_util.tree_leaves(other.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# compact bucketed trainer: once-per-bucket trace contract (PR 6)
# ---------------------------------------------------------------------------


def test_compact_trainer_bucket_retrace_contract():
    """The bucketed analog of compiled-once: exactly one trace per
    *touched* (n_pad, e_pad) shape, and repeat epochs over the same
    buckets add zero traces."""
    from repro.core.trainer import CompactTrainer
    from repro.models import make_gnn
    g = _graph(seed=6)
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)
    trainer = CompactTrainer(make_gnn(cfg), g, adam(1e-2), seed=0)
    with pytest.raises(RetraceError, match="never ran"):
        trainer.assert_compiled_per_bucket()    # no step yet

    # tiny capped mini views land in the smallest bucket; the dense
    # global stream passes through at the full-graph shape — two
    # guaranteed-distinct staged shapes
    mini = strategy_views(g, "mini", 2, seed=0, steps=3, batch_nodes=4,
                          neighbor_cap=2, compact=True)
    trainer.fit(mini, prefetch=False)
    trainer.fit(strategy_views(g, "global", 2, steps=2), prefetch=False)
    assert (g.num_nodes, g.num_edges) in trainer.buckets_touched
    assert len(trainer.buckets_touched) == 2
    assert trainer.trace_counts["train_step"] == 2
    trainer.assert_compiled_per_bucket()

    # repeat epochs: same buckets, ZERO new traces
    trainer.fit(strategy_views(g, "mini", 2, seed=1, steps=3,
                               batch_nodes=4, neighbor_cap=2,
                               compact=True), prefetch=False)
    trainer.fit(strategy_views(g, "global", 2, steps=1), prefetch=False)
    assert trainer.trace_counts["train_step"] == 2
    assert trainer.step_num == 9
    trainer.assert_compiled_per_bucket()
    # reset keeps the compiled steps
    trainer.reset(seed=1)
    trainer.fit(strategy_views(g, "mini", 2, seed=2, steps=2,
                               batch_nodes=4, neighbor_cap=2,
                               compact=True), prefetch=False)
    assert trainer.trace_counts["train_step"] == 2

    trainer.trace_counts["train_step"] = 5      # simulate a retrace
    with pytest.raises(RetraceError, match="traced 5 times"):
        trainer.assert_compiled_per_bucket()


def test_compact_trainer_prefetch_deterministic():
    """Compact staging under the worker pool: identical trajectories for
    no-prefetch / 1 worker / 4 workers (the staged block is detached from
    the per-bucket ring before the stage lock releases)."""
    from repro.core.trainer import CompactTrainer
    from repro.models import make_gnn
    g = _graph(seed=7)
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16,
                    num_classes=4, feature_dim=8)
    model = make_gnn(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0), 8)
    ref = None
    for kwargs in ({"prefetch": False},
                   {"prefetch": True, "prefetch_workers": 1},
                   {"prefetch": True, "prefetch_workers": 4}):
        trainer = CompactTrainer(model, g, adam(1e-2), params=params)
        out = trainer.fit(strategy_views(g, "mini", 2, seed=13, steps=6,
                                         batch_nodes=16, compact=True),
                          **kwargs)
        if ref is None:
            ref = out["losses"]
        else:
            assert out["losses"] == ref, kwargs


def test_engine_trainer_compact_stream_parity(setup):
    """The distributed engine consumes compact streams through
    _shard_compact bit-exactly: same losses as the dense stream, and the
    engine's compiled-once contract holds (sharded shapes come from the
    PartitionPlan, not the view)."""
    g, engine, clusters = setup
    losses = {}
    for compact in (False, True):
        trainer = Trainer(engine, adam(1e-2), seed=0)
        out = trainer.fit(
            strategy_views(g, "mini", 2, seed=17, batch_nodes=24,
                           compact=compact), steps=4)
        out2 = trainer.fit(
            strategy_views(g, "cluster", 2, seed=17, clusters=clusters,
                           clusters_per_batch=2, halo_hops=1,
                           compact=compact), steps=3)
        trainer.assert_compiled_once()
        losses[compact] = out["losses"] + out2["losses"]
    assert losses[False] == losses[True]


# ---------------------------------------------------------------------------
# distributed (P=4) sweep — subprocess with fake devices, slow lane
# ---------------------------------------------------------------------------

_DIST = r"""
import numpy as np, jax
from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters
from repro.core.engine import HybridParallelEngine
from repro.core.partition import build_partitions
from repro.core.strategies import (global_batch_view, shard_view,
                                   shard_view_loop, strategy_views)
from repro.core.trainer import Trainer
from repro.graph import sbm_graph
from repro.models import make_gnn
from repro.optim import adam

g = sbm_graph(num_nodes=400, num_classes=4, feature_dim=8, p_in=0.05,
              p_out=0.005, seed=0).add_self_loops()
clusters = label_propagation_clusters(g, max_cluster_size=80, seed=0)
sg = build_partitions(g, 4)
for backend in ("reference", "csc"):
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=16, num_classes=4,
                    feature_dim=8, aggregate_backend=backend)
    engine = HybridParallelEngine(make_gnn(cfg), sg)
    trainer = Trainer(engine, adam(1e-2), seed=0)

    # naive reference loop == Trainer, step for step
    params = engine.model.init(jax.random.PRNGKey(0), 8)
    opt = adam(1e-2)
    opt_state = opt.init(params)
    step_fn = engine.make_train_step(opt)
    naive_losses = []
    views = strategy_views(g, "mini", 2, seed=3, batch_nodes=40,
                           clusters=clusters)
    trainer_losses = trainer.fit(
        strategy_views(g, "mini", 2, seed=3, batch_nodes=40,
                       clusters=clusters), steps=4)["losses"]
    for _ in range(4):
        params, opt_state, loss = step_fn(
            params, opt_state, shard_view_loop(sg.plan, next(views)))
        naive_losses.append(float(loss))
    assert np.allclose(naive_losses, trainer_losses, atol=1e-6), (
        backend, naive_losses, trainer_losses)

    for strategy in ("global", "cluster"):
        trainer.fit(strategy_views(g, strategy, 2, seed=1,
                                   clusters=clusters), steps=2)
    trainer.assert_compiled_once()
    acc = trainer.evaluate(global_batch_view(g, 2))
    assert 0.0 <= acc <= 1.0
    print(backend, "ok", trainer.trace_counts)
print("distributed trainer ok")
"""


@pytest.mark.slow
def test_trainer_distributed_p4():
    out = run_with_devices(_DIST, n_devices=4)
    assert "distributed trainer ok" in out
