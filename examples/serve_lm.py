"""Batched LM serving: prefill a batch of prompts, decode with a KV cache
(rolling O(window) cache for the sliding-window arch). Uses the reduced
mixtral-8x7b config so it runs on CPU; the identical code path serves the
full config on a pod via launch/dryrun's serve_step sharding.

    PYTHONPATH=src python examples/serve_lm.py [--new-tokens 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import build_model
from repro.config import get_arch_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch_config(args.arch).reduced().replace(
        dtype="float32", sliding_window=16)
    model = build_model(cfg, remat=False, rolling_window_decode=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P, N = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)),
                          jnp.int32)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=P + N))
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, caches, idx = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    generated = [jnp.argmax(logits[:, -1], -1)]
    t0 = time.perf_counter()
    for _ in range(N):
        tok = generated[-1][:, None]
        logits, caches, idx = decode(params, {"tokens": tok}, caches, idx)
        generated.append(jnp.argmax(logits[:, -1], -1))
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    toks = jnp.stack(generated[1:], axis=1)
    print(f"arch={args.arch} (reduced)  batch={B}  prompt={P}  new={N}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({B * P / t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode * 1e3:.1f} ms total, "
          f"{t_decode / N * 1e3:.2f} ms/step, "
          f"{B * N / t_decode:.0f} tok/s")
    print(f"sample continuation (seq 0): {np.asarray(toks[0])[:16]}")
    print(f"rolling SWA cache: window={cfg.sliding_window} slots "
          f"(O(window), not O(seq))")


if __name__ == "__main__":
    main()
