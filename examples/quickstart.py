"""Quickstart: train a GCN with GraphTheta-style global-batch in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --backend csc   # Pallas Sum stage
"""
import argparse

import jax

from repro.config import GNNConfig
from repro.core.mpgnn import accuracy_block, loss_block
from repro.core.strategies import global_batch_view
from repro.graph import make_dataset
from repro.models import make_gnn
from repro.optim import adam


def main(backend: str = "reference"):
    g = make_dataset("cora", seed=0).add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=32, num_classes=7,
                    feature_dim=g.node_features.shape[1],
                    aggregate_backend=backend)
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    opt = adam(1e-2, weight_decay=5e-4)
    state = opt.init(params)
    block = global_batch_view(g, cfg.num_layers).as_block(
        csc_plan=backend == "csc")

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: loss_block(model, p, block))(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    for i in range(100):
        params, state, loss = step(params, state)
        if i % 20 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")
    acc = accuracy_block(model, params, block,
                         mask=g.test_mask.astype("float32"))
    print(f"test accuracy: {float(acc):.4f}")

    # ... or the facade: one typed job, the right trainer picked for
    # you (compiled-once, trace contract certified), then chain straight
    # into offline inference and online serving
    import repro.api as api

    result = api.train(api.TrainJob(dataset="cora", steps=100, hidden=32,
                                    eval_every=100))
    print(f"facade test accuracy: {result.final_acc:.4f}")
    server = api.serve(result, api.ServeConfig(max_batch=8))
    preds = server.submit([0, 1, 2, 3]).argmax(-1)
    print(f"online predictions for nodes 0..3: {preds}")
    server.assert_compiled_per_bucket()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "csc"],
                    help="Sum-stage aggregation backend")
    main(ap.parse_args().backend)
