"""End-to-end driver: distributed GNN training the way the paper runs it —
a worker group (8 simulated workers here; 1,024 in the paper) jointly
computes every batch of an edge-attributed power-law "Alipay-like" graph
with the in-house GAT-E model, under all three training strategies.

    PYTHONPATH=src python examples/distributed_training.py [--steps 200]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import numpy as np

from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters
from repro.core.engine import HybridParallelEngine
from repro.core.mpgnn import accuracy_block
from repro.core.partition import build_partitions, partition_stats
from repro.core.strategies import (cluster_batch_views, global_batch_view,
                                   mini_batch_views, shard_view)
from repro.graph import make_dataset
from repro.models import make_gnn
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=8000)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--partition", default="1d_src",
                    choices=["1d_src", "1d_dst", "vertex_cut"])
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "csc"],
                    help="Sum-stage aggregation backend")
    args = ap.parse_args()

    g = make_dataset("alipay_like", num_nodes=args.nodes, seed=0)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"{g.edge_features.shape[1]} edge attrs, "
          f"max degree {g.in_degree().max()}")

    cfg = GNNConfig(model="gat_e", num_layers=2, hidden_dim=32,
                    num_classes=2, feature_dim=g.node_features.shape[1],
                    edge_feature_dim=g.edge_features.shape[1], num_heads=4,
                    aggregate_backend=args.backend)
    model = make_gnn(cfg)

    sg = build_partitions(g, args.workers, method=args.partition,
                          gcn_norm=False)
    print("partition stats:", partition_stats(sg))
    engine = HybridParallelEngine(model, sg)

    clusters = label_propagation_clusters(
        g, max_cluster_size=max(200, g.num_nodes // 20), seed=0)
    strategies = {
        "global": iter(lambda: global_batch_view(g, 2), None),
        "mini": mini_batch_views(g, 2, batch_nodes=g.num_nodes // 50,
                                 seed=0),
        "cluster": cluster_batch_views(
            g, 2, clusters, clusters_per_batch=max(
                1, (int(clusters.max()) + 1) // 20), halo_hops=1, seed=0),
    }

    steps_per = max(1, args.steps // 3)
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    opt = adam(5e-3)
    opt_state = opt.init(params)
    step_fn = engine.make_train_step(opt)
    infer = engine.make_infer()

    for name, views in strategies.items():
        t0 = time.perf_counter()
        for i in range(steps_per):
            view = next(views)
            params, opt_state, loss = step_fn(params, opt_state,
                                              shard_view(sg.plan, view))
        wall = time.perf_counter() - t0
        # distributed inference through the same engine (paper §4.3)
        logits = infer(params, {**shard_view(
            sg.plan, global_batch_view(g, 2))})
        preds = engine.gather_predictions(np.asarray(logits))
        test = g.test_mask
        acc = float((preds.argmax(-1)[test] == g.labels[test]).mean())
        print(f"[{name:8s}] {steps_per} steps, {wall:.1f}s "
              f"({wall / steps_per * 1e3:.0f} ms/step), "
              f"loss {float(loss):.4f}, test acc {acc:.4f}")
    print("done: one engine, three strategies, unified train+infer.")


if __name__ == "__main__":
    main()
