"""End-to-end driver: distributed GNN training the way the paper runs it —
a worker group (8 simulated workers here; 1,024 in the paper) jointly
computes every batch of an edge-attributed power-law "Alipay-like" graph
with the in-house GAT-E model, under all three training strategies.

Since PR 4 the loop is the compiled-once :class:`repro.core.Trainer`:
one jitted train step serves global-, mini- and cluster-batch alike while
a pool of prefetch workers builds (vectorized ViewBuilder, cached cluster
sets), shards (vectorized ``shard_view``) and stages upcoming views —
deterministically, since view i depends only on (seed, i) — and
``assert_compiled_once()`` certifies that no strategy switch ever
retraced the step.

    PYTHONPATH=src python examples/distributed_training.py [--steps 200]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters
from repro.core.engine import HybridParallelEngine
from repro.core.partition import build_partitions, partition_stats
from repro.core.strategies import global_batch_view, strategy_views
from repro.core.trainer import Trainer
from repro.graph import make_dataset
from repro.models import make_gnn
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=8000)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--partition", default="1d_src",
                    choices=["1d_src", "1d_dst", "vertex_cut"])
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "csc"],
                    help="Sum-stage aggregation backend")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the host-side view prefetch pipeline")
    ap.add_argument("--prefetch-workers", type=int, default=None,
                    help="view-builder threads (default: min(4, cores-1); "
                    "any count yields a bit-identical loss trajectory)")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    g = make_dataset("alipay_like", num_nodes=args.nodes, seed=0)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"{g.edge_features.shape[1]} edge attrs, "
          f"max degree {g.in_degree().max()}")

    cfg = GNNConfig(model="gat_e", num_layers=2, hidden_dim=32,
                    num_classes=2, feature_dim=g.node_features.shape[1],
                    edge_feature_dim=g.edge_features.shape[1], num_heads=4,
                    aggregate_backend=args.backend)
    model = make_gnn(cfg)

    sg = build_partitions(g, args.workers, method=args.partition,
                          gcn_norm=False)
    print("partition stats:", partition_stats(sg))
    engine = HybridParallelEngine(model, sg)
    trainer = Trainer(engine, adam(5e-3), seed=0)

    clusters = label_propagation_clusters(
        g, max_cluster_size=max(200, g.num_nodes // 20), seed=0)
    eval_view = global_batch_view(g, 2)

    steps_per = max(1, args.steps // 3)
    for name in ("global", "mini", "cluster"):
        views = strategy_views(
            g, name, K=2, seed=0, batch_nodes=g.num_nodes // 50,
            clusters=clusters,
            clusters_per_batch=max(1, (int(clusters.max()) + 1) // 20))
        t0 = time.perf_counter()
        out = trainer.fit(views, steps=steps_per,
                          prefetch=not args.no_prefetch,
                          prefetch_workers=args.prefetch_workers,
                          checkpoint_every=steps_per if args.checkpoint_dir
                          else 0,
                          checkpoint_dir=args.checkpoint_dir)
        wall = time.perf_counter() - t0
        # distributed inference through the same engine (paper §4.3),
        # compiled once and shared by every eval
        acc = trainer.evaluate(eval_view)
        print(f"[{name:8s}] {steps_per} steps, {wall:.1f}s "
              f"({wall / steps_per * 1e3:.0f} ms/step), "
              f"loss {out['losses'][-1]:.4f}, test acc {acc:.4f}")
    trainer.assert_compiled_once()
    print("done: one engine, three strategies, one compiled train step "
          f"(traced {trainer.trace_counts['train_step']}x over "
          f"{trainer.step_num} steps).")


if __name__ == "__main__":
    main()
