"""The paper's flexible-training-strategy feature: train the same GCN with
global-, mini- and cluster-batch and compare accuracy / step cost / memory
proxies (Tables 2-4 in miniature).

Since PR 4 all three strategies run through one :class:`repro.core.Trainer`
over a 4-worker hybrid-parallel engine: ``trainer.reset()`` between
strategies keeps the compiled step, so the whole comparison — all
strategies, eval included — traces the train step exactly once
(``assert_compiled_once``).

PR 6's compact sampled-subgraph views ride the same engine: the
``compact`` rows feed :class:`~repro.core.views.CompactView` streams
through the identical compiled step (an O(view) shard scatter instead of
dense-mask gathers) — same trajectory, a fraction of the per-view host
bytes.

    PYTHONPATH=src python examples/strategy_comparison.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import time

import numpy as np

from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters, modularity
from repro.core.engine import HybridParallelEngine
from repro.core.partition import build_partitions
from repro.core.strategies import global_batch_view, strategy_views
from repro.core.trainer import Trainer
from repro.graph import make_dataset
from repro.models import make_gnn
from repro.optim import adam


def _view_host_bytes(v) -> int:
    """Per-view host footprint: compact views own O(view) id arrays, a
    dense view owns (K, N)/(K, E) masks, the global view owns one (N,)."""
    if hasattr(v, "nbytes"):            # CompactView
        return v.nbytes()
    na = v.node_active.nbytes if v.node_active is not None else 0
    ea = v.edge_active.nbytes if v.edge_active is not None else 0
    return na + ea + v.loss_mask.nbytes


def run(trainer, g, clusters, strategy: str, steps: int,
        compact: bool = False):
    trainer.reset(seed=0)
    views = strategy_views(g, strategy, K=2, seed=0, batch_nodes=64,
                           clusters=clusters, clusters_per_batch=4,
                           compact=compact)
    t0 = time.perf_counter()
    trainer.fit(views, steps=steps)     # multi-stream prefetch pool
    wall = time.perf_counter() - t0
    acc = trainer.evaluate(global_batch_view(g, 2),
                           mask=g.test_mask.astype(np.float32))
    # view i is a pure function of (seed, i), so the exact views the run
    # consumed can be replayed off the timed path to measure the peak
    # active-set size (Table 4's memory proxy) and per-view host bytes
    builder = views.make_builder()
    replayed = [views.build(i, builder) for i in range(views.cursor)]
    peak = max((v.active_counts()["active_nodes"] for v in replayed),
               default=g.num_nodes)
    view_kb = max((_view_host_bytes(v) / 1024 for v in replayed),
                  default=_view_host_bytes(global_batch_view(g, 2)) / 1024)
    return {"strategy": strategy + ("+compact" if compact else ""),
            "acc": acc, "ms_per_step": wall / steps * 1e3,
            "peak_active_nodes": peak, "view_kb": view_kb}


def main():
    g = make_dataset("reddit_like", num_nodes=3000, seed=0).add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=64, num_classes=8,
                    feature_dim=g.node_features.shape[1])
    model = make_gnn(cfg)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges")

    clusters = label_propagation_clusters(g, max_cluster_size=300, iters=4,
                                          seed=0)
    print(f"  [cluster] {clusters.max() + 1} communities, "
          f"modularity {modularity(g, clusters):.3f}")

    import jax
    P = min(4, len(jax.devices()))
    engine = HybridParallelEngine(model, build_partitions(g, P))
    trainer = Trainer(engine, adam(1e-2), seed=0)
    # warmup: pay the (single) trace+compile outside the timed windows so
    # the first strategy's ms/step isn't charged for it
    trainer.fit(strategy_views(g, "global", K=2), steps=2)

    print(f"{'strategy':16s} {'test_acc':>8s} {'ms/step':>8s} "
          f"{'peak_active':>11s} {'view_kb':>8s}")
    for strategy, compact in (("global", False), ("mini", False),
                              ("cluster", False), ("mini", True),
                              ("cluster", True)):
        r = run(trainer, g, clusters, strategy, steps=120, compact=compact)
        print(f"{r['strategy']:16s} {r['acc']:8.4f} "
              f"{r['ms_per_step']:8.1f} {r['peak_active_nodes']:11d} "
              f"{r['view_kb']:8.1f}")
    trainer.assert_compiled_once()
    print(f"one compiled train step served every strategy, dense AND "
          f"compact ({trainer.trace_counts['train_step']} trace, P={P}).")


if __name__ == "__main__":
    main()
