"""The paper's flexible-training-strategy feature: train the same GCN with
global-, mini- and cluster-batch and compare accuracy / step cost / memory
proxies (Tables 2-4 in miniature).

    PYTHONPATH=src python examples/strategy_comparison.py
"""
import time

import jax
import numpy as np

from repro.config import GNNConfig
from repro.core.clustering import label_propagation_clusters, modularity
from repro.core.mpgnn import accuracy_block, loss_block
from repro.core.strategies import (cluster_batch_views, global_batch_view,
                                   mini_batch_views)
from repro.graph import make_dataset
from repro.models import make_gnn
from repro.optim import adam


def run(strategy: str, g, model, cfg, steps: int):
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    opt = adam(1e-2)
    state = opt.init(params)
    if strategy == "global":
        views = iter(lambda: global_batch_view(g, cfg.num_layers), None)
    elif strategy == "mini":
        views = mini_batch_views(g, cfg.num_layers, batch_nodes=64, seed=0)
    else:
        clusters = label_propagation_clusters(g, max_cluster_size=300,
                                              iters=4, seed=0)
        print(f"  [cluster] {clusters.max() + 1} communities, "
              f"modularity {modularity(g, clusters):.3f}")
        views = cluster_batch_views(g, cfg.num_layers, clusters,
                                    clusters_per_batch=4, halo_hops=1,
                                    seed=0)

    @jax.jit
    def step(params, state, block):
        loss, grads = jax.value_and_grad(
            lambda p: loss_block(model, p, block))(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    peak = 0
    t0 = time.perf_counter()
    for _ in range(steps):
        v = next(views)
        peak = max(peak, v.active_counts()["active_nodes"])
        params, state, loss = step(params, state, v.as_block())
    wall = time.perf_counter() - t0
    gb = global_batch_view(g, cfg.num_layers).as_block()
    acc = float(accuracy_block(model, params, gb,
                               mask=g.test_mask.astype(np.float32)))
    return {"strategy": strategy, "acc": acc, "ms_per_step":
            wall / steps * 1e3, "peak_active_nodes": peak}


def main():
    g = make_dataset("reddit_like", num_nodes=3000, seed=0).add_self_loops()
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=64, num_classes=8,
                    feature_dim=g.node_features.shape[1])
    model = make_gnn(cfg)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges")
    print(f"{'strategy':10s} {'test_acc':>8s} {'ms/step':>8s} "
          f"{'peak_active':>11s}")
    for strategy in ("global", "mini", "cluster"):
        r = run(strategy, g, model, cfg, steps=120)
        print(f"{r['strategy']:10s} {r['acc']:8.4f} "
              f"{r['ms_per_step']:8.1f} {r['peak_active_nodes']:11d}")


if __name__ == "__main__":
    main()
